//! The distributed task-runtime core (the DuctTeip substrate): typed ids,
//! the task/data model, STF dependency inference, and the per-process
//! coordinator state machine.

pub mod data;
pub mod graph;
pub mod ids;
pub mod process;
pub mod task;

pub use data::{DataMeta, DataStore, Payload};
pub use graph::{GraphBuilder, TaskGraph};
pub use ids::{DataId, ProcessId, TaskId};
pub use process::{Effect, ProcessParams, ProcessState};
pub use task::{TaskKind, TaskNode};
