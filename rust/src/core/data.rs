//! Distributed data handles and per-process block storage.
//!
//! Data placement drives task placement (owner computes, as in DuctTeip);
//! block values move between processes as message payloads.  In simulation
//! mode payloads are size-only; in real mode they carry `f32` block data fed
//! to the PJRT kernels.

use std::sync::Arc;

use super::ids::{DataId, ProcessId};

/// Static metadata for one data handle.
#[derive(Debug, Clone)]
pub struct DataMeta {
    pub id: DataId,
    /// The process that owns (hosts the canonical copy of) this handle.
    pub home: ProcessId,
    /// Row-major dimensions; vectors are (n, 1).
    pub rows: usize,
    pub cols: usize,
}

impl DataMeta {
    pub fn elems(&self) -> usize {
        self.rows * self.cols
    }
}

/// A block value in flight or at rest.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Control-plane only (dependency notification without data).
    None,
    /// Simulation mode: the value is not materialized, only its size (in
    /// doubles) is modeled by the network.
    Sim,
    /// Real mode: row-major f32 block contents, shared by reference.
    ///
    /// `Arc` because blocks are immutable once produced (the graph's
    /// WAR/WAW edges guarantee no in-place update races — see `DataStore`
    /// below): the store, an in-flight `TaskExport`, and a worker's kernel
    /// argument list may all alias the same allocation, so cloning a
    /// payload is pointer-sized instead of a block copy.
    Real(Arc<[f32]>),
}

impl Payload {
    /// Wrap freshly produced block contents (the one copy a block ever
    /// pays: `Vec` → shared slice at creation).
    pub fn real_from(v: Vec<f32>) -> Payload {
        Payload::Real(v.into())
    }

    pub fn is_real(&self) -> bool {
        matches!(self, Payload::Real(_))
    }

    pub fn real(&self) -> Option<&[f32]> {
        match self {
            Payload::Real(v) => Some(v),
            _ => None,
        }
    }

    /// A shared handle to the block contents (no copy); `None` for
    /// control-plane / sim payloads.
    pub fn real_arc(&self) -> Option<Arc<[f32]>> {
        match self {
            Payload::Real(v) => Some(Arc::clone(v)),
            _ => None,
        }
    }
}

/// Per-process store of current block values.
///
/// Correctness of the single-buffer-per-handle design rests on the graph's
/// WAR/WAW edges: a new version cannot be produced anywhere before every
/// consumer of the previous version has completed (see `core::graph`).
///
/// `DataId`s are dense indices into the graph's data table, so the store is
/// a plain `Vec` indexed by id — every `get` on the execution hot path is a
/// bounds check and a pointer chase instead of a SipHash probe.
#[derive(Debug, Default)]
pub struct DataStore {
    blocks: Vec<Option<Payload>>,
    live: usize,
}

impl DataStore {
    pub fn new() -> Self {
        DataStore { blocks: Vec::new(), live: 0 }
    }

    /// Pre-size for a graph with `num_handles` data handles (avoids the
    /// grow-on-insert path entirely for in-graph ids).
    pub fn with_capacity(num_handles: usize) -> Self {
        DataStore { blocks: vec![None; num_handles], live: 0 }
    }

    pub fn insert(&mut self, id: DataId, value: Payload) {
        let i = id.idx();
        if i >= self.blocks.len() {
            self.blocks.resize(i + 1, None);
        }
        if self.blocks[i].replace(value).is_none() {
            self.live += 1;
        }
    }

    pub fn get(&self, id: DataId) -> Option<&Payload> {
        self.blocks.get(id.idx()).and_then(Option::as_ref)
    }

    pub fn contains(&self, id: DataId) -> bool {
        self.get(id).is_some()
    }

    pub fn take(&mut self, id: DataId) -> Option<Payload> {
        let taken = self.blocks.get_mut(id.idx()).and_then(Option::take);
        if taken.is_some() {
            self.live -= 1;
        }
        taken
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip() {
        let mut s = DataStore::new();
        let id = DataId(3);
        assert!(!s.contains(id));
        s.insert(id, Payload::real_from(vec![1.0, 2.0]));
        assert!(s.contains(id));
        assert_eq!(s.get(id).and_then(|p| p.real()), Some(&[1.0f32, 2.0][..]));
        let taken = s.take(id).expect("present");
        assert!(taken.is_real());
        assert!(s.is_empty());
    }

    #[test]
    fn overwrite_replaces() {
        let mut s = DataStore::new();
        s.insert(DataId(0), Payload::Sim);
        s.insert(DataId(0), Payload::real_from(vec![5.0]));
        assert!(s.get(DataId(0)).expect("present").is_real());
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn sparse_ids_and_preallocation() {
        let mut s = DataStore::with_capacity(4);
        assert!(s.is_empty());
        s.insert(DataId(7), Payload::Sim); // beyond capacity: grows
        assert!(s.contains(DataId(7)));
        assert!(!s.contains(DataId(3)));
        assert_eq!(s.len(), 1);
        assert!(s.take(DataId(3)).is_none());
        assert_eq!(s.take(DataId(7)), Some(Payload::Sim));
        assert!(s.is_empty());
    }

    #[test]
    fn payload_clone_shares_the_allocation() {
        let p = Payload::real_from(vec![1.0, 2.0, 3.0]);
        let q = p.clone();
        let (a, b) = (p.real_arc().expect("real"), q.real_arc().expect("real"));
        assert!(Arc::ptr_eq(&a, &b), "clone must alias, not copy");
        assert_eq!(p, q);
        // reads through either handle see the same contents
        assert_eq!(q.real(), Some(&[1.0f32, 2.0, 3.0][..]));
    }

    #[test]
    fn real_arc_is_none_for_control_payloads() {
        assert!(Payload::None.real_arc().is_none());
        assert!(Payload::Sim.real_arc().is_none());
    }

    #[test]
    fn meta_elems() {
        let m = DataMeta { id: DataId(0), home: ProcessId(1), rows: 8, cols: 4 };
        assert_eq!(m.elems(), 32);
    }
}
