//! Distributed data handles and per-process block storage.
//!
//! Data placement drives task placement (owner computes, as in DuctTeip);
//! block values move between processes as message payloads.  In simulation
//! mode payloads are size-only; in real mode they carry `f32` block data fed
//! to the PJRT kernels.

use std::collections::HashMap;

use super::ids::{DataId, ProcessId};

/// Static metadata for one data handle.
#[derive(Debug, Clone)]
pub struct DataMeta {
    pub id: DataId,
    /// The process that owns (hosts the canonical copy of) this handle.
    pub home: ProcessId,
    /// Row-major dimensions; vectors are (n, 1).
    pub rows: usize,
    pub cols: usize,
}

impl DataMeta {
    pub fn elems(&self) -> usize {
        self.rows * self.cols
    }
}

/// A block value in flight or at rest.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Control-plane only (dependency notification without data).
    None,
    /// Simulation mode: the value is not materialized, only its size (in
    /// doubles) is modeled by the network.
    Sim,
    /// Real mode: row-major f32 block contents.
    Real(Vec<f32>),
}

impl Payload {
    pub fn is_real(&self) -> bool {
        matches!(self, Payload::Real(_))
    }

    pub fn real(&self) -> Option<&[f32]> {
        match self {
            Payload::Real(v) => Some(v),
            _ => None,
        }
    }
}

/// Per-process store of current block values.
///
/// Correctness of the single-buffer-per-handle design rests on the graph's
/// WAR/WAW edges: a new version cannot be produced anywhere before every
/// consumer of the previous version has completed (see `core::graph`).
#[derive(Debug, Default)]
pub struct DataStore {
    blocks: HashMap<DataId, Payload>,
}

impl DataStore {
    pub fn new() -> Self {
        DataStore { blocks: HashMap::new() }
    }

    pub fn insert(&mut self, id: DataId, value: Payload) {
        self.blocks.insert(id, value);
    }

    pub fn get(&self, id: DataId) -> Option<&Payload> {
        self.blocks.get(&id)
    }

    pub fn contains(&self, id: DataId) -> bool {
        self.blocks.contains_key(&id)
    }

    pub fn take(&mut self, id: DataId) -> Option<Payload> {
        self.blocks.remove(&id)
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip() {
        let mut s = DataStore::new();
        let id = DataId(3);
        assert!(!s.contains(id));
        s.insert(id, Payload::Real(vec![1.0, 2.0]));
        assert!(s.contains(id));
        assert_eq!(s.get(id).and_then(|p| p.real()), Some(&[1.0f32, 2.0][..]));
        let taken = s.take(id).expect("present");
        assert!(taken.is_real());
        assert!(s.is_empty());
    }

    #[test]
    fn overwrite_replaces() {
        let mut s = DataStore::new();
        s.insert(DataId(0), Payload::Sim);
        s.insert(DataId(0), Payload::Real(vec![5.0]));
        assert!(s.get(DataId(0)).expect("present").is_real());
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn meta_elems() {
        let m = DataMeta { id: DataId(0), home: ProcessId(1), rows: 8, cols: 4 };
        assert_eq!(m.elems(), 32);
    }
}
