//! Strongly-typed identifiers for processes, tasks and data handles.

use std::fmt;

/// An MPI-rank-like process index (the paper's `p_i`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProcessId(pub u32);

impl ProcessId {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for ProcessId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "p{}", self.0)
    }
}

/// Index into the immutable `TaskGraph`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u32);

impl TaskId {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// A distributed data handle (one block of the matrix, one vector, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DataId(pub u32);

impl DataId {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for DataId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "d{}", self.0)
    }
}
