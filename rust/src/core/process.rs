//! The per-process coordinator state machine — "one coordinator, two
//! clocks" (DESIGN.md §7.1).
//!
//! `ProcessState` contains everything a DuctTeip-style process does:
//! dependency bookkeeping, the ready queue, data storage, balancer-policy
//! driving, export mechanics, and termination detection.  It is a *pure*
//! state machine: inputs are `start`/`on_message`/`on_exec_complete`/
//! `on_tick` with an explicit `now`; outputs are `Effect`s.  The DES
//! (`sim::engine`) and the threaded runtime (`runtime::threaded`) interpret
//! the effects; neither contains any scheduling or DLB logic of its own.
//!
//! The *which/when/how much* of load balancing lives behind the
//! [`BalancerPolicy`] trait (`dlb::policy`): this file only interprets
//! [`PolicyAction`]s — sending the messages a policy asks for and running
//! the export mechanics (input gathering, counters, `TaskExport` framing)
//! that every policy shares.

use std::sync::Arc;

use crate::config::{Config, PolicyKind, Strategy};
use crate::dlb::pairing::PairingConfig;
use crate::dlb::policy::{
    self, AdaptiveConfig, BalancerPolicy, PolicyAction, PolicyObs, PolicySpec, SosParams,
};
use crate::dlb::strategy::{select_exports, PartnerInfo};
use crate::dlb::{CostModel, PerfRecorder};
use crate::metrics::counters::DlbCounters;
use crate::metrics::recorder::TraceRecorder;
use crate::metrics::trace::WorkloadTrace;
use crate::net::message::{Envelope, MigratedTask, Msg, Role};
use crate::net::topology::Topology;
use crate::sched::queue::{ReadyQueue, ReadyTask};
use crate::util::rng::Rng;

use super::data::{DataStore, Payload};
use super::graph::TaskGraph;
use super::ids::{DataId, ProcessId, TaskId};

/// Instructions to the surrounding engine.
#[derive(Debug)]
pub enum Effect {
    /// Transmit a message.  Sends of one step that share (destination,
    /// computed delay) may be coalesced by the DES transport into a single
    /// delivery event (`[sim] coalesce`); the receiver still observes them
    /// individually, in this buffer's emission order, at the same arrival
    /// time — so the state machine never needs to know.
    Send(Envelope),
    /// Begin executing `task` on a free core; the engine must call
    /// `on_exec_complete` when it finishes (after the modeled or real
    /// duration).
    StartExec { task: ReadyTask },
    /// Request an `on_tick` call at (or shortly after) time `at`.
    ScheduleTick { at: f64 },
    /// This process has observed global termination.
    Halt,
}

/// Immutable per-run parameters for a process.
#[derive(Debug, Clone)]
pub struct ProcessParams {
    pub dlb_enabled: bool,
    /// Which balancer drives migration (pairing | stealing | diffusion).
    pub policy: PolicyKind,
    /// Work stealing: steal half the excess vs a single task.
    pub steal_half: bool,
    /// Interconnect shape — source of the diffusion neighbor sets.
    pub topology: Topology,
    pub strategy: Strategy,
    pub wt: usize,
    /// §3's alternative model: a hysteresis gap above W_T.  Processes in
    /// the middle zone (wt < w ≤ wt + gap) neither search nor accept —
    /// fewer requests, less overshoot (an idle process that just received
    /// work does not immediately flip to busy).
    pub wt_gap: usize,
    pub pairing: PairingConfig,
    /// Hierarchical stealing: consecutive failed intra-node attempts before
    /// a hunt escalates to remote nodes.
    pub local_tries: usize,
    /// Second-order diffusion coefficients, derived from the topology once
    /// per run (a power iteration — O(P·E), too heavy per rank).  `Some`
    /// exactly when `policy` is `SosDiffusion`.
    pub sos: Option<SosParams>,
    /// Wrap the policy in the AIMD δ controller (`dlb.adaptive_delta`).
    pub adaptive_delta: bool,
    pub delta_min: f64,
    pub delta_max: f64,
    pub cores: usize,
    pub control_doubles: u64,
    pub cost: CostModel,
    /// Arm the per-process flight recorder (`[trace] enabled`).  Off is the
    /// default and costs one enum-discriminant test per hook; determinism
    /// tests pin that *on* changes no fingerprint bit either.
    pub trace: bool,
}

impl ProcessParams {
    pub fn from_config(c: &Config) -> Self {
        let mut cost = CostModel::new(c.flops_per_sec, c.doubles_per_sec);
        cost.task_overhead = c.task_overhead;
        cost.latency = c.net_latency;
        let topology = c.build_topology();
        let sos = (c.policy == PolicyKind::SosDiffusion)
            .then(|| SosParams::for_topology(&topology, c.processes));
        ProcessParams {
            dlb_enabled: c.dlb_enabled,
            policy: c.policy,
            steal_half: c.steal_half,
            topology,
            strategy: c.strategy,
            wt: c.wt,
            wt_gap: c.wt_gap,
            pairing: PairingConfig {
                tries: c.tries,
                delta: c.delta,
                confirm_timeout: c.confirm_timeout,
            },
            local_tries: c.local_tries,
            sos,
            adaptive_delta: c.adaptive_delta,
            delta_min: c.delta_min,
            delta_max: c.delta_max,
            cores: c.cores_per_process,
            control_doubles: c.control_doubles,
            cost,
            trace: c.trace_enabled,
        }
    }

    /// The balancer instantiation spec (shared by both engines).
    pub fn policy_spec(&self) -> PolicySpec {
        PolicySpec {
            kind: self.policy,
            pairing: self.pairing,
            steal_half: self.steal_half,
            local_tries: self.local_tries,
            adaptive: if self.adaptive_delta {
                Some(AdaptiveConfig::new(self.delta_min, self.delta_max))
            } else {
                None
            },
            sos: self.sos,
        }
    }
}

/// The state of one (virtual or threaded) process.
pub struct ProcessState {
    pub me: ProcessId,
    pub num_processes: usize,
    pub graph: Arc<TaskGraph>,
    pub params: ProcessParams,
    pub queue: ReadyQueue,
    pub store: DataStore,
    /// The pluggable balancer driving this process's migration decisions.
    pub policy: Box<dyn BalancerPolicy>,
    pub perf: PerfRecorder,
    pub trace: WorkloadTrace,
    /// Flight recorder: typed span/instant events when `params.trace` is
    /// set, a free no-op otherwise.  Strictly write-only from this state
    /// machine — it never feeds back into decisions or the RNG.
    pub recorder: TraceRecorder,
    pub halted: bool,
    /// Pin this process's busy/idle classification regardless of queue
    /// state — protocol micro-benchmarks only (Fig 3's pairing lab).
    pub role_override: Option<Role>,

    /// Remaining unsatisfied dependencies per task (only meaningful for
    /// tasks placed here).
    pending_deps: Vec<u32>,
    /// Per data handle: local tasks waiting for its v0 arrival (dense,
    /// indexed by `DataId` — ids are contiguous graph indices).
    v0_waiting: Vec<Vec<TaskId>>,
    /// Tasks homed here that have not yet completed (includes exported).
    owned_remaining: usize,
    /// Tasks currently executing on local cores.
    executing: usize,
    /// Tasks exported and awaiting `ResultReturn` (dense, indexed by
    /// `TaskId`).
    exported: Vec<bool>,
    /// Topology neighbor set (diffusion's exchange partners).
    neighbors: Vec<ProcessId>,
    rng: Rng,
    /// Rank-0 only: processes that reported completion.
    owners_done: usize,
    reported_done: bool,
    /// Statistic: completion time of the last locally-executed task.
    pub last_completion: f64,
}

impl ProcessState {
    /// `seed` must be identical across processes of a run (streams are
    /// forked per process id) for reproducibility.
    pub fn new(
        me: ProcessId,
        num_processes: usize,
        graph: Arc<TaskGraph>,
        params: ProcessParams,
        seed: u64,
    ) -> Self {
        let mut root = Rng::new(seed);
        let rng = root.fork(me.0 as u64 + 1);
        let balancer = policy::build(&params.policy_spec(), me, num_processes, &params.topology);
        let neighbors = params.topology.neighbors(me, num_processes);
        let perf = PerfRecorder::new(params.cost);
        let pending_deps = vec![0u32; graph.num_tasks()];
        let v0_waiting = vec![Vec::new(); graph.data.len()];
        let exported = vec![false; graph.num_tasks()];
        let store = DataStore::with_capacity(graph.data.len());
        let recorder = TraceRecorder::new(params.trace, graph.num_tasks());
        ProcessState {
            me,
            num_processes,
            graph,
            params,
            queue: ReadyQueue::new(),
            store,
            policy: balancer,
            perf,
            trace: WorkloadTrace::new(),
            recorder,
            halted: false,
            role_override: None,
            pending_deps,
            v0_waiting,
            owned_remaining: 0,
            executing: 0,
            exported,
            neighbors,
            rng,
            owners_done: 0,
            reported_done: false,
            last_completion: 0.0,
        }
    }

    /// Current workload w_i(t) (paper §3: ready tasks in the queue).
    pub fn workload(&self) -> usize {
        self.queue.workload()
    }

    /// Busy/idle classification: busy above W_T + gap, idle at or below
    /// W_T (gap = 0 reproduces the paper's base model).
    pub fn role(&self) -> Role {
        if let Some(r) = self.role_override {
            return r;
        }
        if self.workload() > self.params.wt + self.params.wt_gap {
            Role::Busy
        } else {
            Role::Idle
        }
    }

    /// §3's middle zone: with a non-zero gap, processes here sit out the
    /// pairing protocol entirely.
    pub fn in_middle_zone(&self) -> bool {
        if self.role_override.is_some() {
            return false;
        }
        let w = self.workload();
        w > self.params.wt && w <= self.params.wt + self.params.wt_gap
    }

    pub fn counters(&self) -> &DlbCounters {
        self.policy.counters()
    }

    pub fn tasks_done(&self) -> bool {
        self.owned_remaining == 0
    }

    fn send(&self, effects: &mut Vec<Effect>, to: ProcessId, msg: Msg) {
        let extra = self.sim_payload_doubles(&msg);
        let wire = msg.wire_doubles(self.params.control_doubles) + extra;
        effects.push(Effect::Send(Envelope { from: self.me, to, msg, wire_doubles: wire }));
    }

    /// `Payload::Sim` carries no length; size it from graph metadata so the
    /// DES network model charges realistic transfer times.
    fn sim_payload_doubles(&self, msg: &Msg) -> u64 {
        let one = |data: &DataId, p: &Payload| -> u64 {
            if matches!(p, Payload::Sim) {
                self.graph.meta(*data).elems() as u64
            } else {
                0
            }
        };
        match msg {
            Msg::TaskDone { data, payload, .. } | Msg::DataSend { data, payload } => {
                one(data, payload)
            }
            Msg::ResultReturn { task, payload } => {
                one(&self.graph.task(*task).output, payload)
            }
            Msg::TaskExport { tasks, .. } => tasks
                .iter()
                .flat_map(|mt| mt.inputs.iter())
                .map(|(d, p)| one(d, p))
                .sum(),
            _ => 0,
        }
    }

    fn record_trace(&mut self, now: f64) {
        let w = self.queue.workload();
        self.trace.record(now, w);
    }

    // ------------------------------------------------------------------
    // lifecycle
    // ------------------------------------------------------------------

    /// Initialize: seed dependency counters, push v0 data to remote
    /// consumers, enqueue initially-ready local tasks, stagger the first
    /// DLB search.  Appends to the caller-owned `effects` buffer (as do all
    /// the step entry points — the engines reuse one scratch `Vec` across
    /// events instead of allocating a fresh one per step).
    pub fn start(&mut self, now: f64, effects: &mut Vec<Effect>) {
        let graph = Arc::clone(&self.graph);

        // O(own tasks): dependency counters + initially-ready queue.
        for t in graph.tasks_of(self.me) {
            self.owned_remaining += 1;
            let mut missing = 0u32;
            for &a in &t.v0_args {
                if graph.meta(a).home != self.me {
                    missing += 1;
                    self.v0_waiting[a.idx()].push(t.id);
                }
            }
            self.pending_deps[t.id.idx()] = t.deps.len() as u32 + missing;
            if self.pending_deps[t.id.idx()] == 0 {
                self.queue.push(ReadyTask::home(t.id, self.me));
                self.recorder.task_ready(t.id, now);
            }
        }
        // Ship v0 handles homed here to their remote consumers (the
        // sorted/deduplicated pair list is precomputed on the graph).
        for &(to, data) in graph.v0_exports(self.me) {
            let payload = match self.store.get(data) {
                Some(p) => p.clone(),
                None => Payload::Sim,
            };
            self.send(effects, to, Msg::DataSend { data, payload });
        }
        self.record_trace(now);

        // done before starting? (process owns zero tasks)
        self.maybe_report_done(now, effects);
        self.maybe_exec(now, effects);

        if self.params.dlb_enabled {
            // stagger the first balancer activity uniformly over one δ
            self.policy.init(now, &mut self.rng);
            self.dlb_poll(now, effects);
        }
    }

    /// Start executions on free cores.
    fn maybe_exec(&mut self, now: f64, effects: &mut Vec<Effect>) {
        while self.executing < self.params.cores {
            match self.queue.pop() {
                Some(rt) => {
                    self.executing += 1;
                    self.recorder.exec_start(rt.task, now);
                    effects.push(Effect::StartExec { task: rt });
                }
                None => break,
            }
        }
    }

    /// A task finished executing on a local core after `duration` seconds.
    pub fn on_exec_complete(
        &mut self,
        rt: ReadyTask,
        output: Payload,
        duration: f64,
        now: f64,
        effects: &mut Vec<Effect>,
    ) {
        self.executing -= 1;
        let node = self.graph.task(rt.task);
        self.perf.record_exec(node.kind, duration);
        self.recorder.exec_end(rt.task, duration, now);
        self.last_completion = now;

        if rt.is_migrated(self.me) {
            // return the result to the origin; it publishes completion
            self.send(effects, rt.origin, Msg::ResultReturn { task: rt.task, payload: output });
        } else {
            self.store.insert(node.output, output);
            self.publish_completion(rt.task, now, effects);
        }
        self.record_trace(now);
        self.maybe_exec(now, effects);
        self.dlb_poll(now, effects);
    }

    /// Local bookkeeping + dependent notification after task `t` (homed
    /// here) has a result available locally.
    fn publish_completion(&mut self, t: TaskId, now: f64, effects: &mut Vec<Effect>) {
        let graph = Arc::clone(&self.graph);
        let node = graph.task(t);
        debug_assert_eq!(node.placement, self.me);
        self.owned_remaining -= 1;

        // Group dependents by placement; attach the output payload when the
        // destination actually reads it (RAW), else a pure notification.
        // Fan-out is small (≤ a handful of processes), so a linear-scan vec
        // beats a BTreeMap on this hot path (§Perf).
        let mut remote: Vec<(ProcessId, bool)> = Vec::new();
        for &d in &node.dependents {
            let dn = graph.task(d);
            if dn.placement == self.me {
                self.satisfy_dep(d, now, effects);
            } else {
                let reads = dn.args.contains(&node.output);
                match remote.iter_mut().find(|(q, _)| *q == dn.placement) {
                    Some((_, r)) => *r |= reads,
                    None => remote.push((dn.placement, reads)),
                }
            }
        }
        for (q, reads) in remote {
            let payload = if reads {
                self.store.get(node.output).cloned().unwrap_or(Payload::Sim)
            } else {
                Payload::None
            };
            self.send(effects, q, Msg::TaskDone { task: t, data: node.output, payload });
        }
        self.maybe_report_done(now, effects);
    }

    fn satisfy_dep(&mut self, task: TaskId, now: f64, effects: &mut Vec<Effect>) {
        let p = &mut self.pending_deps[task.idx()];
        debug_assert!(*p > 0, "dependency underflow for {task}");
        *p -= 1;
        if *p == 0 {
            self.queue.push(ReadyTask::home(task, self.me));
            self.recorder.task_ready(task, now);
            self.record_trace(now);
            self.maybe_exec(now, effects);
        }
    }

    fn maybe_report_done(&mut self, now: f64, effects: &mut Vec<Effect>) {
        if self.owned_remaining == 0 && !self.reported_done {
            self.reported_done = true;
            if self.me == ProcessId(0) {
                self.on_owner_done(now, effects);
            } else {
                self.send(effects, ProcessId(0), Msg::OwnerDone { proc: self.me });
            }
        }
    }

    fn on_owner_done(&mut self, now: f64, effects: &mut Vec<Effect>) {
        debug_assert_eq!(self.me, ProcessId(0));
        self.owners_done += 1;
        if self.owners_done == self.num_processes {
            for q in 0..self.num_processes {
                if q != 0 {
                    self.send(effects, ProcessId(q as u32), Msg::Shutdown);
                }
            }
            self.halted = true;
            self.recorder.run_end(now);
            effects.push(Effect::Halt);
        }
    }

    // ------------------------------------------------------------------
    // messages
    // ------------------------------------------------------------------

    pub fn on_message(&mut self, env: Envelope, now: f64, effects: &mut Vec<Effect>) {
        if self.halted {
            return;
        }
        let from = env.from;
        match env.msg {
            Msg::DataSend { data, payload } => {
                if !matches!(payload, Payload::None) {
                    self.store.insert(data, payload);
                }
                let waiters = std::mem::take(&mut self.v0_waiting[data.idx()]);
                for t in waiters {
                    self.satisfy_dep(t, now, effects);
                }
            }
            Msg::TaskDone { task, data, payload } => {
                if !matches!(payload, Payload::None) {
                    self.store.insert(data, payload);
                }
                let graph = Arc::clone(&self.graph);
                for &d in &graph.task(task).dependents {
                    if graph.task(d).placement == self.me {
                        self.satisfy_dep(d, now, effects);
                    }
                }
            }
            Msg::ResultReturn { task, payload } => {
                let was_exported = std::mem::replace(&mut self.exported[task.idx()], false);
                debug_assert!(was_exported, "unexpected return of {task}");
                let out = self.graph.task(task).output;
                if !matches!(payload, Payload::None) {
                    self.store.insert(out, payload);
                }
                self.recorder.result_returned(task, now);
                self.last_completion = now;
                self.publish_completion(task, now, effects);
            }

            Msg::TaskExport { round, tasks } => {
                let n = tasks.len();
                for mt in tasks {
                    for (d, p) in mt.inputs {
                        if !matches!(p, Payload::None) {
                            self.store.insert(d, p);
                        }
                    }
                    // origin is the task's home (not necessarily `from`:
                    // tasks may propagate through intermediaries, §7)
                    self.queue.push(ReadyTask { task: mt.task, origin: mt.origin });
                    self.recorder.migrated_in(mt.task, from, now);
                    self.recorder.task_ready(mt.task, now);
                }
                self.recorder.round_granted(round, n, now);
                self.policy.counters_mut().tasks_received += n as u64;
                self.send(effects, from, Msg::ExportAck { round, accepted: n });
                self.drive_policy(PolicyEvent::Transfer { from, round, received: n }, now, effects);
                self.record_trace(now);
                self.maybe_exec(now, effects);
            }

            Msg::OwnerDone { .. } => {
                self.on_owner_done(now, effects);
            }
            Msg::Shutdown => {
                self.halted = true;
                self.recorder.run_end(now);
                effects.push(Effect::Halt);
            }

            // Every remaining DLB control-plane message belongs to the
            // balancer policy (pairing handshake, steal requests, load
            // reports, export acks).
            other => {
                debug_assert!(other.is_dlb(), "unhandled non-DLB message {other:?}");
                self.recorder.protocol_recv(&other, from, now);
                self.drive_policy(PolicyEvent::Message { from, msg: &other }, now, effects);
            }
        }
        if !self.halted {
            self.dlb_poll(now, effects);
        }
    }

    /// Build the policy's observation once, dispatch one event to it, and
    /// interpret the resulting actions.  The single construction site for
    /// the `PolicyObs` split borrow.
    fn drive_policy(&mut self, ev: PolicyEvent<'_>, now: f64, effects: &mut Vec<Effect>) {
        let workload = self.queue.workload();
        let role = self.role();
        let middle_zone = self.in_middle_zone();
        let pinned = self.role_override.is_some();
        let mut actions: Vec<PolicyAction> = Vec::new();
        {
            let mut obs = PolicyObs {
                me: self.me,
                num_processes: self.num_processes,
                workload,
                role,
                middle_zone,
                pinned,
                wt: self.params.wt,
                neighbors: &self.neighbors,
                queue: &self.queue,
                graph: &self.graph,
                perf: &self.perf,
                rng: &mut self.rng,
            };
            match ev {
                PolicyEvent::Poll => self.policy.poll(&mut obs, now, &mut actions),
                PolicyEvent::Message { from, msg } => {
                    self.policy.on_message(&mut obs, from, msg, now, &mut actions);
                }
                PolicyEvent::Transfer { from, round, received } => {
                    self.policy.on_transfer(&mut obs, from, round, received, now, &mut actions);
                }
            }
        }
        self.apply_policy_actions(actions, now, effects);
    }

    /// Interpret what the policy asked for.
    fn apply_policy_actions(
        &mut self,
        actions: Vec<PolicyAction>,
        now: f64,
        effects: &mut Vec<Effect>,
    ) {
        for a in actions {
            match a {
                PolicyAction::Send { to, msg } => {
                    // observe *after* the policy decided — the recorder sits
                    // strictly downstream of the RNG
                    self.recorder.protocol_send(&msg, to, now);
                    self.send(effects, to, msg);
                }
                PolicyAction::ExportSelected { to, round, partner } => {
                    self.export_selected(to, round, partner, now, effects);
                }
                PolicyAction::ExportCount { to, round, count } => {
                    self.export_count(to, round, count, now, effects);
                }
            }
        }
    }

    /// Run the configured export strategy and ship the selection.
    fn export_selected(
        &mut self,
        partner: ProcessId,
        round: u64,
        info: PartnerInfo,
        now: f64,
        effects: &mut Vec<Effect>,
    ) {
        let graph = Arc::clone(&self.graph);
        let picked = select_exports(
            self.params.strategy,
            self.me,
            &mut self.queue,
            &graph,
            self.params.wt,
            info,
            &self.perf,
        );
        self.ship_tasks(partner, round, picked, now, effects);
    }

    /// Ship exactly `count` tasks from the queue back, capped so the local
    /// queue never drops below W_T (the shared invariant of §3).  Ships an
    /// empty `TaskExport` when nothing can leave — protocol completion for
    /// policies whose peer is blocked on a reply (work stealing).
    fn export_count(
        &mut self,
        partner: ProcessId,
        round: u64,
        count: usize,
        now: f64,
        effects: &mut Vec<Effect>,
    ) {
        let cap = self.queue.workload().saturating_sub(self.params.wt);
        let picked = self.queue.drain_back(count.min(cap), |_| true);
        self.ship_tasks(partner, round, picked, now, effects);
    }

    /// Common export mechanics: gather inputs, count, frame, send.
    fn ship_tasks(
        &mut self,
        partner: ProcessId,
        round: u64,
        picked: Vec<ReadyTask>,
        now: f64,
        effects: &mut Vec<Effect>,
    ) {
        let graph = Arc::clone(&self.graph);
        if picked.is_empty() {
            self.policy.counters_mut().empty_transactions += 1;
        }
        let mut migrated = Vec::with_capacity(picked.len());
        for rt in &picked {
            let node = graph.task(rt.task);
            if rt.origin == self.me {
                // our own task leaves: expect a ResultReturn for it
                self.exported[rt.task.idx()] = true;
            }
            self.recorder.migrated_out(rt.task, partner, now);
            let inputs: Vec<(DataId, Payload)> = node
                .args
                .iter()
                .map(|&a| (a, self.store.get(a).cloned().unwrap_or(Payload::Sim)))
                .collect();
            self.policy.counters_mut().migration_doubles += node.migration_doubles();
            migrated.push(MigratedTask { task: rt.task, origin: rt.origin, inputs });
        }
        self.policy.counters_mut().tasks_exported += picked.len() as u64;
        // Locality accounting: tasks that leave the cluster node / adjacency
        // shell (> 1 hop) are the migrations locality-aware policies exist
        // to avoid.
        if !picked.is_empty() && self.params.topology.hops(self.me, partner) > 1 {
            self.policy.counters_mut().tasks_exported_remote += picked.len() as u64;
        }
        self.send(effects, partner, Msg::TaskExport { round, tasks: migrated });
        self.record_trace(now);
    }

    // ------------------------------------------------------------------
    // timers / DLB driving
    // ------------------------------------------------------------------

    pub fn on_tick(&mut self, now: f64, effects: &mut Vec<Effect>) {
        if self.halted {
            return;
        }
        self.policy.on_tick(now, &mut self.rng);
        self.dlb_poll(now, effects);
    }

    /// Give the policy a chance to act and schedule the next wakeup.
    fn dlb_poll(&mut self, now: f64, effects: &mut Vec<Effect>) {
        if !self.params.dlb_enabled || self.halted {
            return;
        }
        self.drive_policy(PolicyEvent::Poll, now, effects);
        if let Some(at) = self.policy.next_wakeup() {
            let at = if at <= now { now + self.params.pairing.delta.max(1e-4) } else { at };
            effects.push(Effect::ScheduleTick { at });
        }
    }
}

/// One occasion to consult the balancer policy.
enum PolicyEvent<'m> {
    /// Timer tick / state change: chance to start a search or exchange.
    Poll,
    /// A DLB control-plane message arrived.
    Message { from: ProcessId, msg: &'m Msg },
    /// A `TaskExport` landed: `received` tasks already enqueued + acked.
    Transfer { from: ProcessId, round: u64, received: usize },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::graph::GraphBuilder;
    use crate::core::task::TaskKind;

    fn params(dlb: bool, wt: usize, gap: usize) -> ProcessParams {
        let mut cfg = Config::default();
        cfg.dlb_enabled = dlb;
        cfg.wt = wt;
        cfg.wt_gap = gap;
        ProcessParams::from_config(&cfg)
    }

    /// n independent tasks homed on p0, 2-process world.
    fn bag_state(n: usize, dlb: bool, wt: usize, gap: usize) -> ProcessState {
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            let d = b.data(ProcessId(0), 8, 8);
            b.task(TaskKind::Synthetic, vec![], d, 1000, None);
        }
        ProcessState::new(ProcessId(0), 2, b.build(), params(dlb, wt, gap), 1)
    }

    fn envelope(from: u32, to: u32, msg: Msg) -> Envelope {
        Envelope { from: ProcessId(from), to: ProcessId(to), msg, wire_doubles: 8 }
    }

    // Scratch-buffer wrappers: tests read effects as a returned Vec.
    fn run_start(ps: &mut ProcessState) -> Vec<Effect> {
        let mut fx = Vec::new();
        ps.start(0.0, &mut fx);
        fx
    }

    fn deliver(ps: &mut ProcessState, env: Envelope, now: f64) -> Vec<Effect> {
        let mut fx = Vec::new();
        ps.on_message(env, now, &mut fx);
        fx
    }

    fn tick(ps: &mut ProcessState, now: f64) -> Vec<Effect> {
        let mut fx = Vec::new();
        ps.on_tick(now, &mut fx);
        fx
    }

    fn exec_done(ps: &mut ProcessState, rt: ReadyTask, duration: f64, now: f64) -> Vec<Effect> {
        let mut fx = Vec::new();
        ps.on_exec_complete(rt, Payload::Sim, duration, now, &mut fx);
        fx
    }

    #[test]
    fn start_enqueues_ready_tasks_and_starts_cores() {
        let mut ps = bag_state(5, false, 2, 0);
        let effects = run_start(&mut ps);
        // 1 core → exactly one StartExec; 4 remain queued
        let execs = effects.iter().filter(|e| matches!(e, Effect::StartExec { .. })).count();
        assert_eq!(execs, 1);
        assert_eq!(ps.workload(), 4);
    }

    #[test]
    fn role_thresholds_with_and_without_gap() {
        let mut ps = bag_state(8, true, 3, 0);
        let _ = run_start(&mut ps);
        assert_eq!(ps.workload(), 7);
        assert_eq!(ps.role(), Role::Busy);
        assert!(!ps.in_middle_zone());

        // same queue with a gap of 10: w = 7 ≤ 3 + 10 → idle-ish middle zone
        let mut ps = bag_state(8, true, 3, 10);
        let _ = run_start(&mut ps);
        assert_eq!(ps.role(), Role::Idle);
        assert!(ps.in_middle_zone());
    }

    #[test]
    fn middle_zone_declines_requests() {
        let mut ps = bag_state(8, true, 3, 10); // w = 7: middle zone
        let _ = run_start(&mut ps);
        let effects = deliver(
            &mut ps,
            envelope(1, 0, Msg::PairRequest { round: 9, role: Role::Idle, load: 0, eta: 0.0 }),
            0.001,
        );
        let declined = effects.iter().any(|e| {
            matches!(e, Effect::Send(env) if matches!(env.msg, Msg::PairDecline { round: 9 }))
        });
        assert!(declined, "middle-zone process must decline: {effects:?}");
    }

    #[test]
    fn busy_process_accepts_idle_request_and_exports() {
        let mut ps = bag_state(10, true, 2, 0); // w = 9 > 2: busy
        let _ = run_start(&mut ps);
        let effects = deliver(
            &mut ps,
            envelope(1, 0, Msg::PairRequest { round: 1, role: Role::Idle, load: 0, eta: 0.0 }),
            0.001,
        );
        assert!(effects.iter().any(|e| {
            matches!(e, Effect::Send(env) if matches!(env.msg, Msg::PairAccept { .. }))
        }));
        // idle requester confirms → busy side ships the excess
        let effects = deliver(
            &mut ps,
            envelope(1, 0, Msg::PairConfirm { round: 1, load: 0, eta: 0.0 }),
            0.002,
        );
        let exported = effects.iter().find_map(|e| match e {
            Effect::Send(env) => match &env.msg {
                Msg::TaskExport { tasks, .. } => Some(tasks.len()),
                _ => None,
            },
            _ => None,
        });
        assert_eq!(exported, Some(7), "basic: w−W_T = 9−2 tasks leave");
        assert_eq!(ps.workload(), 2);
        // idle side acks → transaction closes, counters recorded
        let _ = deliver(&mut ps, envelope(1, 0, Msg::ExportAck { round: 1, accepted: 7 }), 0.003);
        assert!(!ps.policy.engaged());
        assert_eq!(ps.counters().tasks_exported, 7);
    }

    /// Payload ownership rule: shipping a task's inputs in a `TaskExport`
    /// aliases the store's `Arc` blocks — no deep copy on export — and the
    /// exporter can still read its local copy afterwards (a concurrent
    /// local consumer of the same version must keep working).
    #[test]
    fn exported_task_inputs_alias_the_store_blocks() {
        let mut b = GraphBuilder::new();
        let shared = b.data(ProcessId(0), 8, 8); // producer-less v0 input
        for _ in 0..10 {
            let d = b.data(ProcessId(0), 8, 8);
            b.task(TaskKind::Synthetic, vec![shared], d, 1000, None);
        }
        let mut ps = ProcessState::new(ProcessId(0), 2, b.build(), params(true, 2, 0), 1);
        ps.store.insert(shared, Payload::real_from(vec![7.0; 64]));
        let _ = run_start(&mut ps);
        let _ = deliver(
            &mut ps,
            envelope(1, 0, Msg::PairRequest { round: 1, role: Role::Idle, load: 0, eta: 0.0 }),
            0.001,
        );
        let effects = deliver(
            &mut ps,
            envelope(1, 0, Msg::PairConfirm { round: 1, load: 0, eta: 0.0 }),
            0.002,
        );
        let tasks = effects
            .iter()
            .find_map(|e| match e {
                Effect::Send(env) => match &env.msg {
                    Msg::TaskExport { tasks, .. } => Some(tasks),
                    _ => None,
                },
                _ => None,
            })
            .expect("busy side must export");
        let store_arc =
            ps.store.get(shared).and_then(|p| p.real_arc()).expect("block still local");
        let mut aliased = 0;
        for mt in tasks {
            for (d, p) in &mt.inputs {
                if *d == shared {
                    let sent = p.real_arc().expect("real input shipped");
                    assert!(Arc::ptr_eq(&sent, &store_arc), "export must alias, not copy");
                    aliased += 1;
                }
            }
        }
        assert!(aliased > 0, "exported tasks carry the shared input");
        // local read-through is unaffected by the export in flight
        assert_eq!(ps.store.get(shared).and_then(|p| p.real()), Some(&[7.0f32; 64][..]));
    }

    #[test]
    fn task_export_receipt_enqueues_migrated_tasks() {
        // p1's view: receives 2 tasks of p0's
        let mut b = GraphBuilder::new();
        let d0 = b.data(ProcessId(0), 8, 8);
        let t0 = b.task(TaskKind::Synthetic, vec![], d0, 1000, None);
        let d1 = b.data(ProcessId(0), 8, 8);
        let t1 = b.task(TaskKind::Synthetic, vec![], d1, 1000, None);
        let g = b.build();
        let mut ps = ProcessState::new(ProcessId(1), 2, g, params(true, 2, 0), 1);
        let _ = run_start(&mut ps);
        // fake an in-transaction state by receiving a request we accept
        let _ = deliver(
            &mut ps,
            envelope(0, 1, Msg::PairRequest { round: 4, role: Role::Busy, load: 9, eta: 1.0 }),
            0.001,
        );
        let effects = deliver(
            &mut ps,
            envelope(
                0,
                1,
                Msg::TaskExport {
                    round: 4,
                    tasks: vec![
                        MigratedTask { task: t0, origin: ProcessId(0), inputs: vec![] },
                        MigratedTask { task: t1, origin: ProcessId(0), inputs: vec![] },
                    ],
                },
            ),
            0.002,
        );
        // both enqueued; one starts executing on the single core
        assert_eq!(ps.counters().tasks_received, 2);
        assert!(effects.iter().any(|e| {
            matches!(e, Effect::Send(env) if matches!(env.msg, Msg::ExportAck { accepted: 2, .. }))
        }));
        assert!(effects.iter().any(|e| matches!(e, Effect::StartExec { .. })));
    }

    #[test]
    fn migrated_completion_returns_to_origin() {
        let mut b = GraphBuilder::new();
        let d0 = b.data(ProcessId(0), 8, 8);
        let t0 = b.task(TaskKind::Synthetic, vec![], d0, 1000, None);
        let g = b.build();
        let mut ps = ProcessState::new(ProcessId(1), 2, g, params(true, 2, 0), 1);
        let _ = run_start(&mut ps);
        let rt = ReadyTask { task: t0, origin: ProcessId(0) };
        ps.executing = 1; // as if the engine had started it
        let effects = exec_done(&mut ps, rt, 0.01, 0.5);
        let returned = effects.iter().any(|e| {
            matches!(e, Effect::Send(env)
                if env.to == ProcessId(0) && matches!(env.msg, Msg::ResultReturn { .. }))
        });
        assert!(returned, "thief must return the result to the origin");
    }

    #[test]
    fn dependency_chain_via_task_done() {
        // p1 owns a task depending on p0's output
        let mut b = GraphBuilder::new();
        let d0 = b.data(ProcessId(0), 8, 8);
        let t0 = b.task(TaskKind::Synthetic, vec![], d0, 1000, None);
        let d1 = b.data(ProcessId(1), 8, 8);
        let _t1 = b.task(TaskKind::Synthetic, vec![d0], d1, 1000, None);
        let g = b.build();
        let mut ps = ProcessState::new(ProcessId(1), 2, g, params(false, 2, 0), 1);
        let effects = run_start(&mut ps);
        assert!(effects.iter().all(|e| !matches!(e, Effect::StartExec { .. })), "not ready yet");
        let effects = deliver(
            &mut ps,
            envelope(0, 1, Msg::TaskDone { task: t0, data: d0, payload: Payload::Sim }),
            0.1,
        );
        assert!(
            effects.iter().any(|e| matches!(e, Effect::StartExec { .. })),
            "dependency satisfied → execute"
        );
    }

    #[test]
    fn owner_done_protocol_rank0_broadcasts_shutdown() {
        // p0 owns nothing → reports done at start; second OwnerDone closes
        let mut b = GraphBuilder::new();
        let d = b.data(ProcessId(1), 8, 8);
        b.task(TaskKind::Synthetic, vec![], d, 1000, None);
        let g = b.build();
        let mut ps = ProcessState::new(ProcessId(0), 2, g, params(false, 2, 0), 1);
        let _ = run_start(&mut ps);
        assert!(!ps.halted);
        let effects = deliver(&mut ps, envelope(1, 0, Msg::OwnerDone { proc: ProcessId(1) }), 1.0);
        assert!(ps.halted);
        assert!(effects.iter().any(|e| {
            matches!(e, Effect::Send(env) if matches!(env.msg, Msg::Shutdown))
        }));
        assert!(effects.iter().any(|e| matches!(e, Effect::Halt)));
    }

    #[test]
    fn halted_process_ignores_messages() {
        let mut ps = bag_state(1, true, 2, 0);
        let _ = run_start(&mut ps);
        ps.halted = true;
        let effects = deliver(
            &mut ps,
            envelope(1, 0, Msg::PairRequest { round: 1, role: Role::Idle, load: 0, eta: 0.0 }),
            0.1,
        );
        assert!(effects.is_empty());
    }

    #[test]
    fn dlb_disabled_never_searches() {
        let mut ps = bag_state(20, false, 2, 0);
        let effects = run_start(&mut ps);
        assert!(effects.iter().all(|e| !matches!(e, Effect::ScheduleTick { .. })));
        let effects = tick(&mut ps, 1.0);
        assert!(effects
            .iter()
            .all(|e| !matches!(e, Effect::Send(env) if env.msg.is_dlb())));
    }

    /// Same bag as `bag_state`, but under a chosen policy.
    fn bag_state_policy(n: usize, wt: usize, policy: PolicyKind) -> ProcessState {
        let mut cfg = Config::default();
        cfg.dlb_enabled = true;
        cfg.wt = wt;
        cfg.policy = policy;
        let params = ProcessParams::from_config(&cfg);
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            let d = b.data(ProcessId(0), 8, 8);
            b.task(TaskKind::Synthetic, vec![], d, 1000, None);
        }
        ProcessState::new(ProcessId(0), 4, b.build(), params, 1)
    }

    #[test]
    fn steal_request_on_busy_process_exports_half_excess() {
        let mut ps = bag_state_policy(11, 2, PolicyKind::WorkStealing);
        let _ = run_start(&mut ps);
        assert_eq!(ps.workload(), 10); // one executing
        // idle thief p1 asks: excess = 8 → steal-half = 4
        let effects = deliver(
            &mut ps,
            envelope(1, 0, Msg::StealRequest { round: 5, load: 0, eta: 0.0 }),
            0.001,
        );
        let exported = effects.iter().find_map(|e| match e {
            Effect::Send(env) => match &env.msg {
                Msg::TaskExport { round, tasks } => Some((*round, tasks.len())),
                _ => None,
            },
            _ => None,
        });
        assert_eq!(exported, Some((5, 4)), "steal-half of the excess: {effects:?}");
        assert_eq!(ps.workload(), 6);
        assert_eq!(ps.counters().tasks_exported, 4);
    }

    #[test]
    fn steal_request_on_idle_process_gets_empty_export() {
        let mut ps = bag_state_policy(2, 2, PolicyKind::WorkStealing);
        let _ = run_start(&mut ps);
        assert_eq!(ps.workload(), 1); // idle
        let effects = deliver(
            &mut ps,
            envelope(1, 0, Msg::StealRequest { round: 3, load: 0, eta: 0.0 }),
            0.001,
        );
        let exported = effects.iter().find_map(|e| match e {
            Effect::Send(env) => match &env.msg {
                Msg::TaskExport { tasks, .. } => Some(tasks.len()),
                _ => None,
            },
            _ => None,
        });
        assert_eq!(exported, Some(0), "denied steal still replies: {effects:?}");
        assert_eq!(ps.workload(), 1, "nothing actually left");
    }

    #[test]
    fn diffusion_reports_load_and_flows_to_lighter_neighbor() {
        let mut ps = bag_state_policy(13, 2, PolicyKind::Diffusion);
        let _ = run_start(&mut ps);
        assert_eq!(ps.workload(), 12);
        // first exchange (report-only: no neighbor loads known yet) — the
        // staggered start is < δ = 10 ms, so a 1 s tick certainly fires it
        let effects = tick(&mut ps, 1.0);
        let reports = effects
            .iter()
            .filter(|e| {
                matches!(e, Effect::Send(env) if matches!(env.msg, Msg::LoadReport { load: 12 }))
            })
            .count();
        assert_eq!(reports, 3, "one report per flat-topology neighbor: {effects:?}");
        assert_eq!(ps.workload(), 12, "no flow without neighbor data");
        // p1 reports empty right after (jitter keeps the next exchange
        // ≥ 0.75δ away, so this cannot race it) …
        let _ = deliver(&mut ps, envelope(1, 0, Msg::LoadReport { load: 0 }), 1.001);
        // … and the next period flows α·(12−0) = ⌊12/4⌋ = 3 tasks to p1
        let effects = tick(&mut ps, 2.0);
        let flowed = effects.iter().find_map(|e| match e {
            Effect::Send(env) => match &env.msg {
                Msg::TaskExport { tasks, .. } if env.to == ProcessId(1) => Some(tasks.len()),
                _ => None,
            },
            _ => None,
        });
        assert_eq!(flowed, Some(3), "flow down the gradient: {effects:?}");
        assert_eq!(ps.workload(), 9);
        assert_eq!(ps.counters().tasks_exported, 3);
    }

    #[test]
    fn remote_exports_counted_by_hop_distance() {
        // 2 nodes × 2 ranks: p1 shares p0's node, p2/p3 are across the wire
        let mut cfg = Config::default();
        cfg.dlb_enabled = true;
        cfg.wt = 2;
        cfg.policy = PolicyKind::WorkStealing;
        cfg.processes = 4;
        cfg.topology = crate::config::TopologyKind::Cluster;
        cfg.cluster_nodes = 2;
        cfg.validate().expect("valid");
        let params = ProcessParams::from_config(&cfg);
        let mut b = GraphBuilder::new();
        for _ in 0..13 {
            let d = b.data(ProcessId(0), 8, 8);
            b.task(TaskKind::Synthetic, vec![], d, 1000, None);
        }
        let mut ps = ProcessState::new(ProcessId(0), 4, b.build(), params, 1);
        let _ = run_start(&mut ps);
        // an intra-node steal (p1) migrates tasks but nothing "remote"
        let _ = deliver(
            &mut ps,
            envelope(1, 0, Msg::StealRequest { round: 1, load: 0, eta: 0.0 }),
            0.001,
        );
        let after_local = ps.counters().tasks_exported;
        assert!(after_local > 0, "local steal must export");
        assert_eq!(ps.counters().tasks_exported_remote, 0, "same node = not remote");
        // an inter-node steal (p2) counts toward the remote tally
        let _ = deliver(
            &mut ps,
            envelope(2, 0, Msg::StealRequest { round: 2, load: 0, eta: 0.0 }),
            0.002,
        );
        let remote = ps.counters().tasks_exported_remote;
        assert!(remote > 0, "cross-node steal must count as remote");
        assert_eq!(ps.counters().tasks_exported, after_local + remote);
    }

    #[test]
    fn all_policies_schedule_wakeups_from_start() {
        for policy in PolicyKind::ALL {
            let mut ps = bag_state_policy(6, 2, policy);
            let effects = run_start(&mut ps);
            assert!(
                effects.iter().any(|e| matches!(e, Effect::ScheduleTick { .. })),
                "{policy} must arm its timer"
            );
        }
    }

    #[test]
    fn recorder_captures_protocol_and_task_events_only_when_armed() {
        use crate::metrics::recorder::{RoundOutcome, TraceEvent};
        // default params: recorder off, hooks are no-ops
        let mut ps = bag_state(10, true, 2, 0);
        let _ = run_start(&mut ps);
        assert!(!ps.recorder.is_on());
        assert!(ps.recorder.events().is_empty());

        // armed: the busy-side accept → confirm → export → ack flow leaves
        // a round span plus task events
        let mut cfg = Config::default();
        cfg.dlb_enabled = true;
        cfg.wt = 2;
        cfg.trace_enabled = true;
        let params = ProcessParams::from_config(&cfg);
        let mut b = GraphBuilder::new();
        for _ in 0..10 {
            let d = b.data(ProcessId(0), 8, 8);
            b.task(TaskKind::Synthetic, vec![], d, 1000, None);
        }
        let mut ps = ProcessState::new(ProcessId(0), 2, b.build(), params, 1);
        let _ = run_start(&mut ps);
        assert!(ps.recorder.is_on());
        let ready = ps
            .recorder
            .events()
            .iter()
            .filter(|e| matches!(e, TraceEvent::TaskReady { .. }))
            .count();
        assert_eq!(ready, 10, "every start-ready task must be recorded");
        assert!(ps
            .recorder
            .events()
            .iter()
            .any(|e| matches!(e, TraceEvent::ExecStart { .. })));

        let _ = deliver(
            &mut ps,
            envelope(1, 0, Msg::PairRequest { round: 1, role: Role::Idle, load: 0, eta: 0.0 }),
            0.001,
        );
        let _ = deliver(
            &mut ps,
            envelope(1, 0, Msg::PairConfirm { round: 1, load: 0, eta: 0.0 }),
            0.002,
        );
        let _ = deliver(&mut ps, envelope(1, 0, Msg::ExportAck { round: 1, accepted: 7 }), 0.003);
        let evs = ps.recorder.events();
        let migrated = evs.iter().filter(|e| matches!(e, TraceEvent::MigratedOut { .. })).count();
        assert_eq!(migrated, 7, "the shipped excess must be recorded per task");
        // this process answered the search; if it also opened its own busy
        // round it must close as Granted on the ack
        if let Some(TraceEvent::RoundEnd { outcome, tasks, .. }) =
            evs.iter().find(|e| matches!(e, TraceEvent::RoundEnd { .. }))
        {
            assert_eq!(*outcome, RoundOutcome::Granted);
            assert_eq!(*tasks, 7);
        }
        // append order keeps per-process streams time-monotone
        let times: Vec<f64> = evs.iter().map(TraceEvent::time).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "{times:?}");
    }

    #[test]
    fn local_completion_publishes_and_reports_done() {
        let mut ps = bag_state(1, false, 2, 0);
        let effects = run_start(&mut ps);
        assert_eq!(effects.iter().filter(|e| matches!(e, Effect::StartExec { .. })).count(), 1);
        let rt = ReadyTask::home(TaskId(0), ProcessId(0));
        let effects = exec_done(&mut ps, rt, 0.001, 0.1);
        // sole task complete; rank 0 owns everything and p1 owns none…
        // p1 reports at its own start, so here p0 halts only after that
        // message. At minimum the task is recorded done locally:
        assert!(ps.tasks_done());
        let _ = effects;
    }
}
