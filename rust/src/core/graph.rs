//! Task-graph construction with sequential-task-flow (STF) dependency
//! inference — the DuctTeip/SuperGlue data-versioning model.
//!
//! The application submits tasks in program order, declaring which handles
//! each task reads and which single handle it writes.  The builder tracks a
//! version counter and the reader set per handle and derives:
//!
//! - **RAW** edges: reader depends on the producer of the version it reads;
//! - **WAR** edges: a writer depends on all readers of the previous version
//!   (this is what makes the single-buffer-per-handle `DataStore` safe, and
//!   it is exactly the paper's dashed "any order but not simultaneously"
//!   constraint between updates of one block in Fig 2);
//! - **WAW** edges: a writer depends on the previous writer.
//!
//! The result is an immutable `TaskGraph` shared (`Arc`) by every process.

use std::sync::Arc;

use super::data::DataMeta;
use super::ids::{DataId, ProcessId, TaskId};
use super::task::{TaskKind, TaskNode};

/// Immutable, validated task graph plus data metadata.
#[derive(Debug)]
pub struct TaskGraph {
    pub tasks: Vec<TaskNode>,
    pub data: Vec<DataMeta>,
    /// Task ids grouped by placement, in submission order — `tasks_of`
    /// reads this instead of scanning every task.
    tasks_by_proc: Vec<Vec<TaskId>>,
    /// Per home process: the `(consumer, handle)` pairs of version-0 data
    /// it must push at startup (sorted, deduplicated).  Precomputed once so
    /// process start is O(own tasks), not O(all tasks).
    v0_exports: Vec<Vec<(ProcessId, DataId)>>,
}

impl TaskGraph {
    pub fn task(&self, id: TaskId) -> &TaskNode {
        &self.tasks[id.idx()]
    }

    pub fn meta(&self, id: DataId) -> &DataMeta {
        &self.data[id.idx()]
    }

    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Tasks placed on `p` (owner-computes homes) — a precomputed index
    /// lookup, not a scan over all tasks.
    pub fn tasks_of(&self, p: ProcessId) -> impl Iterator<Item = &TaskNode> {
        self.tasks_by_proc
            .get(p.idx())
            .map(Vec::as_slice)
            .unwrap_or(&[])
            .iter()
            .map(|id| &self.tasks[id.idx()])
    }

    /// The startup pushes of version-0 data homed at `home`: each remote
    /// consumer process paired with the handle it needs, sorted and
    /// deduplicated (one `DataSend` per pair).
    pub fn v0_exports(&self, home: ProcessId) -> &[(ProcessId, DataId)] {
        self.v0_exports.get(home.idx()).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Total flops over all tasks (for utilization/roofline accounting).
    pub fn total_flops(&self) -> u64 {
        self.tasks.iter().map(|t| t.flops).sum()
    }

    /// Verify acyclicity and intra-bounds; returns a topological order.
    /// Panics in tests only — callers get a Result.
    pub fn topo_order(&self) -> Result<Vec<TaskId>, String> {
        let n = self.tasks.len();
        let mut indeg: Vec<usize> = self.tasks.iter().map(|t| t.deps.len()).collect();
        let mut stack: Vec<TaskId> =
            (0..n).filter(|&i| indeg[i] == 0).map(|i| TaskId(i as u32)).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(t) = stack.pop() {
            order.push(t);
            for &d in &self.tasks[t.idx()].dependents {
                indeg[d.idx()] -= 1;
                if indeg[d.idx()] == 0 {
                    stack.push(d);
                }
            }
        }
        if order.len() != n {
            return Err(format!("cycle: only {} of {} tasks orderable", order.len(), n));
        }
        Ok(order)
    }

    /// The critical-path length in flops (longest path; a lower bound on
    /// makespan·S regardless of P — used by experiment reports).
    pub fn critical_path_flops(&self) -> u64 {
        let order = self.topo_order().expect("acyclic");
        let mut dist = vec![0u64; self.tasks.len()];
        let mut best = 0;
        for t in order {
            let node = &self.tasks[t.idx()];
            let d = dist[t.idx()] + node.flops;
            best = best.max(d);
            for &dep in &node.dependents {
                dist[dep.idx()] = dist[dep.idx()].max(d);
            }
        }
        best
    }
}

/// Mutable builder with STF version tracking.
pub struct GraphBuilder {
    tasks: Vec<TaskNode>,
    data: Vec<DataMeta>,
    /// Per handle: the task that produced the current version (None = initial
    /// distribution) and the readers of the current version so far.
    last_writer: Vec<Option<TaskId>>,
    readers: Vec<Vec<TaskId>>,
}

impl GraphBuilder {
    pub fn new() -> Self {
        GraphBuilder { tasks: Vec::new(), data: Vec::new(), last_writer: Vec::new(), readers: Vec::new() }
    }

    /// Register a data handle hosted at `home`.
    pub fn data(&mut self, home: ProcessId, rows: usize, cols: usize) -> DataId {
        let id = DataId(self.data.len() as u32);
        self.data.push(DataMeta { id, home, rows, cols });
        self.last_writer.push(None);
        self.readers.push(Vec::new());
        id
    }

    /// Submit a task in program order.
    ///
    /// `args` are the kernel arguments (handles read); `output` is the handle
    /// written.  If `output` is also among `args` the task is read-modify-
    /// write (SYRK/GEMM trailing updates).  Placement defaults to the home of
    /// the output handle (owner computes) unless overridden.
    pub fn task(
        &mut self,
        kind: TaskKind,
        args: Vec<DataId>,
        output: DataId,
        flops: u64,
        placement: Option<ProcessId>,
    ) -> TaskId {
        let id = TaskId(self.tasks.len() as u32);
        let placement = placement.unwrap_or(self.data[output.idx()].home);
        let mut deps: Vec<TaskId> = Vec::new();
        let mut v0_args: Vec<DataId> = Vec::new();

        // RAW: depend on the producer of each argument's current version.
        for &a in &args {
            match self.last_writer[a.idx()] {
                Some(w) => deps.push(w),
                None => v0_args.push(a),
            }
            self.readers[a.idx()].push(id);
        }
        v0_args.sort_unstable();
        v0_args.dedup();
        // WAR: depend on all readers of the previous version of `output`
        // (excluding ourselves; we may read our own output handle).
        for &r in &self.readers[output.idx()] {
            if r != id {
                deps.push(r);
            }
        }
        // WAW: depend on the previous writer of `output`.
        if let Some(w) = self.last_writer[output.idx()] {
            deps.push(w);
        }
        deps.sort_unstable();
        deps.dedup();

        // Writing bumps the version: reset the reader set.
        self.last_writer[output.idx()] = Some(id);
        self.readers[output.idx()].clear();

        let in_doubles: u64 = args.iter().map(|a| self.data[a.idx()].elems() as u64).sum();
        let out_doubles = self.data[output.idx()].elems() as u64;
        let node = TaskNode {
            id,
            kind,
            placement,
            args,
            output,
            flops,
            in_doubles,
            out_doubles,
            deps,
            dependents: Vec::new(),
            v0_args,
        };
        self.tasks.push(node);
        id
    }

    /// Finalize: fill the dependents lists and freeze.
    pub fn build(mut self) -> Arc<TaskGraph> {
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); self.tasks.len()];
        for t in &self.tasks {
            for &d in &t.deps {
                dependents[d.idx()].push(t.id);
            }
        }
        for (t, deps) in self.tasks.iter_mut().zip(dependents) {
            t.dependents = deps;
        }

        // Per-process task index (placement buckets).
        let max_proc = self.tasks.iter().map(|t| t.placement.idx() + 1).max().unwrap_or(0);
        let mut tasks_by_proc: Vec<Vec<TaskId>> = vec![Vec::new(); max_proc];
        for t in &self.tasks {
            tasks_by_proc[t.placement.idx()].push(t.id);
        }

        // Startup v0 pushes, bucketed by the data's home process.  Sorted
        // (to, data) with duplicates removed — identical to the BTreeMap
        // the process start loop used to build, so send order (and thus
        // DES determinism) is unchanged.
        let max_home = self.data.iter().map(|d| d.home.idx() + 1).max().unwrap_or(0);
        let mut v0_exports: Vec<Vec<(ProcessId, DataId)>> = vec![Vec::new(); max_home];
        for t in &self.tasks {
            for &a in &t.v0_args {
                let home = self.data[a.idx()].home;
                if home != t.placement {
                    v0_exports[home.idx()].push((t.placement, a));
                }
            }
        }
        for v in &mut v0_exports {
            v.sort_unstable();
            v.dedup();
        }

        let g = TaskGraph { tasks: self.tasks, data: self.data, tasks_by_proc, v0_exports };
        debug_assert!(g.topo_order().is_ok());
        Arc::new(g)
    }
}

impl Default for GraphBuilder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(i: u32) -> ProcessId {
        ProcessId(i)
    }

    #[test]
    fn raw_dependency() {
        let mut b = GraphBuilder::new();
        let x = b.data(p(0), 4, 4);
        let y = b.data(p(1), 4, 4);
        let t0 = b.task(TaskKind::Synthetic, vec![], x, 10, None);
        let t1 = b.task(TaskKind::Synthetic, vec![x], y, 10, None);
        let g = b.build();
        assert_eq!(g.task(t1).deps, vec![t0]);
        assert_eq!(g.task(t0).dependents, vec![t1]);
        assert_eq!(g.task(t1).placement, p(1)); // owner computes
    }

    #[test]
    fn war_dependency_serializes_reader_then_writer() {
        let mut b = GraphBuilder::new();
        let x = b.data(p(0), 2, 2);
        let y = b.data(p(0), 2, 2);
        let r = b.task(TaskKind::Synthetic, vec![x], y, 1, None); // reads x@v0
        let w = b.task(TaskKind::Synthetic, vec![], x, 1, None); // writes x→v1
        let g = b.build();
        assert!(g.task(w).deps.contains(&r), "WAR edge reader→writer");
    }

    #[test]
    fn waw_dependency_chains_writers() {
        let mut b = GraphBuilder::new();
        let x = b.data(p(0), 2, 2);
        let w0 = b.task(TaskKind::Synthetic, vec![], x, 1, None);
        let w1 = b.task(TaskKind::Synthetic, vec![], x, 1, None);
        let g = b.build();
        assert!(g.task(w1).deps.contains(&w0));
    }

    #[test]
    fn rmw_task_does_not_self_depend() {
        let mut b = GraphBuilder::new();
        let c = b.data(p(0), 2, 2);
        let a = b.data(p(0), 2, 2);
        let t = b.task(TaskKind::Syrk, vec![c, a], c, 8, None);
        let g = b.build();
        assert!(!g.task(t).deps.contains(&t));
    }

    #[test]
    fn rmw_chain_serialized_in_order() {
        // gemm updates on the same block: must form a chain (paper's dashed
        // edges, "any order but not at the same time" — STF fixes the order).
        let mut b = GraphBuilder::new();
        let c = b.data(p(0), 2, 2);
        let a1 = b.data(p(0), 2, 2);
        let a2 = b.data(p(0), 2, 2);
        let u1 = b.task(TaskKind::Gemm, vec![c, a1], c, 16, None);
        let u2 = b.task(TaskKind::Gemm, vec![c, a2], c, 16, None);
        let g = b.build();
        assert!(g.task(u2).deps.contains(&u1));
    }

    #[test]
    fn doubles_accounting() {
        let mut b = GraphBuilder::new();
        let c = b.data(p(0), 4, 4);
        let x = b.data(p(0), 4, 4);
        let y = b.data(p(0), 4, 4);
        let t = b.task(TaskKind::Gemm, vec![c, x, y], c, 128, None);
        let g = b.build();
        assert_eq!(g.task(t).in_doubles, 48);
        assert_eq!(g.task(t).out_doubles, 16);
        assert_eq!(g.task(t).migration_doubles(), 64);
    }

    #[test]
    fn topo_order_covers_all() {
        let mut b = GraphBuilder::new();
        let xs: Vec<DataId> = (0..10).map(|_| b.data(p(0), 2, 2)).collect();
        for i in 1..10 {
            b.task(TaskKind::Synthetic, vec![xs[i - 1]], xs[i], 1, None);
        }
        let g = b.build();
        let order = g.topo_order().expect("acyclic");
        assert_eq!(order.len(), 9);
    }

    #[test]
    fn critical_path_of_chain_is_total() {
        let mut b = GraphBuilder::new();
        let xs: Vec<DataId> = (0..5).map(|_| b.data(p(0), 2, 2)).collect();
        for i in 1..5 {
            b.task(TaskKind::Synthetic, vec![xs[i - 1]], xs[i], 7, None);
        }
        let g = b.build();
        assert_eq!(g.critical_path_flops(), 28);
        assert_eq!(g.total_flops(), 28);
    }

    #[test]
    fn tasks_of_matches_placement_scan() {
        let mut b = GraphBuilder::new();
        for i in 0..9 {
            let x = b.data(p(i % 3), 2, 2);
            b.task(TaskKind::Synthetic, vec![], x, 1, None);
        }
        let g = b.build();
        for q in 0..4u32 {
            let fast: Vec<TaskId> = g.tasks_of(p(q)).map(|t| t.id).collect();
            let slow: Vec<TaskId> =
                g.tasks.iter().filter(|t| t.placement == p(q)).map(|t| t.id).collect();
            assert_eq!(fast, slow, "process {q}");
        }
    }

    #[test]
    fn v0_exports_cover_remote_consumers_sorted() {
        let mut b = GraphBuilder::new();
        let x = b.data(p(0), 2, 2); // v0 handle homed at p0
        let y = b.data(p(1), 2, 2);
        let z = b.data(p(2), 2, 2);
        // two remote consumers of x@v0, one local
        b.task(TaskKind::Synthetic, vec![x], y, 1, None); // p1 reads x
        b.task(TaskKind::Synthetic, vec![x], z, 1, None); // p2 reads x
        let w = b.data(p(0), 2, 2);
        b.task(TaskKind::Synthetic, vec![x], w, 1, None); // p0 reads x (local)
        let g = b.build();
        assert_eq!(g.v0_exports(p(0)), &[(p(1), x), (p(2), x)]);
        assert!(g.v0_exports(p(1)).is_empty());
        assert!(g.v0_exports(p(7)).is_empty(), "out-of-range home is empty");
    }

    #[test]
    fn independent_tasks_have_no_edges() {
        let mut b = GraphBuilder::new();
        for i in 0..4 {
            let x = b.data(p(i % 2), 2, 2);
            b.task(TaskKind::Synthetic, vec![], x, 1, None);
        }
        let g = b.build();
        assert!(g.tasks.iter().all(|t| t.deps.is_empty()));
        assert_eq!(g.critical_path_flops(), 1);
    }
}
