//! Task model: kinds, §4 cost attributes, and graph nodes.

use super::ids::{DataId, ProcessId, TaskId};

/// The task types of the block-Cholesky benchmark (paper Fig 2) plus the
/// §4 GEMV comparison task and a synthetic kind for workload generators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TaskKind {
    /// Factorize a diagonal block (F = b³/3).
    Potrf,
    /// Triangular solve of a panel block (F = b³).
    Trsm,
    /// Symmetric rank-b update of a diagonal block (F = b³ as implemented).
    Syrk,
    /// General trailing update (F = 2b³).
    Gemm,
    /// Matrix–vector product (F = 2b²) — the low-intensity §4 case.
    Gemv,
    /// Synthetic task with explicit cost attributes (workload generators).
    Synthetic,
}

impl TaskKind {
    pub const ALL: [TaskKind; 6] = [
        TaskKind::Potrf,
        TaskKind::Trsm,
        TaskKind::Syrk,
        TaskKind::Gemm,
        TaskKind::Gemv,
        TaskKind::Synthetic,
    ];

    /// Artifact name in `artifacts/manifest.txt` (None for synthetic tasks,
    /// which exist only in simulation).
    pub fn kernel_name(self) -> Option<&'static str> {
        match self {
            TaskKind::Potrf => Some("potrf"),
            TaskKind::Trsm => Some("trsm"),
            TaskKind::Syrk => Some("syrk"),
            TaskKind::Gemm => Some("gemm"),
            TaskKind::Gemv => Some("gemv"),
            TaskKind::Synthetic => None,
        }
    }

    /// Stable small index for per-kind tables.
    pub fn index(self) -> usize {
        match self {
            TaskKind::Potrf => 0,
            TaskKind::Trsm => 1,
            TaskKind::Syrk => 2,
            TaskKind::Gemm => 3,
            TaskKind::Gemv => 4,
            TaskKind::Synthetic => 5,
        }
    }

    /// LAPACK-convention flop count for a square block of order `b`
    /// (must agree with `python/compile/model.py::TaskSpec::flops`).
    pub fn flops_for_block(self, b: u64) -> u64 {
        match self {
            TaskKind::Potrf => b * b * b / 3,
            TaskKind::Trsm => b * b * b,
            TaskKind::Syrk => b * b * b,
            TaskKind::Gemm => 2 * b * b * b,
            TaskKind::Gemv => 2 * b * b,
            TaskKind::Synthetic => 0,
        }
    }
}

impl std::fmt::Display for TaskKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TaskKind::Potrf => "potrf",
            TaskKind::Trsm => "trsm",
            TaskKind::Syrk => "syrk",
            TaskKind::Gemm => "gemm",
            TaskKind::Gemv => "gemv",
            TaskKind::Synthetic => "synthetic",
        })
    }
}

/// One node of the immutable task graph.
///
/// The task reads `args` (kernel arguments, in artifact order — the output
/// block's *current* value is among them for read-modify-write kinds) and
/// writes `output`.  `flops`, `in_doubles` and `out_doubles` are the §4
/// F and D attributes: F flops, D = in + out doubles crossing the network on
/// migration.
#[derive(Debug, Clone)]
pub struct TaskNode {
    pub id: TaskId,
    pub kind: TaskKind,
    /// Owner-computes home process (from the data distribution).
    pub placement: ProcessId,
    /// Kernel arguments in execution order.
    pub args: Vec<DataId>,
    /// The handle whose value this task produces.
    pub output: DataId,
    pub flops: u64,
    pub in_doubles: u64,
    pub out_doubles: u64,
    /// Tasks that must complete before this one (RAW + WAR + WAW).
    pub deps: Vec<TaskId>,
    /// Inverse of `deps`.
    pub dependents: Vec<TaskId>,
    /// Distinct argument handles read at version 0 (no producing task):
    /// these come from the initial data distribution and must be pushed by
    /// their home process before this task can run remotely from it.
    pub v0_args: Vec<DataId>,
}

impl TaskNode {
    /// Total doubles crossing the network if this task runs remotely (paper
    /// §4's D: ship inputs, return output).
    pub fn migration_doubles(&self) -> u64 {
        self.in_doubles + self.out_doubles
    }

    /// Computational intensity F/D (higher ⇒ cheaper to migrate, §4).
    pub fn intensity(&self) -> f64 {
        self.flops as f64 / self.migration_doubles().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_indices_unique_and_dense() {
        let mut seen = [false; 6];
        for k in TaskKind::ALL {
            assert!(!seen[k.index()]);
            seen[k.index()] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn flops_match_python_model() {
        // mirror of python/tests/test_model.py::TestTaskSpecs
        assert_eq!(TaskKind::Gemm.flops_for_block(64), 2 * 64 * 64 * 64);
        assert_eq!(TaskKind::Potrf.flops_for_block(32), 32 * 32 * 32 / 3);
        assert_eq!(TaskKind::Gemv.flops_for_block(128), 2 * 128 * 128);
    }

    #[test]
    fn kernel_names_only_for_real_kinds() {
        assert_eq!(TaskKind::Synthetic.kernel_name(), None);
        for k in TaskKind::ALL {
            if k != TaskKind::Synthetic {
                assert!(k.kernel_name().is_some());
            }
        }
    }

    #[test]
    fn intensity_orders_gemm_above_gemv() {
        let gemm = TaskNode {
            id: TaskId(0),
            kind: TaskKind::Gemm,
            placement: ProcessId(0),
            args: vec![],
            output: DataId(0),
            flops: TaskKind::Gemm.flops_for_block(64),
            in_doubles: 3 * 64 * 64,
            out_doubles: 64 * 64,
            deps: vec![],
            dependents: vec![],
            v0_args: vec![],
        };
        let gemv = TaskNode {
            id: TaskId(1),
            kind: TaskKind::Gemv,
            placement: ProcessId(0),
            args: vec![],
            output: DataId(1),
            flops: TaskKind::Gemv.flops_for_block(64),
            in_doubles: 64 * 64 + 64,
            out_doubles: 64,
            deps: vec![],
            dependents: vec![],
            v0_args: vec![],
        };
        assert!(gemm.intensity() > 10.0 * gemv.intensity());
    }
}
