//! Scheduling primitives: the ready queue whose length is the paper's
//! workload measure w_i(t), and the shared worker-pool injector the
//! threaded runtime dispatches through.

pub mod injector;
pub mod queue;

pub use injector::Injector;
pub use queue::{ReadyQueue, ReadyTask};
