//! Scheduling primitives: the ready queue whose length is the paper's
//! workload measure w_i(t).

pub mod queue;

pub use queue::{ReadyQueue, ReadyTask};
