//! A shared FIFO work queue (injector) for the threaded runtime's worker
//! pool: one producer (the coordinator), many blocking consumers (workers).
//!
//! This replaces the earlier per-worker channels + round-robin dispatch.
//! Round-robin assigns a task to a worker at *dispatch* time, so a short
//! task could sit behind a long one on a busy worker's private channel
//! while a sibling idled — classic head-of-line blocking.  With a single
//! shared queue, assignment happens at *pop* time: whichever worker frees
//! up first takes the oldest waiting task, so an idle core can never wait
//! behind work it could have run (John et al. 2022's shared-queue executor
//! shape).
//!
//! Mutex + Condvar over a `VecDeque` is deliberate: the queue holds at most
//! a process's ready surplus (tens of entries), pops happen once per task
//! (milliseconds apart), and the consumers must *block*, not spin — a
//! Chase–Lev deque would buy contention throughput this path never needs.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

pub struct Injector<T> {
    state: Mutex<State<T>>,
    available: Condvar,
}

struct State<T> {
    queue: VecDeque<T>,
    closed: bool,
}

impl<T> Injector<T> {
    pub fn new() -> Self {
        Injector {
            state: Mutex::new(State { queue: VecDeque::new(), closed: false }),
            available: Condvar::new(),
        }
    }

    /// Enqueue one item and wake one waiting consumer.  Pushing after
    /// `close` is allowed and the item is still drained (the coordinator
    /// closes only after its event loop halts, so this path is unused, but
    /// the queue itself does not care).
    pub fn push(&self, item: T) {
        let mut s = self.state.lock().expect("injector poisoned");
        s.queue.push_back(item);
        drop(s);
        self.available.notify_one();
    }

    /// Dequeue the oldest item, blocking while the queue is empty and open.
    /// Returns `None` only when the queue is closed **and** drained — the
    /// consumer's signal to exit.
    pub fn pop_blocking(&self) -> Option<T> {
        let mut s = self.state.lock().expect("injector poisoned");
        loop {
            if let Some(item) = s.queue.pop_front() {
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.available.wait(s).expect("injector poisoned");
        }
    }

    /// Mark the queue closed and wake every consumer so they can drain the
    /// remainder and exit.
    pub fn close(&self) {
        let mut s = self.state.lock().expect("injector poisoned");
        s.closed = true;
        drop(s);
        self.available.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().expect("injector poisoned").queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Default for Injector<T> {
    fn default() -> Self {
        Injector::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    #[test]
    fn fifo_order_single_consumer() {
        let q = Injector::new();
        for i in 0..5 {
            q.push(i);
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop_blocking(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = Injector::new();
        q.push(1);
        q.push(2);
        q.close();
        // items pushed before close are not lost
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), Some(2));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn push_wakes_a_blocked_consumer() {
        let q = Arc::new(Injector::new());
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let t0 = Instant::now();
            let v = q2.pop_blocking();
            (v, t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(20));
        q.push(42usize);
        let (v, waited) = h.join().expect("join");
        assert_eq!(v, Some(42));
        assert!(waited >= Duration::from_millis(15), "was blocked, not spinning");
    }

    #[test]
    fn close_wakes_all_blocked_consumers() {
        let q: Arc<Injector<usize>> = Arc::new(Injector::new());
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || q.pop_blocking())
            })
            .collect();
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        for c in consumers {
            assert_eq!(c.join().expect("join"), None);
        }
    }

    #[test]
    fn multiple_consumers_partition_the_work() {
        let q = Arc::new(Injector::new());
        for i in 0..100usize {
            q.push(i);
        }
        q.close();
        let workers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop_blocking() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        let mut all: Vec<usize> =
            workers.into_iter().flat_map(|w| w.join().expect("join")).collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>(), "each item taken exactly once");
    }
}
