//! The per-process ready-task queue — the quantity the whole paper is about:
//! its length is the workload w_i(t).

use std::collections::VecDeque;

use crate::core::ids::{ProcessId, TaskId};

/// A runnable task instance: the graph node plus where it came from.
/// `origin == executor` for home tasks; for migrated tasks `origin` is the
/// process the result must be returned to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadyTask {
    pub task: TaskId,
    pub origin: ProcessId,
}

impl ReadyTask {
    pub fn home(task: TaskId, me: ProcessId) -> Self {
        ReadyTask { task, origin: me }
    }

    pub fn is_migrated(&self, me: ProcessId) -> bool {
        self.origin != me
    }
}

/// FIFO ready queue.  FIFO matches DuctTeip's default; the export strategies
/// take from the *back* so the oldest (most likely on the critical path)
/// tasks run locally first.
#[derive(Debug, Default)]
pub struct ReadyQueue {
    q: VecDeque<ReadyTask>,
}

impl ReadyQueue {
    pub fn new() -> Self {
        ReadyQueue { q: VecDeque::new() }
    }

    /// Current workload w_i(t).
    pub fn workload(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn push(&mut self, t: ReadyTask) {
        self.q.push_back(t);
    }

    /// Next task to execute (FIFO head).
    pub fn pop(&mut self) -> Option<ReadyTask> {
        self.q.pop_front()
    }

    /// Remove up to `k` tasks from the *back* that satisfy `pred`, in
    /// back-to-front order — the export-selection primitive shared by all
    /// three strategies.  Migrated tasks never satisfy the caller's
    /// predicate (no re-stealing churn).
    pub fn drain_back<F: FnMut(&ReadyTask) -> bool>(
        &mut self,
        k: usize,
        mut pred: F,
    ) -> Vec<ReadyTask> {
        let mut out = Vec::new();
        let mut kept: Vec<ReadyTask> = Vec::new();
        while out.len() < k {
            match self.q.pop_back() {
                None => break,
                Some(t) => {
                    if pred(&t) {
                        out.push(t);
                    } else {
                        kept.push(t);
                    }
                }
            }
        }
        // restore non-selected tasks in original order
        for t in kept.into_iter().rev() {
            self.q.push_back(t);
        }
        out
    }

    pub fn iter(&self) -> impl Iterator<Item = &ReadyTask> {
        self.q.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(i: u32) -> ReadyTask {
        ReadyTask { task: TaskId(i), origin: ProcessId(0) }
    }

    #[test]
    fn fifo_order() {
        let mut q = ReadyQueue::new();
        q.push(rt(1));
        q.push(rt(2));
        q.push(rt(3));
        assert_eq!(q.workload(), 3);
        assert_eq!(q.pop().expect("pop").task, TaskId(1));
        assert_eq!(q.pop().expect("pop").task, TaskId(2));
    }

    #[test]
    fn drain_back_takes_newest_first() {
        let mut q = ReadyQueue::new();
        for i in 0..5 {
            q.push(rt(i));
        }
        let got = q.drain_back(2, |_| true);
        assert_eq!(got.iter().map(|t| t.task.0).collect::<Vec<_>>(), vec![4, 3]);
        assert_eq!(q.workload(), 3);
        assert_eq!(q.pop().expect("pop").task, TaskId(0)); // order preserved
    }

    #[test]
    fn drain_back_respects_predicate_and_restores_order() {
        let mut q = ReadyQueue::new();
        for i in 0..6 {
            q.push(rt(i));
        }
        // only even ids exportable
        let got = q.drain_back(2, |t| t.task.0 % 2 == 0);
        assert_eq!(got.iter().map(|t| t.task.0).collect::<Vec<_>>(), vec![4, 2]);
        let rest: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|t| t.task.0).collect();
        assert_eq!(rest, vec![0, 1, 3, 5]);
    }

    #[test]
    fn drain_back_k_zero_is_noop() {
        let mut q = ReadyQueue::new();
        q.push(rt(0));
        assert!(q.drain_back(0, |_| true).is_empty());
        assert_eq!(q.workload(), 1);
    }

    #[test]
    fn migrated_flag() {
        let me = ProcessId(1);
        assert!(ReadyTask { task: TaskId(0), origin: ProcessId(2) }.is_migrated(me));
        assert!(!ReadyTask::home(TaskId(0), me).is_migrated(me));
    }
}
