//! # ductr — distributed dynamic load balancing for task-parallel programs
//!
//! A reproduction of Zafari & Larsson, *Distributed dynamic load balancing
//! for task parallel programming* (Uppsala University, 2018), built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)**: a DuctTeip-style distributed task runtime — STF
//!   dependency inference ([`core::graph`]), per-process ready queues, an
//!   owner-computes placement from block-cyclic data distribution, plus the
//!   paper's contribution: randomized idle–busy pairing ([`dlb::pairing`])
//!   with Basic/Equalizing/Smart task-export strategies ([`dlb::strategy`]).
//! - **L2/L1 (build time)**: the block-Cholesky task kernels, written as JAX
//!   + Pallas and AOT-lowered to HLO text (`python/compile/`), loaded and
//!   executed on the request path through PJRT ([`runtime`]).
//!
//! Two execution modes share the identical coordinator state machine
//! ([`core::process::ProcessState`]): a deterministic discrete-event
//! simulator ([`sim`]) for paper-scale experiments and a threaded real mode
//! ([`runtime::threaded`]) that computes actual numerics via PJRT.
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! paper-vs-measured record of every figure.

#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::field_reassign_with_default,
    clippy::manual_div_ceil
)]

pub mod apps;
pub mod cholesky;
pub mod cli;
pub mod config;
pub mod core;
pub mod dlb;
pub mod experiments;
pub mod metrics;
pub mod net;
pub mod prob;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;
