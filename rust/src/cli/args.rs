//! Lightweight CLI argument parser.
//!
//! Grammar: `ductr <subcommand> [--flag] [--key value] [--key=value] [pos..]`.
//! Typed getters consume recognized keys so `finish()` can reject typos —
//! the failure mode that silently ignores `--strateg smart` is the one we
//! must not have in an experiment driver.

use std::collections::BTreeMap;

#[derive(Debug)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for ArgError {}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, Vec<String>>,
    consumed: std::collections::BTreeSet<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I, S>(raw: I) -> Result<Args, ArgError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut it = raw.into_iter().map(Into::into).peekable();
        // first non-flag token is the subcommand
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` : everything after is positional
                    args.positional.extend(it.by_ref());
                    break;
                }
                let (key, val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if key.is_empty() {
                    return Err(ArgError(format!("malformed flag: {tok}")));
                }
                let val = match val {
                    Some(v) => v,
                    None => {
                        // take the next token as the value unless it looks
                        // like a flag — then this is a boolean switch
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => it.next().expect("peeked"),
                            _ => String::from("true"),
                        }
                    }
                };
                args.flags.entry(key).or_default().push(val);
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse from the live process arguments.
    pub fn from_env() -> Result<Args, ArgError> {
        Self::parse(std::env::args().skip(1))
    }

    fn take(&mut self, key: &str) -> Option<&str> {
        if self.flags.contains_key(key) {
            self.consumed.insert(key.to_string());
            self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
        } else {
            None
        }
    }

    /// String flag.
    pub fn get_str(&mut self, key: &str) -> Option<String> {
        self.take(key).map(|s| s.to_string())
    }

    /// All occurrences of a repeatable flag (e.g. `--set a=1 --set b=2`).
    pub fn get_all(&mut self, key: &str) -> Vec<String> {
        if self.flags.contains_key(key) {
            self.consumed.insert(key.to_string());
            self.flags.get(key).cloned().unwrap_or_default()
        } else {
            Vec::new()
        }
    }

    /// Boolean switch: `--foo`, `--foo=true/false`.
    pub fn get_bool(&mut self, key: &str) -> Result<bool, ArgError> {
        match self.take(key) {
            None => Ok(false),
            Some("true") | Some("1") | Some("yes") | Some("on") => Ok(true),
            Some("false") | Some("0") | Some("no") | Some("off") => Ok(false),
            Some(v) => Err(ArgError(format!("--{key}: expected bool, got {v}"))),
        }
    }

    pub fn get_usize(&mut self, key: &str) -> Result<Option<usize>, ArgError> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| ArgError(format!("--{key}: expected integer, got {v}"))),
        }
    }

    pub fn get_u64(&mut self, key: &str) -> Result<Option<u64>, ArgError> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| ArgError(format!("--{key}: expected integer, got {v}"))),
        }
    }

    pub fn get_f64(&mut self, key: &str) -> Result<Option<f64>, ArgError> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| ArgError(format!("--{key}: expected number, got {v}"))),
        }
    }

    /// Reject any flag that no getter consumed.
    pub fn finish(&self) -> Result<(), ArgError> {
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !self.consumed.contains(*k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(ArgError(format!(
                "unknown flag(s): {}",
                unknown.iter().map(|k| format!("--{k}")).collect::<Vec<_>>().join(", ")
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().copied()).expect("parse")
    }

    #[test]
    fn subcommand_and_flags() {
        let mut a = parse(&["run", "--mode", "sim", "--dlb", "--wt=5", "pos1"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get_str("mode").as_deref(), Some("sim"));
        assert!(a.get_bool("dlb").expect("bool"));
        assert_eq!(a.get_usize("wt").expect("usize"), Some(5));
        assert_eq!(a.positional, vec!["pos1"]);
        a.finish().expect("all consumed");
    }

    #[test]
    fn equals_and_space_forms_agree() {
        let mut a = parse(&["x", "--k=3"]);
        let mut b = parse(&["x", "--k", "3"]);
        assert_eq!(a.get_usize("k").expect("a"), b.get_usize("k").expect("b"));
    }

    #[test]
    fn bool_switch_before_flag() {
        let mut a = parse(&["x", "--verbose", "--n", "2"]);
        assert!(a.get_bool("verbose").expect("bool"));
        assert_eq!(a.get_usize("n").expect("n"), Some(2));
    }

    #[test]
    fn repeatable_flags() {
        let mut a = parse(&["x", "--set", "a=1", "--set", "b=2"]);
        assert_eq!(a.get_all("set"), vec!["a=1", "b=2"]);
    }

    #[test]
    fn last_occurrence_wins_for_scalar() {
        let mut a = parse(&["x", "--n", "1", "--n", "9"]);
        assert_eq!(a.get_usize("n").expect("n"), Some(9));
    }

    #[test]
    fn unknown_flag_rejected() {
        let mut a = parse(&["x", "--typo", "3"]);
        let _ = a.get_usize("correct");
        assert!(a.finish().is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["x", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn bad_number_reports_key() {
        let mut a = parse(&["x", "--n", "abc"]);
        let e = a.get_usize("n").expect_err("should fail");
        assert!(e.to_string().contains("--n"));
    }

    #[test]
    fn missing_returns_none() {
        let mut a = parse(&["x"]);
        assert_eq!(a.get_usize("nope").expect("ok"), None);
        assert!(!a.get_bool("flag").expect("ok"));
    }
}
