//! Command-line interface: a small argument parser (clap is unavailable
//! offline) plus the `ductr` subcommand surface.

pub mod args;

pub use args::{ArgError, Args};
