//! Subcommand dispatch for the `ductr` binary.

use ductr::util::error::{Context, Error, Result};
use ductr::{anyhow, bail};

use ductr::apps::{bag, gemv_chain, rand_dag};
use ductr::cholesky;
use ductr::cli::Args;
use ductr::config::{Config, Grid, Mode, PolicyKind, Strategy, Workload};
use ductr::core::task::TaskKind;
use ductr::dlb::threshold::calibrate_from_traces;
use ductr::experiments::{ablation, compare, fig1, fig3, fig4, fig5, sec4};
use ductr::metrics::counters::DlbCounters;
use ductr::metrics::trace::RunTraces;
use ductr::metrics::{chrome, csv, LatencyReport, RunTrace};
use ductr::runtime::{KernelLibrary, Manifest};
use std::sync::Arc;

const USAGE: &str = "\
ductr — distributed dynamic load balancing for task-parallel programs
(reproduction of Zafari & Larsson 2018)

USAGE:
    ductr <subcommand> [flags]

SUBCOMMANDS:
    run               run one workload (see flags below)
    compare           balancer shoot-out: policy × topology × adaptive-δ table
                      (--quick/--smoke for the reduced CI profile)
    bench             DES hot-path baseline: cholesky + random-DAG sweep over
                      P ∈ {16..65536} with coalescing off/on per cell, writes
                      BENCH_pr5.json (--smoke for the quick CI profile,
                      --out FILE to choose the path, --baseline FILE to
                      diff against a committed baseline — fails the run on
                      an events/sec regression; --sim-threads N adds a
                      threads=N row per cell, gated bit-for-bit against its
                      threads=1 twin);
                      --real switches to the threaded-runtime matrix
                      (P × policy × cores on the imbalanced bag, real
                      threads + shaped wire): reports wallclock makespan and
                      round-latency p95 from the span recorder, writes
                      BENCH_real.json, and hard-fails any DLB cell that
                      stops migrating work — behavior gates, never timing,
                      so --real --smoke is safe on loaded CI runners
    experiment <id>   regenerate a paper figure: fig1 | fig3 | fig4 | fig5 | sec4 | ablation | compare | all
    trace             run one workload with the span recorder armed, print
                      latency percentiles, and write a Chrome trace-event
                      JSON (open in ui.perfetto.dev or chrome://tracing);
                      takes all RUN FLAGS plus --out FILE (trace.json), or
                      --validate FILE to check an existing trace instead
    calibrate-wt      §6 calibration: run without DLB, print W_T = max w/2
    artifacts-check   compile + smoke-run every AOT kernel artifact
    help              this text

RUN FLAGS (defaults in parentheses):
    --config FILE       load a TOML config first
    --mode sim|real     execution mode (sim)
    --workload W        cholesky|gemv_chain|bag|random_dag (cholesky)
    --p N               number of processes (10)
    --grid RxC          process grid, must multiply to --p (squarest)
    --nb N              blocks per matrix dimension (12)
    --block N           block size; real mode needs a matching artifact (64)
    --dlb on|off        dynamic load balancing (on)
    --policy P          balancer: pairing|stealing|hierarchical|diffusion|
                        sos-diffusion (pairing)
    --topology T        interconnect: flat|ring|torus|cluster, or graph-backed:
                        dragonfly:a,p,h | fattree:k | randreg:d | graph:FILE
                        (edge-list file of `u-v` tokens; inline edges via
                        --set network.graph_edges=\"0-1 1-2 ...\") (flat)
    --strategy S        basic|equalizing|smart (basic)
    --wt N              busy threshold W_T (5)
    --delta SECONDS     search back-off / exchange period δ (0.010)
    --local-tries N     hierarchical: intra-node attempts before escalating (3)
    --adaptive-delta    AIMD δ controller: shrink δ on successful transfers,
                        grow on failed rounds, within [dlb.delta_min, delta_max]
    --coalesce on|off   DES transport coalescing: pack same-(destination,
                        delay) sends of one step into one delivery event (off)
    --sim-threads N     shard the DES across N worker threads (conservative
                        time-windowed sync; results stay bit-identical to
                        the single-threaded engine) (1)
    --sim-window MODE   sharded-DES barrier protocol: matrix = distance-aware
                        per-shard horizons with sparse barriers, scalar =
                        the global min-delay horizon, every shard commanded
                        every window (matrix)
    --seed N            run seed (1)
    --trace FILE.csv    write per-process workload traces
    --trace-record on|off  arm the structured span recorder: prints round /
                        queue-wait latency percentiles after the run (off)
    --trace-out FILE    also write a Chrome trace-event JSON of the run
                        (implies --trace-record on)
    --csv-dir DIR       write workload.csv + per-rank counters.csv into DIR
    --set sec.key=val   raw config override (repeatable)

EXPERIMENT FLAGS:
    --out DIR           CSV output directory (results/<id>)
    --quick             reduced trial counts / scaled sizes
";

pub fn dispatch() -> Result<()> {
    let mut args = Args::from_env().map_err(|e| anyhow!("{e}"))?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "run" => cmd_run(&mut args),
        "compare" => cmd_compare(&mut args),
        "bench" => cmd_bench(&mut args),
        "experiment" => cmd_experiment(&mut args),
        "trace" => cmd_trace(&mut args),
        "calibrate-wt" => cmd_calibrate(&mut args),
        "artifacts-check" => cmd_artifacts_check(&mut args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand `{other}`\n\n{USAGE}"),
    }
}

/// Assemble a Config from --config + individual flags + --set overrides.
fn config_from_args(args: &mut Args) -> Result<Config> {
    let mut cfg = match args.get_str("config") {
        Some(path) => Config::from_file(&path).with_context(|| format!("loading {path}"))?,
        None => Config::default(),
    };
    if let Some(m) = args.get_str("mode") {
        cfg.mode = Mode::parse(&m)?;
    }
    if let Some(w) = args.get_str("workload") {
        cfg.workload = Workload::parse(&w)?;
    }
    if let Some(p) = args.get_usize("p")? {
        cfg.processes = p;
        cfg.grid = None; // re-derive unless --grid follows
    }
    if let Some(g) = args.get_str("grid") {
        cfg.grid = Some(Grid::parse(&g)?);
    }
    if let Some(nb) = args.get_usize("nb")? {
        cfg.nb = nb;
    }
    if let Some(b) = args.get_usize("block")? {
        cfg.block = b;
    }
    if let Some(d) = args.get_str("dlb") {
        cfg.dlb_enabled = matches!(d.as_str(), "on" | "true" | "1");
    }
    if let Some(p) = args.get_str("policy") {
        cfg.policy = PolicyKind::parse(&p)?;
    }
    if let Some(t) = args.get_str("topology") {
        // Routes `graph:FILE` into cfg.graph_file; plain kinds parse as-is.
        cfg.set_topology_str(&t)?;
    }
    if let Some(s) = args.get_str("strategy") {
        cfg.strategy = Strategy::parse(&s)?;
    }
    if let Some(wt) = args.get_usize("wt")? {
        cfg.wt = wt;
    }
    if let Some(d) = args.get_f64("delta")? {
        cfg.delta = d;
    }
    if let Some(n) = args.get_usize("local-tries")? {
        cfg.local_tries = n;
    }
    // `--adaptive-delta` alone switches it on; `--adaptive-delta off`
    // overrides a config file that enabled it.  Anything else is an error —
    // a typo must not silently run the experiment with fixed δ.
    if let Some(v) = args.get_str("adaptive-delta") {
        cfg.adaptive_delta = match v.as_str() {
            "on" | "true" | "1" | "yes" => true,
            "off" | "false" | "0" | "no" => false,
            other => bail!("--adaptive-delta: expected on|off, got {other}"),
        };
    }
    // Same contract as --adaptive-delta: bare `--coalesce` switches it on,
    // an explicit off overrides a config file, and a typo'd value errors.
    if let Some(v) = args.get_str("coalesce") {
        cfg.coalesce = match v.as_str() {
            "on" | "true" | "1" | "yes" => true,
            "off" | "false" | "0" | "no" => false,
            other => bail!("--coalesce: expected on|off, got {other}"),
        };
    }
    // Thread counts get the same typo protection: 0 is a likely slip for 1
    // and would otherwise vanish into validate()'s generic message;
    // non-numeric values already die in `get_usize`.
    if let Some(n) = args.get_usize("sim-threads")? {
        if n == 0 {
            bail!("--sim-threads: must be ≥ 1, got 0");
        }
        cfg.sim_threads = n;
    }
    if let Some(v) = args.get_str("sim-window") {
        cfg.sim_window = crate::config::WindowMode::parse(&v).map_err(|e| anyhow!("{e}"))?;
    }
    // Same on/off contract again for the span recorder: a typo'd value must
    // not silently run untraced (or traced) — it errors.
    if let Some(v) = args.get_str("trace-record") {
        cfg.trace_enabled = match v.as_str() {
            "on" | "true" | "1" | "yes" => true,
            "off" | "false" | "0" | "no" => false,
            other => bail!("--trace-record: expected on|off, got {other}"),
        };
    }
    if let Some(p) = args.get_str("trace-out") {
        cfg.trace_out = p;
        cfg.trace_enabled = true;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.seed = s;
    }
    let overrides = args.get_all("set");
    cfg.apply_overrides(overrides.iter().map(|s| s.as_str()))?;
    cfg.validate()?;
    Ok(cfg)
}

/// Everything a workload run produces that the CLI reports on, whatever
/// the workload × mode combination was.
struct WorkloadRun {
    makespan: f64,
    traces: RunTraces,
    trace: RunTrace,
    counters: DlbCounters,
    per_process: Vec<DlbCounters>,
}

/// Run the configured workload in the configured mode, printing the
/// per-workload summary lines (tasks, residual, utilization) as it goes.
/// Shared by `ductr run` and `ductr trace`.
fn run_workload(cfg: &Config) -> Result<WorkloadRun> {
    Ok(match (cfg.workload, cfg.mode) {
        (Workload::Cholesky, Mode::Sim) => {
            let r = cholesky::run_sim(cfg)?;
            println!(
                "tasks={} static-imbalance={:.3} utilization={:.1}%",
                r.tasks,
                r.static_imbalance,
                r.utilization.unwrap_or(0.0) * 100.0
            );
            WorkloadRun {
                makespan: r.makespan,
                traces: r.traces,
                trace: r.trace,
                counters: r.counters,
                per_process: r.per_process_counters,
            }
        }
        (Workload::Cholesky, Mode::Real) => {
            let r = cholesky::run_real(cfg)?;
            let res = r.residual.unwrap_or(f64::NAN);
            println!("tasks={} residual={res:.3e}", r.tasks);
            if !(res < 1e-3) {
                bail!("numeric verification FAILED: residual {res:.3e}");
            }
            WorkloadRun {
                makespan: r.makespan,
                traces: r.traces,
                trace: r.trace,
                counters: r.counters,
                per_process: r.per_process_counters,
            }
        }
        (w, Mode::Sim) => {
            let graph = match w {
                Workload::GemvChain => gemv_chain::build(
                    cfg.processes,
                    (cfg.processes / 2).max(1),
                    cfg.chains_per_proc,
                    cfg.chain_len,
                    cfg.block,
                ),
                Workload::Bag => bag::build(
                    cfg.processes,
                    bag::BagParams {
                        tasks: cfg.bag_tasks,
                        skew: cfg.bag_skew,
                        block: cfg.block,
                        ..Default::default()
                    },
                    cfg.seed,
                ),
                Workload::RandomDag => {
                    rand_dag::build(cfg.processes, rand_dag::DagParams::default(), cfg.seed)
                }
                Workload::Cholesky => unreachable!(),
            };
            let r = ductr::sim::run_config(cfg, graph).map_err(Error::new)?;
            println!("utilization={:.1}%", r.utilization * 100.0);
            WorkloadRun {
                makespan: r.makespan,
                traces: r.traces,
                trace: r.trace,
                counters: r.counters,
                per_process: r.per_process_counters,
            }
        }
        (w, Mode::Real) => {
            let graph = match w {
                Workload::Bag => bag::build(
                    cfg.processes,
                    bag::BagParams {
                        tasks: cfg.bag_tasks,
                        skew: cfg.bag_skew,
                        block: cfg.block,
                        ..Default::default()
                    },
                    cfg.seed,
                ),
                Workload::RandomDag => {
                    rand_dag::build(cfg.processes, rand_dag::DagParams::default(), cfg.seed)
                }
                other => bail!("real mode for `{other}` not supported (synthetic payloads)"),
            };
            let init = vec![Vec::new(); cfg.processes];
            let r = ductr::runtime::run_threaded(cfg, graph, init, false)?;
            WorkloadRun {
                makespan: r.makespan,
                traces: r.traces,
                trace: r.trace,
                counters: r.counters,
                per_process: r.per_process_counters,
            }
        }
    })
}

fn cmd_run(args: &mut Args) -> Result<()> {
    let trace_out = args.get_str("trace");
    let csv_dir = args.get_str("csv-dir");
    let cfg = config_from_args(args)?;
    args.finish().map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;

    let delta_desc = if cfg.adaptive_delta {
        format!("adaptive[{}..{}]s (start {})", cfg.delta_min, cfg.delta_max, cfg.delta)
    } else {
        format!("{}s", cfg.delta)
    };
    println!(
        "ductr run: workload={} mode={} P={} grid={} dlb={} policy={} topology={} strategy={} W_T={} δ={} seed={}",
        cfg.workload,
        cfg.mode,
        cfg.processes,
        cfg.effective_grid(),
        cfg.dlb_enabled,
        cfg.policy,
        cfg.topology,
        cfg.strategy,
        cfg.wt,
        delta_desc,
        cfg.seed
    );

    let r = run_workload(&cfg)?;

    println!("makespan: {:.6} s", r.makespan);
    println!("dlb: {}", r.counters.summary_line());
    if cfg.trace_enabled {
        print!("{}", LatencyReport::from_trace(&r.trace).render());
    }
    if !cfg.trace_out.is_empty() {
        chrome::write_trace(&cfg.trace_out, &r.trace, &r.traces)?;
        println!("chrome trace → {}", cfg.trace_out);
    }
    if let Some(path) = trace_out {
        csv::write_traces(&path, &r.traces)?;
        println!("traces → {path}");
    }
    if let Some(dir) = csv_dir {
        let dir = std::path::PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        csv::write_traces(dir.join("workload.csv"), &r.traces)?;
        csv::write_counters(dir.join("counters.csv"), &r.per_process)?;
        println!("csv → {} (workload.csv, counters.csv)", dir.display());
    }
    Ok(())
}

/// `ductr trace`: one run with the recorder armed, percentile report, and a
/// Chrome trace-event JSON on disk.  `--validate FILE` instead checks an
/// existing trace file (the CI smoke path).
fn cmd_trace(args: &mut Args) -> Result<()> {
    if let Some(path) = args.get_str("validate") {
        args.finish().map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;
        let s = chrome::validate_file(&path)?;
        println!(
            "trace {path}: {} events ({} spans, {} instants, {} counter samples, \
             {} metadata), {} distinct event names",
            s.total, s.spans, s.instants, s.counters, s.metadata, s.names
        );
        return Ok(());
    }
    let out = args.get_str("out");
    let mut cfg = config_from_args(args)?;
    args.finish().map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;
    cfg.trace_enabled = true;
    if let Some(o) = out {
        cfg.trace_out = o;
    }
    if cfg.trace_out.is_empty() {
        cfg.trace_out = "trace.json".to_string();
    }

    println!(
        "ductr trace: workload={} mode={} P={} dlb={} policy={} seed={}",
        cfg.workload, cfg.mode, cfg.processes, cfg.dlb_enabled, cfg.policy, cfg.seed
    );
    let r = run_workload(&cfg)?;
    println!("makespan: {:.6} s", r.makespan);
    print!("{}", LatencyReport::from_trace(&r.trace).render());
    chrome::write_trace(&cfg.trace_out, &r.trace, &r.traces)?;
    println!(
        "chrome trace → {} ({} events; open in ui.perfetto.dev or chrome://tracing)",
        cfg.trace_out,
        r.trace.total_events()
    );
    Ok(())
}

/// The balancer shoot-out (also reachable as `experiment compare`).
fn cmd_compare(args: &mut Args) -> Result<()> {
    // `--smoke` is the CI spelling of `--quick` (matches `bench --smoke`).
    // Evaluate both before or-ing: short-circuiting would leave the second
    // flag unconsumed and `finish()` would reject it.
    let quick_flag = args.get_bool("quick")?;
    let smoke_flag = args.get_bool("smoke")?;
    let quick = quick_flag || smoke_flag;
    let seed = args.get_u64("seed")?.unwrap_or(1);
    let out = args.get_str("out");
    args.finish().map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;
    let r = compare::run(seed, quick)?;
    print!("{}", r.render());
    let dir = out
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| ductr::experiments::out_dir("compare"));
    std::fs::create_dir_all(&dir).ok();
    let path = dir.join("compare.csv");
    r.write_csv(&path)?;
    println!("table → {}", path.display());
    Ok(())
}

/// The DES hot-path baseline (the perf trajectory record, BENCH_pr5.json).
fn cmd_bench(args: &mut Args) -> Result<()> {
    let smoke = args.get_bool("smoke")?;
    let real = args.get_bool("real")?;
    let seed = args.get_u64("seed")?.unwrap_or(1);
    // Same 0-is-a-typo contract as the run flag: each cell always gets its
    // threads=1 oracle row; N > 1 adds a sharded row gated against it.
    let threads_flag = args.get_usize("sim-threads")?;
    let threads = match threads_flag {
        Some(0) => bail!("--sim-threads: must be ≥ 1, got 0"),
        Some(n) => n,
        None => 1,
    };
    let baseline = args.get_str("baseline");
    let out_flag = args.get_str("out");
    args.finish().map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;

    if real {
        // The threaded matrix: wallclock numbers, so no cross-machine
        // --baseline timing gate (behavior gates live inside the run) and
        // no DES shard dimension.
        if threads_flag.is_some() {
            bail!("--sim-threads applies to the DES bench, not --real");
        }
        if baseline.is_some() {
            bail!(
                "--baseline applies to the DES bench, not --real \
                 (wallclock timings are machine-dependent; --real gates on \
                 completion + migration counters instead)"
            );
        }
        let repo_real = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_real.json");
        let out = match out_flag {
            Some(o) => o,
            None if smoke => {
                std::env::temp_dir().join("ductr_bench_real_smoke.json").display().to_string()
            }
            None if std::path::Path::new(repo_real).exists() => repo_real.to_string(),
            None => "BENCH_real.json".to_string(),
        };
        let r = ductr::experiments::bench_real::run(seed, smoke)?;
        print!("{}", r.render());
        r.write_json(std::path::Path::new(&out))
            .map_err(|e| anyhow!("writing {out}: {e}"))?;
        println!("real-mode baseline → {out}");
        return Ok(());
    }

    // Full sweeps default to the committed baseline at this checkout's
    // repo root (compile-time anchor, checked at runtime so a copied
    // binary on another machine falls back to the current directory
    // instead of failing or touching an unrelated file).  Smoke runs must
    // not overwrite the baseline — they default to a temp path.
    let repo_baseline = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_pr5.json");
    let out = match out_flag {
        Some(o) => o,
        None if smoke => {
            std::env::temp_dir().join("ductr_bench_smoke.json").display().to_string()
        }
        None if std::path::Path::new(repo_baseline).exists() => repo_baseline.to_string(),
        None => "BENCH_pr5.json".to_string(),
    };
    // Read the baseline BEFORE anything is written: the default full-sweep
    // out path IS the committed baseline, so loading later would diff the
    // fresh run against its own just-written numbers (always passing) and
    // clobber the reference irrecoverably.
    let base = match &baseline {
        Some(bp) => {
            Some(ductr::experiments::bench::load_baseline(std::path::Path::new(bp))?)
        }
        None => None,
    };
    let r = ductr::experiments::bench::run(seed, smoke, threads)?;
    print!("{}", r.render());
    r.write_json(std::path::Path::new(&out))
        .map_err(|e| anyhow!("writing {out}: {e}"))?;
    println!("baseline → {out}");
    // Regression gate last, after the fresh numbers are safely on disk: a
    // placeholder baseline compares informationally, a real one fails the
    // command on deterministic event drift or an events/sec collapse.
    if let (Some(base), Some(bp)) = (base, baseline) {
        let table = r.compare_to_baseline(&base, &bp)?;
        print!("{table}");
    }
    Ok(())
}

fn cmd_experiment(args: &mut Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| {
            anyhow!("experiment needs an id: fig1|fig3|fig4|fig5|sec4|ablation|compare|all")
        })?;
    let quick_flag = args.get_bool("quick")?;
    let smoke_flag = args.get_bool("smoke")?;
    let quick = quick_flag || smoke_flag;
    let out = args.get_str("out");
    let seed = args.get_u64("seed")?.unwrap_or(1);
    args.finish().map_err(|e| anyhow!("{e}"))?;

    let run_one = |id: &str| -> Result<()> {
        let dir = out
            .clone()
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| ductr::experiments::out_dir(id));
        std::fs::create_dir_all(&dir).ok();
        match id {
            "fig1" => {
                let r = fig1::run(10, if quick { 1000 } else { 20_000 }, seed);
                println!("{}", r.render_panel(10));
                println!("{}", r.render_panel(100));
                println!(
                    "K=P/2, n=5: success = {:.4} (asymptote 1-2^-5 = {:.4})",
                    r.k_half_n5, r.asymptote_n5
                );
                csv::write_rows(
                    dir.join("fig1.csv"),
                    &["population", "busy", "tries", "exact", "monte_carlo"],
                    &r.csv_rows(),
                )?;
            }
            "fig3" => {
                let (ps, trials): (&[usize], usize) = if quick {
                    (&[8, 16, 32], 10)
                } else {
                    (&[8, 16, 32, 64, 128], 40)
                };
                let r = fig3::run(ps, &[0.1, 0.3, 0.5, 0.7, 0.9], 0.010, trials, seed);
                println!("{}", r.render());
                csv::write_rows(
                    dir.join("fig3.csv"),
                    &["processes", "busy_fraction", "mean_s", "max_s", "p95_s"],
                    &r.csv_rows(),
                )?;
            }
            "fig4" => {
                let results = fig4::run(seed)?;
                for r in &results {
                    println!("{}", r.render(5));
                    let stem = r.spec.name.replace([' ', '='], "_");
                    csv::write_rows(
                        dir.join(format!("fig4_{stem}.csv")),
                        &["process", "time", "workload", "dlb"],
                        &r.csv_rows(),
                    )?;
                }
            }
            "fig5" => {
                let seeds: Vec<u64> = if quick { (1..=4).collect() } else { (1..=10).collect() };
                let r = fig5::run(100_000, &seeds)?;
                println!("{}", r.render());
                csv::write_rows(
                    dir.join("fig5.csv"),
                    &["seed", "makespan", "improvement", "migrations"],
                    &r.csv_rows(),
                )?;
            }
            "sec4" => {
                let r = sec4::run(seed)?;
                println!("{}", r.render());
                csv::write_rows(
                    dir.join("sec4_q_table.csv"),
                    &["kind_index", "block", "q", "wt_guideline"],
                    &r.csv_rows(),
                )?;
            }
            "ablation" => {
                let r = ablation::run(seed)?;
                println!("{}", r.render());
                csv::write_rows(
                    dir.join("ablation.csv"),
                    &["row", "makespan", "improvement", "migrations", "requests", "max_w"],
                    &r.csv_rows(),
                )?;
            }
            "compare" => {
                let r = compare::run(seed, quick)?;
                print!("{}", r.render());
                r.write_csv(dir.join("compare.csv"))?;
            }
            other => bail!("unknown experiment `{other}`"),
        }
        Ok(())
    };

    if id == "all" {
        for e in ["fig1", "fig3", "fig4", "fig5", "sec4", "ablation", "compare"] {
            println!("\n================ {e} ================");
            run_one(e)?;
        }
        Ok(())
    } else {
        run_one(&id)
    }
}

fn cmd_calibrate(args: &mut Args) -> Result<()> {
    let mut cfg = config_from_args(args)?;
    args.finish().map_err(|e| anyhow!("{e}"))?;
    cfg.dlb_enabled = false;
    let r = match cfg.workload {
        Workload::Cholesky => cholesky::run_sim(&cfg)?.traces,
        _ => bail!("calibrate-wt currently supports the cholesky workload"),
    };
    let wt = calibrate_from_traces(&r);
    println!("max_t w_i(t) = {}", r.max_workload());
    println!("W_T = max/2 = {wt}   (paper §6 rule)");
    Ok(())
}

fn cmd_artifacts_check(args: &mut Args) -> Result<()> {
    let dir = args.get_str("artifacts").unwrap_or_else(|| "artifacts".to_string());
    args.finish().map_err(|e| anyhow!("{e}"))?;
    let manifest = Arc::new(Manifest::load(&dir).map_err(|e| anyhow!("{e}"))?);
    manifest.check_files().map_err(|e| anyhow!("{e}"))?;
    println!("manifest: {} kernel artifacts in {dir}", manifest.entries.len());
    let mut blocks = manifest.blocks_for(TaskKind::Gemm);
    blocks.sort_unstable();
    for b in blocks {
        let mut lib = KernelLibrary::new(Arc::clone(&manifest), b)?;
        let report = lib.smoke_all()?;
        for (kind, dt) in report {
            println!("  block {b:>4} {kind:<6} compile+run OK ({:.1} ms)", dt * 1e3);
        }
    }
    println!("artifacts-check: all kernels OK");
    Ok(())
}
