//! Probability machinery for the randomized partner search (paper eq. (1),
//! Fig 1).

pub mod hypergeom;

pub use hypergeom::{ln_choose, ln_gamma, Hypergeometric};
