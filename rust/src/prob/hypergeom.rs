//! Exact hypergeometric distribution in log space — paper eq. (1).
//!
//! The probability of finding `k` busy processes in `n` uniform
//! no-replacement tries when `K` of `P` processes are busy:
//!
//! ```text
//! P(k) = C(P−K, n−k) · C(K, k) / C(P, n)
//! ```
//!
//! Fig 1 plots the success probability `1 − P(0)` for P = 10 and P = 100; the
//! paper's asymptotic observation is that for K = P/2 and P → ∞ this tends to
//! `1 − 2⁻ⁿ` (> 96% for n = 5 tries, which fixes the protocol's tries-per-
//! round constant).
//!
//! Evaluation is in log space via a Lanczos `ln_gamma`, so P of 10⁶⁺ is fine.

/// Lanczos approximation of ln Γ(x) for x > 0 (|err| ≲ 1e-13).
pub fn ln_gamma(x: f64) -> f64 {
    assert!(x > 0.0, "ln_gamma domain: x > 0, got {x}");
    // g = 7, n = 9 Lanczos coefficients.
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection: Γ(x)Γ(1−x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = C[0];
    let t = x + G + 0.5;
    for (i, &c) in C.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// ln C(n, k); `-inf` when the coefficient is zero (k > n).
pub fn ln_choose(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    if k == 0 || k == n {
        return 0.0;
    }
    ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0)
}

/// Hypergeometric(P, K, n): number of busy processes found in `n` distinct
/// uniform tries from a population of `P` containing `K` busy.
#[derive(Debug, Clone, Copy)]
pub struct Hypergeometric {
    /// Population size (total processes that can be tried).
    pub population: u64,
    /// Number of "successes" in the population (busy processes).
    pub busy: u64,
    /// Number of tries (draws without replacement).
    pub tries: u64,
}

impl Hypergeometric {
    pub fn new(population: u64, busy: u64, tries: u64) -> Self {
        assert!(busy <= population, "K={busy} > P={population}");
        assert!(tries <= population, "n={tries} > P={population}");
        Hypergeometric { population, busy, tries }
    }

    /// P(X = k) — paper eq. (1).
    pub fn pmf(&self, k: u64) -> f64 {
        let (p, kk, n) = (self.population, self.busy, self.tries);
        if k > kk || k > n || n - k > p - kk {
            return 0.0;
        }
        (ln_choose(p - kk, n - k) + ln_choose(kk, k) - ln_choose(p, n)).exp()
    }

    /// P(X ≥ 1) = 1 − P(0): probability that a round of `tries` finds at
    /// least one busy partner.
    pub fn success_probability(&self) -> f64 {
        1.0 - self.pmf(0)
    }

    /// Mean of the distribution: n·K/P.
    pub fn mean(&self) -> f64 {
        self.tries as f64 * self.busy as f64 / self.population as f64
    }

    /// Expected number of rounds until a success (geometric in the round
    /// success probability) — the model behind the paper's δ discussion.
    pub fn expected_rounds(&self) -> f64 {
        let p = self.success_probability();
        if p <= 0.0 { f64::INFINITY } else { 1.0 / p }
    }

    /// Limit of the success probability as P → ∞ with K/P = `frac`:
    /// 1 − (1−frac)ⁿ. The paper quotes the frac = 1/2 case: 1 − 2⁻ⁿ.
    pub fn asymptotic_success(frac: f64, tries: u64) -> f64 {
        1.0 - (1.0 - frac).powi(tries as i32)
    }

    /// Monte-Carlo estimate of the success probability using the same
    /// `sample_distinct` draw as the live pairing protocol; used by tests
    /// and the Fig 1 bench to validate eq. (1) against the implementation.
    pub fn monte_carlo_success(&self, reps: usize, rng: &mut crate::util::rng::Rng) -> f64 {
        let p = self.population as usize;
        let kk = self.busy as usize;
        let n = self.tries as usize;
        let mut hits = 0usize;
        // dense membership mask reused across reps: set the k busy bits,
        // test, clear the same bits — no per-rep allocation or hashing
        let mut busy_mask = vec![false; p];
        for _ in 0..reps {
            // busy set = a random k-subset; try n distinct indices
            let busy = rng.sample_distinct(p, kk, None);
            for &b in &busy {
                busy_mask[b] = true;
            }
            let tries = rng.sample_distinct(p, n, None);
            if tries.iter().any(|&t| busy_mask[t]) {
                hits += 1;
            }
            for &b in &busy {
                busy_mask[b] = false;
            }
        }
        hits as f64 / reps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1) = Γ(2) = 1, Γ(5) = 24, Γ(0.5) = √π
        assert!(ln_gamma(1.0).abs() < 1e-12);
        assert!(ln_gamma(2.0).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn ln_choose_small_exact() {
        assert!((ln_choose(10, 3).exp() - 120.0).abs() < 1e-9);
        assert!((ln_choose(52, 5).exp() - 2_598_960.0).abs() < 1e-3);
        assert_eq!(ln_choose(5, 6), f64::NEG_INFINITY);
        assert_eq!(ln_choose(7, 0), 0.0);
    }

    #[test]
    fn pmf_sums_to_one() {
        for &(p, k, n) in &[(10, 5, 5), (100, 30, 5), (17, 3, 7), (50, 50, 10)] {
            let h = Hypergeometric::new(p, k, n);
            let total: f64 = (0..=n).map(|x| h.pmf(x)).sum();
            assert!((total - 1.0).abs() < 1e-10, "P={p} K={k} n={n}: sum={total}");
        }
    }

    #[test]
    fn pmf_mean_matches() {
        let h = Hypergeometric::new(60, 21, 8);
        let mean: f64 = (0..=8).map(|k| k as f64 * h.pmf(k)).sum();
        assert!((mean - h.mean()).abs() < 1e-10);
    }

    #[test]
    fn success_probability_monotone_in_tries() {
        let mut prev = 0.0;
        for n in 1..=9 {
            let s = Hypergeometric::new(10, 3, n).success_probability();
            assert!(s >= prev);
            prev = s;
        }
    }

    #[test]
    fn paper_claim_k_half_n5() {
        // paper: for K=P/2, P→∞, success → 1 − 2⁻ⁿ; for n=5 > 96%.
        // Already at P=100 the value is within 1% of the limit.
        let s = Hypergeometric::new(100, 50, 5).success_probability();
        assert!(s > 0.96, "success at P=100, K=50, n=5: {s}");
        let asym = Hypergeometric::asymptotic_success(0.5, 5);
        assert!((asym - (1.0 - 1.0 / 32.0)).abs() < 1e-12);
        assert!((s - asym).abs() < 0.02);
    }

    #[test]
    fn certain_success_when_tries_exceed_idle() {
        // n > P−K ⇒ impossible to pick only idle ⇒ success = 1
        let h = Hypergeometric::new(10, 8, 3);
        assert!((h.success_probability() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_busy_means_no_success() {
        let h = Hypergeometric::new(20, 0, 5);
        assert_eq!(h.success_probability(), 0.0);
        assert_eq!(h.expected_rounds(), f64::INFINITY);
    }

    #[test]
    fn monte_carlo_agrees_with_exact() {
        let mut rng = Rng::new(99);
        for &(p, k, n) in &[(10u64, 5u64, 5u64), (100, 30, 5), (30, 15, 5)] {
            let h = Hypergeometric::new(p, k, n);
            let mc = h.monte_carlo_success(4000, &mut rng);
            let exact = h.success_probability();
            assert!(
                (mc - exact).abs() < 0.03,
                "P={p} K={k} n={n}: mc={mc} exact={exact}"
            );
        }
    }
}
