//! DLB event counters — the quantitative audit trail of the protocol:
//! how many rounds, how many hits/declines, how much data migrated.

/// Per-process DLB counters; `merge` aggregates a run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DlbCounters {
    /// Pair-search rounds started (each = up to `tries` requests).
    pub rounds: u64,
    /// Rounds where every try was declined.
    pub failed_rounds: u64,
    pub requests_sent: u64,
    pub requests_received: u64,
    pub accepts_sent: u64,
    pub declines_sent: u64,
    /// Confirmed transactions this process participated in.
    pub transactions: u64,
    /// Transactions where the busy side had nothing (beneficial) to export.
    pub empty_transactions: u64,
    pub tasks_exported: u64,
    /// Subset of `tasks_exported` that crossed more than one hop (left the
    /// cluster node / adjacency shell) — the locality cost of a policy.
    pub tasks_exported_remote: u64,
    pub tasks_received: u64,
    /// Doubles shipped as migrated inputs + returned outputs.
    pub migration_doubles: u64,
    /// Accepter soft-lock timeouts (confirm never arrived).
    pub confirm_timeouts: u64,
    /// Steal grants that arrived *after* the thief's confirm-timeout had
    /// already written the round off: the tasks are enqueued anyway, so the
    /// thief may over-steal with a second request already in flight.
    pub late_grants: u64,
    /// Messages this process emitted that the transport packed into an
    /// already-scheduled delivery (same destination, same computed delay,
    /// same step) instead of their own event — the saving of
    /// `[sim] coalesce = true`.  Zero with coalescing off.
    pub messages_coalesced: u64,
}

impl DlbCounters {
    pub fn merge(&mut self, o: &DlbCounters) {
        self.rounds += o.rounds;
        self.failed_rounds += o.failed_rounds;
        self.requests_sent += o.requests_sent;
        self.requests_received += o.requests_received;
        self.accepts_sent += o.accepts_sent;
        self.declines_sent += o.declines_sent;
        self.transactions += o.transactions;
        self.empty_transactions += o.empty_transactions;
        self.tasks_exported += o.tasks_exported;
        self.tasks_exported_remote += o.tasks_exported_remote;
        self.tasks_received += o.tasks_received;
        self.migration_doubles += o.migration_doubles;
        self.confirm_timeouts += o.confirm_timeouts;
        self.late_grants += o.late_grants;
        self.messages_coalesced += o.messages_coalesced;
    }

    /// Fraction of rounds that found a partner — compare against the
    /// hypergeometric prediction of eq. (1).
    pub fn round_success_rate(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        (self.rounds - self.failed_rounds) as f64 / self.rounds as f64
    }

    pub fn summary_line(&self) -> String {
        format!(
            "rounds={} (failed {}), req {}/{} s/r, accepts {}, declines {}, tx={} (empty {}), tasks {}→/{}← ({} remote), {} doubles, timeouts {} (late grants {}), coalesced {}",
            self.rounds,
            self.failed_rounds,
            self.requests_sent,
            self.requests_received,
            self.accepts_sent,
            self.declines_sent,
            self.transactions,
            self.empty_transactions,
            self.tasks_exported,
            self.tasks_received,
            self.tasks_exported_remote,
            self.migration_doubles,
            self.confirm_timeouts,
            self.late_grants,
            self.messages_coalesced,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums_everything() {
        let mut a = DlbCounters { rounds: 2, tasks_exported: 3, ..Default::default() };
        let b = DlbCounters { rounds: 5, failed_rounds: 1, tasks_received: 7, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.rounds, 7);
        assert_eq!(a.failed_rounds, 1);
        assert_eq!(a.tasks_exported, 3);
        assert_eq!(a.tasks_received, 7);
    }

    #[test]
    fn success_rate() {
        let c = DlbCounters { rounds: 10, failed_rounds: 3, ..Default::default() };
        assert!((c.round_success_rate() - 0.7).abs() < 1e-12);
        assert_eq!(DlbCounters::default().round_success_rate(), 0.0);
    }

    #[test]
    fn summary_is_stable() {
        let c = DlbCounters { rounds: 1, ..Default::default() };
        assert!(c.summary_line().contains("rounds=1"));
    }
}
