//! Observability: per-process workload traces (the w_i(t) of Figs 4–5),
//! DLB event counters, CSV writers, and the flight recorder — typed
//! span/instant events (`recorder`), latency histograms over them
//! (`histogram`), and a Chrome/Perfetto trace exporter (`chrome`).

pub mod chrome;
pub mod counters;
pub mod csv;
pub mod histogram;
pub mod recorder;
pub mod trace;

pub use counters::DlbCounters;
pub use histogram::{LatencyHistogram, LatencyReport};
pub use recorder::{RoundOutcome, RunTrace, TraceEvent, TraceRecorder};
pub use trace::WorkloadTrace;
