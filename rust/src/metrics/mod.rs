//! Observability: per-process workload traces (the w_i(t) of Figs 4–5),
//! DLB event counters, and CSV writers.

pub mod counters;
pub mod csv;
pub mod trace;

pub use counters::DlbCounters;
pub use trace::WorkloadTrace;
