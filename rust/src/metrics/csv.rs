//! Tiny CSV writers for experiment outputs (plots are reproduced from
//! these; the ASCII renderings are quick-looks only).

use std::io::{self, Write};
use std::path::Path;

use super::trace::RunTraces;

/// Write per-process workload traces as long-format CSV:
/// `process,time,workload`.
pub fn write_traces(path: impl AsRef<Path>, traces: &RunTraces) -> io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "process,time,workload")?;
    for (p, tr) in traces.per_process.iter().enumerate() {
        for &(t, w) in tr.samples() {
            writeln!(f, "{p},{t},{w}")?;
        }
    }
    Ok(())
}

/// Write arbitrary named columns: header + rows.
pub fn write_rows(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<f64>],
) -> io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ids::ProcessId;

    #[test]
    fn traces_csv_format() {
        let mut tr = RunTraces::new(2);
        tr.record(ProcessId(0), 0.0, 1);
        tr.record(ProcessId(1), 0.5, 2);
        let p = std::env::temp_dir().join("ductr_trace_test.csv");
        write_traces(&p, &tr).expect("write");
        let body = std::fs::read_to_string(&p).expect("read");
        assert!(body.starts_with("process,time,workload\n"));
        assert!(body.contains("0,0,1"));
        assert!(body.contains("1,0.5,2"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn rows_csv_format() {
        let p = std::env::temp_dir().join("ductr_rows_test.csv");
        write_rows(&p, &["a", "b"], &[vec![1.0, 2.0], vec![3.0, 4.5]]).expect("write");
        let body = std::fs::read_to_string(&p).expect("read");
        assert_eq!(body, "a,b\n1,2\n3,4.5\n");
        let _ = std::fs::remove_file(p);
    }
}
