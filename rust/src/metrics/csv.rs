//! Tiny CSV writers for experiment outputs (plots are reproduced from
//! these; the ASCII renderings are quick-looks only).

use std::io::{self, Write};
use std::path::Path;

use super::counters::DlbCounters;
use super::trace::RunTraces;

/// Write per-process workload traces as long-format CSV:
/// `process,time,workload`.
pub fn write_traces(path: impl AsRef<Path>, traces: &RunTraces) -> io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "process,time,workload")?;
    for (p, tr) in traces.per_process.iter().enumerate() {
        for &(t, w) in tr.samples() {
            writeln!(f, "{p},{t},{w}")?;
        }
    }
    Ok(())
}

/// Write per-process DLB counters, one row per rank — the full counter
/// set a merged run summary collapses away, for offline per-rank analysis
/// of sweeps (`ductr run --csv-dir`).
pub fn write_counters(path: impl AsRef<Path>, per_process: &[DlbCounters]) -> io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(
        f,
        "process,rounds,failed_rounds,requests_sent,requests_received,accepts_sent,\
         declines_sent,transactions,empty_transactions,tasks_exported,tasks_exported_remote,\
         tasks_received,migration_doubles,confirm_timeouts,late_grants,messages_coalesced"
    )?;
    for (p, c) in per_process.iter().enumerate() {
        writeln!(
            f,
            "{p},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            c.rounds,
            c.failed_rounds,
            c.requests_sent,
            c.requests_received,
            c.accepts_sent,
            c.declines_sent,
            c.transactions,
            c.empty_transactions,
            c.tasks_exported,
            c.tasks_exported_remote,
            c.tasks_received,
            c.migration_doubles,
            c.confirm_timeouts,
            c.late_grants,
            c.messages_coalesced,
        )?;
    }
    Ok(())
}

/// Write arbitrary named columns: header + rows.
pub fn write_rows(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<f64>],
) -> io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ids::ProcessId;

    #[test]
    fn traces_csv_format() {
        let mut tr = RunTraces::new(2);
        tr.record(ProcessId(0), 0.0, 1);
        tr.record(ProcessId(1), 0.5, 2);
        let p = std::env::temp_dir().join("ductr_trace_test.csv");
        write_traces(&p, &tr).expect("write");
        let body = std::fs::read_to_string(&p).expect("read");
        assert!(body.starts_with("process,time,workload\n"));
        assert!(body.contains("0,0,1"));
        assert!(body.contains("1,0.5,2"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn counters_csv_one_row_per_rank() {
        use crate::metrics::DlbCounters;
        let per = vec![
            DlbCounters { rounds: 3, tasks_exported: 2, ..Default::default() },
            DlbCounters { requests_received: 5, ..Default::default() },
        ];
        let p = std::env::temp_dir().join("ductr_counters_test.csv");
        write_counters(&p, &per).expect("write");
        let body = std::fs::read_to_string(&p).expect("read");
        let mut lines = body.lines();
        let header = lines.next().expect("header");
        assert!(header.starts_with("process,rounds,failed_rounds,"));
        assert_eq!(header.split(',').count(), 16);
        assert_eq!(lines.next().expect("rank 0"), "0,3,0,0,0,0,0,0,0,2,0,0,0,0,0,0");
        assert_eq!(lines.next().expect("rank 1"), "1,0,0,0,5,0,0,0,0,0,0,0,0,0,0,0");
        assert!(lines.next().is_none());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn rows_csv_format() {
        let p = std::env::temp_dir().join("ductr_rows_test.csv");
        write_rows(&p, &["a", "b"], &[vec![1.0, 2.0], vec![3.0, 4.5]]).expect("write");
        let body = std::fs::read_to_string(&p).expect("read");
        assert_eq!(body, "a,b\n1,2\n3,4.5\n");
        let _ = std::fs::remove_file(p);
    }
}
