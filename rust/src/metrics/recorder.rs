//! The flight recorder: typed span/instant events for one process.
//!
//! [`TraceRecorder`] is an enum with a no-op variant so a disabled recorder
//! (the default) costs one discriminant test per hook and allocates
//! nothing — determinism tests in `tests/determinism.rs` enforce that
//! enabling it changes no fingerprint bit either, because the recorder
//! only *observes* the coordinator: it never touches the RNG, the queues,
//! or the effect stream.
//!
//! Timestamps are whatever clock the host engine passes in: virtual
//! seconds in the DES, monotonic seconds since run start in the threaded
//! runtime ("one coordinator, two clocks" — see ARCHITECTURE.md).  Events
//! are appended in call order, so per-process streams are time-monotone
//! as long as the engine's `now` is (both are).
//!
//! Event taxonomy (three tracks per process):
//!
//! - **protocol**: pair-search round lifecycle.  A *round* opens when the
//!   coordinator sends its first `PairRequest`/`StealRequest` with a new
//!   round id, accumulates handshake instants (accept/decline/confirm),
//!   and closes with a terminal [`RoundOutcome`] — `Granted`/`Empty` when
//!   tasks (or an empty export / its ack) arrive, `Superseded` when a new
//!   round starts first, `Abandoned` at shutdown.
//! - **tasks**: ready → exec start (with queue wait) → exec end, plus
//!   migration and result-return instants.
//! - **net**: per-message in-flight intervals, recorded on the *receiver*
//!   (the DES stamps `Flight::sent_at`; the threaded runtime's channels
//!   carry no send stamp, so this track is DES-only).

use crate::core::ids::{ProcessId, TaskId};
use crate::net::message::Msg;

/// How a pair-search round ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundOutcome {
    /// Tasks were obtained (idle side) or shipped and acked (busy side).
    Granted,
    /// The transaction completed but moved zero tasks.
    Empty,
    /// A new round started before this one reached a terminal message.
    Superseded,
    /// Still open when the process halted.
    Abandoned,
}

impl RoundOutcome {
    pub fn name(self) -> &'static str {
        match self {
            RoundOutcome::Granted => "granted",
            RoundOutcome::Empty => "empty",
            RoundOutcome::Superseded => "superseded",
            RoundOutcome::Abandoned => "abandoned",
        }
    }
}

/// One recorded event.  Spans carry their start instant inline
/// (`started`/`requested`/`sent`) and are emitted at their *end*, which
/// keeps the per-process stream append-only and time-monotone.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEvent {
    /// First request of a new pair-search round.
    RoundStart { round: u64, t: f64 },
    /// A `PairRequest`/`StealRequest` left for `to`.
    RoundRequest { round: u64, to: ProcessId, t: f64 },
    /// `PairAccept` arrived from `from`.
    RoundAccept { round: u64, from: ProcessId, t: f64 },
    /// `PairDecline` arrived from `from`.
    RoundDecline { round: u64, from: ProcessId, t: f64 },
    /// `PairConfirm` sent to `to` (partner committed).
    RoundConfirm { round: u64, to: ProcessId, t: f64 },
    /// Round closed.  `started` is the `RoundStart` instant, `requested`
    /// the last request send; `tasks` the number of tasks moved.
    RoundEnd {
        round: u64,
        outcome: RoundOutcome,
        tasks: usize,
        started: f64,
        requested: f64,
        t: f64,
    },
    /// Task entered the ready queue.
    TaskReady { task: TaskId, t: f64 },
    /// Execution began; `queue_wait` = t − ready instant.
    ExecStart { task: TaskId, queue_wait: f64, t: f64 },
    /// Execution finished; `started` = t − kernel duration.
    ExecEnd { task: TaskId, started: f64, t: f64 },
    /// Task shipped to `to` in an export.
    MigratedOut { task: TaskId, to: ProcessId, t: f64 },
    /// Task received from `from` in an export.
    MigratedIn { task: TaskId, from: ProcessId, t: f64 },
    /// A migrated task's output arrived back at its origin (this process).
    ResultReturned { task: TaskId, t: f64 },
    /// A message was delivered here; `sent` is its send instant.
    MsgFlight { kind: &'static str, from: ProcessId, sent: f64, t: f64 },
}

impl TraceEvent {
    /// The instant the event was recorded at (span end for spans).
    pub fn time(&self) -> f64 {
        match *self {
            TraceEvent::RoundStart { t, .. }
            | TraceEvent::RoundRequest { t, .. }
            | TraceEvent::RoundAccept { t, .. }
            | TraceEvent::RoundDecline { t, .. }
            | TraceEvent::RoundConfirm { t, .. }
            | TraceEvent::RoundEnd { t, .. }
            | TraceEvent::TaskReady { t, .. }
            | TraceEvent::ExecStart { t, .. }
            | TraceEvent::ExecEnd { t, .. }
            | TraceEvent::MigratedOut { t, .. }
            | TraceEvent::MigratedIn { t, .. }
            | TraceEvent::ResultReturned { t, .. }
            | TraceEvent::MsgFlight { t, .. } => t,
        }
    }
}

/// A pair-search round the recorder is still watching.
#[derive(Debug, Clone, Copy)]
struct OpenRound {
    round: u64,
    started: f64,
    /// Last request send instant (grant latency measures from here: with
    /// `tries` candidates per round, earlier requests were declined).
    requested: f64,
    /// Partner this round committed to via `PairConfirm` (initiator side).
    /// Round ids are per-process counters, so an `ExportAck` round number
    /// alone can collide with a foreign transaction this process merely
    /// served; requiring the ack to come from the confirmed partner keeps
    /// the busy-initiator close correct.
    confirmed_to: Option<ProcessId>,
}

/// Live recorder state (heap-allocated only when tracing is on).
#[derive(Debug)]
pub struct Recorder {
    events: Vec<TraceEvent>,
    /// Ready instant per task id (NaN = never seen here), for queue-wait.
    ready_at: Vec<f64>,
    open: Option<OpenRound>,
}

impl Recorder {
    fn new(num_tasks: usize) -> Self {
        Recorder { events: Vec::new(), ready_at: vec![f64::NAN; num_tasks], open: None }
    }

    fn close_round(&mut self, outcome: RoundOutcome, tasks: usize, t: f64) {
        if let Some(o) = self.open.take() {
            self.events.push(TraceEvent::RoundEnd {
                round: o.round,
                outcome,
                tasks,
                started: o.started,
                requested: o.requested,
                t,
            });
        }
    }
}

/// Per-process trace recorder: `Off` is free, `On` appends typed events.
///
/// Every hook takes the coordinator's current `now` and is a single
/// discriminant test when disabled.  The recorder must never be consulted
/// by the coordinator — information flows strictly *into* it.
#[derive(Debug)]
pub enum TraceRecorder {
    Off,
    On(Box<Recorder>),
}

impl TraceRecorder {
    pub fn new(enabled: bool, num_tasks: usize) -> Self {
        if enabled { TraceRecorder::On(Box::new(Recorder::new(num_tasks))) } else { TraceRecorder::Off }
    }

    #[inline]
    pub fn is_on(&self) -> bool {
        matches!(self, TraceRecorder::On(_))
    }

    /// Recorded events so far (empty slice when off).
    pub fn events(&self) -> &[TraceEvent] {
        match self {
            TraceRecorder::Off => &[],
            TraceRecorder::On(r) => &r.events,
        }
    }

    /// Drain the event stream (for the threaded runtime's result assembly).
    pub fn take_events(&mut self) -> Vec<TraceEvent> {
        match self {
            TraceRecorder::Off => Vec::new(),
            TraceRecorder::On(r) => std::mem::take(&mut r.events),
        }
    }

    // ---- task lifecycle ----------------------------------------------

    #[inline]
    pub fn task_ready(&mut self, task: TaskId, t: f64) {
        if let TraceRecorder::On(r) = self {
            if let Some(slot) = r.ready_at.get_mut(task.idx()) {
                *slot = t;
            }
            r.events.push(TraceEvent::TaskReady { task, t });
        }
    }

    #[inline]
    pub fn exec_start(&mut self, task: TaskId, t: f64) {
        if let TraceRecorder::On(r) = self {
            let ready = r.ready_at.get(task.idx()).copied().unwrap_or(f64::NAN);
            let queue_wait = if ready.is_finite() { (t - ready).max(0.0) } else { 0.0 };
            r.events.push(TraceEvent::ExecStart { task, queue_wait, t });
        }
    }

    #[inline]
    pub fn exec_end(&mut self, task: TaskId, duration: f64, t: f64) {
        if let TraceRecorder::On(r) = self {
            r.events.push(TraceEvent::ExecEnd { task, started: t - duration, t });
        }
    }

    #[inline]
    pub fn migrated_out(&mut self, task: TaskId, to: ProcessId, t: f64) {
        if let TraceRecorder::On(r) = self {
            r.events.push(TraceEvent::MigratedOut { task, to, t });
        }
    }

    #[inline]
    pub fn migrated_in(&mut self, task: TaskId, from: ProcessId, t: f64) {
        if let TraceRecorder::On(r) = self {
            r.events.push(TraceEvent::MigratedIn { task, from, t });
        }
    }

    #[inline]
    pub fn result_returned(&mut self, task: TaskId, t: f64) {
        if let TraceRecorder::On(r) = self {
            r.events.push(TraceEvent::ResultReturned { task, t });
        }
    }

    // ---- pair-search round lifecycle ---------------------------------

    /// Observe an outbound DLB message (called from the coordinator's
    /// policy-action interpreter, after the policy decided — never before,
    /// so RNG order is untouched).
    #[inline]
    pub fn protocol_send(&mut self, msg: &Msg, to: ProcessId, t: f64) {
        if let TraceRecorder::On(r) = self {
            match *msg {
                Msg::PairRequest { round, .. } | Msg::StealRequest { round, .. } => {
                    match r.open {
                        Some(ref mut o) if o.round == round => o.requested = t,
                        _ => {
                            // a still-open previous round never got a
                            // terminal message: a fresh search replaced it
                            r.close_round(RoundOutcome::Superseded, 0, t);
                            r.open = Some(OpenRound {
                                round,
                                started: t,
                                requested: t,
                                confirmed_to: None,
                            });
                            r.events.push(TraceEvent::RoundStart { round, t });
                        }
                    }
                    r.events.push(TraceEvent::RoundRequest { round, to, t });
                }
                Msg::PairConfirm { round, .. } => {
                    if let Some(ref mut o) = r.open {
                        if o.round == round {
                            o.confirmed_to = Some(to);
                        }
                    }
                    r.events.push(TraceEvent::RoundConfirm { round, to, t });
                }
                _ => {}
            }
        }
    }

    /// Observe an inbound DLB message (called from `on_message` before the
    /// policy sees it).
    #[inline]
    pub fn protocol_recv(&mut self, msg: &Msg, from: ProcessId, t: f64) {
        if let TraceRecorder::On(r) = self {
            match *msg {
                Msg::PairAccept { round, .. } => {
                    r.events.push(TraceEvent::RoundAccept { round, from, t });
                }
                Msg::PairDecline { round } => {
                    r.events.push(TraceEvent::RoundDecline { round, from, t });
                }
                // a busy-*initiated* round ends when the confirmed partner
                // acks the export shipped to it (idle-initiated rounds
                // close at TaskExport arrival via `round_granted`)
                Msg::ExportAck { round, accepted } => {
                    if matches!(r.open, Some(o) if o.round == round && o.confirmed_to == Some(from))
                    {
                        let outcome =
                            if accepted > 0 { RoundOutcome::Granted } else { RoundOutcome::Empty };
                        r.close_round(outcome, accepted, t);
                    }
                }
                _ => {}
            }
        }
    }

    /// The idle side's round ends when the export lands: `tasks` ready
    /// tasks arrived (0 ⇒ the victim had nothing to give).
    #[inline]
    pub fn round_granted(&mut self, round: u64, tasks: usize, t: f64) {
        if let TraceRecorder::On(r) = self {
            if matches!(r.open, Some(o) if o.round == round) {
                let outcome = if tasks > 0 { RoundOutcome::Granted } else { RoundOutcome::Empty };
                r.close_round(outcome, tasks, t);
            }
        }
    }

    /// Process halted: close any round still in flight.
    #[inline]
    pub fn run_end(&mut self, t: f64) {
        if let TraceRecorder::On(r) = self {
            r.close_round(RoundOutcome::Abandoned, 0, t);
        }
    }

    // ---- transport ----------------------------------------------------

    /// A message addressed here was delivered; `sent` is its engine-stamped
    /// send instant (DES only).
    #[inline]
    pub fn msg_flight(&mut self, kind: &'static str, from: ProcessId, sent: f64, t: f64) {
        if let TraceRecorder::On(r) = self {
            r.events.push(TraceEvent::MsgFlight { kind, from, sent, t });
        }
    }
}

/// All processes' event streams from one run, indexed by rank.
#[derive(Debug, Clone, Default)]
pub struct RunTrace {
    pub per_process: Vec<Vec<TraceEvent>>,
}

impl RunTrace {
    pub fn new(processes: usize) -> Self {
        RunTrace { per_process: vec![Vec::new(); processes] }
    }

    pub fn total_events(&self) -> usize {
        self.per_process.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total_events() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::message::Role;

    fn pid(i: u32) -> ProcessId {
        ProcessId(i)
    }

    #[test]
    fn off_recorder_records_nothing() {
        let mut rec = TraceRecorder::new(false, 8);
        assert!(!rec.is_on());
        rec.task_ready(TaskId(0), 0.0);
        rec.exec_start(TaskId(0), 0.1);
        rec.protocol_send(
            &Msg::PairRequest { round: 1, role: Role::Idle, load: 0, eta: 0.0 },
            pid(1),
            0.2,
        );
        rec.run_end(1.0);
        assert!(rec.events().is_empty());
        assert!(rec.take_events().is_empty());
    }

    #[test]
    fn task_lifecycle_computes_queue_wait() {
        let mut rec = TraceRecorder::new(true, 4);
        rec.task_ready(TaskId(2), 1.0);
        rec.exec_start(TaskId(2), 1.5);
        rec.exec_end(TaskId(2), 0.25, 1.75);
        let evs = rec.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[1], TraceEvent::ExecStart { task: TaskId(2), queue_wait: 0.5, t: 1.5 });
        assert_eq!(evs[2], TraceEvent::ExecEnd { task: TaskId(2), started: 1.5, t: 1.75 });
    }

    #[test]
    fn round_grant_measures_from_last_request() {
        let mut rec = TraceRecorder::new(true, 1);
        let req = |round| Msg::StealRequest { round, load: 0, eta: 0.0 };
        rec.protocol_send(&req(7), pid(1), 1.0);
        // declined, retry same round at a new victim
        rec.protocol_recv(&Msg::PairDecline { round: 7 }, pid(1), 1.2);
        rec.protocol_send(&req(7), pid(2), 1.3);
        rec.round_granted(7, 3, 1.9);
        let end = rec
            .events()
            .iter()
            .find_map(|e| match *e {
                TraceEvent::RoundEnd { round, outcome, tasks, started, requested, t } => {
                    Some((round, outcome, tasks, started, requested, t))
                }
                _ => None,
            })
            .expect("round must close");
        assert_eq!(end, (7, RoundOutcome::Granted, 3, 1.0, 1.3, 1.9));
    }

    #[test]
    fn new_round_supersedes_open_round() {
        let mut rec = TraceRecorder::new(true, 1);
        let req = |round| Msg::PairRequest { round, role: Role::Idle, load: 0, eta: 0.0 };
        rec.protocol_send(&req(1), pid(1), 0.5);
        rec.protocol_send(&req(2), pid(2), 0.9);
        rec.run_end(2.0);
        let outcomes: Vec<_> = rec
            .events()
            .iter()
            .filter_map(|e| match *e {
                TraceEvent::RoundEnd { round, outcome, .. } => Some((round, outcome)),
                _ => None,
            })
            .collect();
        assert_eq!(
            outcomes,
            vec![(1, RoundOutcome::Superseded), (2, RoundOutcome::Abandoned)]
        );
    }

    #[test]
    fn export_ack_closes_busy_side_round_only_if_confirmed() {
        let mut rec = TraceRecorder::new(true, 1);
        // an ack for a round this process never opened must be ignored
        rec.protocol_recv(&Msg::ExportAck { round: 9, accepted: 2 }, pid(1), 0.4);
        assert!(rec.events().is_empty());
        rec.protocol_send(
            &Msg::PairRequest { round: 3, role: Role::Busy, load: 8, eta: 0.0 },
            pid(1),
            1.0,
        );
        // foreign transaction that happens to share the round id: the ack
        // comes from a process this round never confirmed — ignore it
        rec.protocol_recv(&Msg::ExportAck { round: 3, accepted: 5 }, pid(2), 1.3);
        assert!(!rec.events().iter().any(|e| matches!(e, TraceEvent::RoundEnd { .. })));
        rec.protocol_send(&Msg::PairConfirm { round: 3, load: 8, eta: 0.0 }, pid(1), 1.4);
        rec.protocol_recv(&Msg::ExportAck { round: 3, accepted: 0 }, pid(1), 1.6);
        let last = *rec.events().last().expect("events");
        assert!(matches!(
            last,
            TraceEvent::RoundEnd { round: 3, outcome: RoundOutcome::Empty, tasks: 0, .. }
        ));
    }

    #[test]
    fn per_process_stream_is_time_monotone() {
        let mut rec = TraceRecorder::new(true, 4);
        rec.task_ready(TaskId(0), 0.0);
        rec.protocol_send(
            &Msg::StealRequest { round: 1, load: 0, eta: 0.0 },
            pid(1),
            0.2,
        );
        rec.msg_flight("task_export", pid(1), 0.2, 0.4);
        rec.round_granted(1, 1, 0.4);
        rec.exec_start(TaskId(0), 0.5);
        rec.exec_end(TaskId(0), 0.3, 0.8);
        rec.run_end(1.0);
        let times: Vec<f64> = rec.events().iter().map(TraceEvent::time).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "{times:?}");
    }
}
