//! Workload trace: the w_i(t) time series the paper plots in Figs 4–5.
//!
//! `w_i(t)` is the number of ready tasks in process i's queue (paper §3) —
//! recorded on every change, compressed to one sample per distinct time.

use crate::core::ids::ProcessId;

/// One process's workload history.
#[derive(Debug, Clone, Default)]
pub struct WorkloadTrace {
    /// (time, ready-queue length) samples, time-ordered.
    samples: Vec<(f64, usize)>,
    max_w: usize,
}

impl WorkloadTrace {
    pub fn new() -> Self {
        WorkloadTrace { samples: Vec::new(), max_w: 0 }
    }

    /// Record the workload at `t`; coalesces same-timestamp updates.
    pub fn record(&mut self, t: f64, w: usize) {
        self.max_w = self.max_w.max(w);
        if let Some(last) = self.samples.last_mut() {
            debug_assert!(t >= last.0, "time must be monotone");
            if (t - last.0).abs() < 1e-12 {
                last.1 = w;
                return;
            }
            if last.1 == w {
                return; // no change, no sample
            }
        }
        self.samples.push((t, w));
    }

    pub fn samples(&self) -> &[(f64, usize)] {
        &self.samples
    }

    /// Max workload over the whole run — the paper's `max_t w_i(t)`, used to
    /// calibrate W_T = max/2 (§6).
    pub fn max_workload(&self) -> usize {
        self.max_w
    }

    /// The workload at an arbitrary time (step function semantics).
    pub fn at(&self, t: f64) -> usize {
        match self.samples.binary_search_by(|s| s.0.partial_cmp(&t).expect("no NaN")) {
            Ok(i) => self.samples[i].1,
            Err(0) => 0,
            Err(i) => self.samples[i - 1].1,
        }
    }

    /// Time-weighted average workload over [t0, t1].
    pub fn time_average(&self, t0: f64, t1: f64) -> f64 {
        if t1 <= t0 || self.samples.is_empty() {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut prev_t = t0;
        let mut prev_w = self.at(t0) as f64;
        for &(t, w) in &self.samples {
            if t <= t0 {
                continue;
            }
            let tt = t.min(t1);
            acc += prev_w * (tt - prev_t);
            prev_t = tt;
            prev_w = w as f64;
            if t >= t1 {
                break;
            }
        }
        acc += prev_w * (t1 - prev_t).max(0.0);
        acc / (t1 - t0)
    }

    /// Resample to `n` equidistant points over [0, t_end] for plotting.
    pub fn resample(&self, t_end: f64, n: usize) -> Vec<(f64, f64)> {
        (0..n)
            .map(|i| {
                let t = t_end * i as f64 / (n - 1).max(1) as f64;
                (t, self.at(t) as f64)
            })
            .collect()
    }
}

/// Traces for every process in a run.
#[derive(Debug, Clone, Default)]
pub struct RunTraces {
    pub per_process: Vec<WorkloadTrace>,
    pub makespan: f64,
}

impl RunTraces {
    pub fn new(p: usize) -> Self {
        RunTraces { per_process: vec![WorkloadTrace::new(); p], makespan: 0.0 }
    }

    pub fn record(&mut self, p: ProcessId, t: f64, w: usize) {
        self.per_process[p.idx()].record(t, w);
        self.makespan = self.makespan.max(t);
    }

    /// Global max workload — W_T calibration input (§6: W_T = max/2).
    pub fn max_workload(&self) -> usize {
        self.per_process.iter().map(|t| t.max_workload()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_maxes() {
        let mut tr = WorkloadTrace::new();
        tr.record(0.0, 0);
        tr.record(1.0, 3);
        tr.record(2.0, 7);
        tr.record(3.0, 2);
        assert_eq!(tr.max_workload(), 7);
        assert_eq!(tr.at(0.5), 0);
        assert_eq!(tr.at(1.0), 3);
        assert_eq!(tr.at(2.5), 7);
        assert_eq!(tr.at(99.0), 2);
    }

    #[test]
    fn no_change_no_sample() {
        let mut tr = WorkloadTrace::new();
        tr.record(0.0, 1);
        tr.record(1.0, 1);
        tr.record(2.0, 2);
        assert_eq!(tr.samples().len(), 2);
    }

    #[test]
    fn same_time_coalesces() {
        let mut tr = WorkloadTrace::new();
        tr.record(1.0, 1);
        tr.record(1.0, 5);
        assert_eq!(tr.samples(), &[(1.0, 5)]);
    }

    #[test]
    fn time_average_step() {
        let mut tr = WorkloadTrace::new();
        tr.record(0.0, 2);
        tr.record(1.0, 4);
        // [0,1): 2, [1,2): 4 → avg 3 over [0,2]
        assert!((tr.time_average(0.0, 2.0) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn resample_endpoints() {
        let mut tr = WorkloadTrace::new();
        tr.record(0.0, 1);
        tr.record(10.0, 9);
        let r = tr.resample(10.0, 5);
        assert_eq!(r.len(), 5);
        assert_eq!(r[0], (0.0, 1.0));
        assert_eq!(r[4].1, 9.0);
    }

    #[test]
    fn run_traces_global_max() {
        let mut rt = RunTraces::new(2);
        rt.record(ProcessId(0), 1.0, 4);
        rt.record(ProcessId(1), 2.0, 9);
        assert_eq!(rt.max_workload(), 9);
        assert_eq!(rt.makespan, 2.0);
    }
}
