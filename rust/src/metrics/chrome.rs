//! Chrome trace-event / Perfetto exporter for recorder output.
//!
//! Emits the JSON Array-of-objects trace format that chrome://tracing and
//! https://ui.perfetto.dev load directly: one `pid` per simulated process,
//! three `tid` tracks each (protocol rounds, task execution, network
//! flights), "X" complete events for spans, "i" instants for handshake and
//! migration markers, and a "C" counter track carrying the ready-queue
//! depth (`w_i(t)`).  Timestamps are microseconds (the format's unit) from
//! the engine clock — virtual time in the DES, monotonic run time in the
//! threaded runtime.
//!
//! The writer puts one event object per line so [`validate_file`] — and
//! CI's smoke-trace step — can sanity-check an emitted file with the same
//! line-oriented `util::json::field` parser the bench baselines use,
//! without a JSON parser dependency.

use std::io::{BufWriter, Write};
use std::path::Path;

use super::recorder::{RunTrace, TraceEvent};
use super::trace::RunTraces;
use crate::util::error::{Error, Result};

/// `tid` of the protocol-round track.
const TID_PROTOCOL: u32 = 0;
/// `tid` of the task-execution track.
const TID_TASKS: u32 = 1;
/// `tid` of the network-flight track.
const TID_NET: u32 = 2;

fn us(t: f64) -> f64 {
    t * 1e6
}

/// Write one run's trace (plus queue-depth counters from the workload
/// traces) as Chrome trace-event JSON.
pub fn write_trace(
    path: impl AsRef<Path>,
    run: &RunTrace,
    workloads: &RunTraces,
) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[")?;
    let mut first = true;
    let mut emit = |w: &mut BufWriter<std::fs::File>, line: String| -> std::io::Result<()> {
        if first {
            first = false;
            writeln!(w, "{line}")
        } else {
            writeln!(w, ",{line}")
        }
    };

    for (pid, evs) in run.per_process.iter().enumerate() {
        emit(
            &mut w,
            format!(
                "{{\"ph\":\"M\",\"pid\":{pid},\"name\":\"process_name\",\"args\":{{\"name\":\"rank {pid}\"}}}}"
            ),
        )?;
        for (tid, tname) in
            [(TID_PROTOCOL, "protocol"), (TID_TASKS, "tasks"), (TID_NET, "net")]
        {
            emit(
                &mut w,
                format!(
                    "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\"args\":{{\"name\":\"{tname}\"}}}}"
                ),
            )?;
        }
        for e in evs {
            let line = match *e {
                TraceEvent::RoundEnd { round, outcome, tasks, started, t, .. } => format!(
                    "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{TID_PROTOCOL},\"name\":\"round\",\"ts\":{:.3},\"dur\":{:.3},\"args\":{{\"round\":{round},\"outcome\":\"{}\",\"tasks\":{tasks}}}}}",
                    us(started),
                    us((t - started).max(0.0)),
                    outcome.name(),
                ),
                TraceEvent::RoundStart { .. } => continue, // folded into the RoundEnd span
                TraceEvent::RoundRequest { round, to, t } => instant(
                    pid, TID_PROTOCOL, "request", t,
                    format!("\"round\":{round},\"peer\":{}", to.0),
                ),
                TraceEvent::RoundAccept { round, from, t } => instant(
                    pid, TID_PROTOCOL, "accept", t,
                    format!("\"round\":{round},\"peer\":{}", from.0),
                ),
                TraceEvent::RoundDecline { round, from, t } => instant(
                    pid, TID_PROTOCOL, "decline", t,
                    format!("\"round\":{round},\"peer\":{}", from.0),
                ),
                TraceEvent::RoundConfirm { round, to, t } => instant(
                    pid, TID_PROTOCOL, "confirm", t,
                    format!("\"round\":{round},\"peer\":{}", to.0),
                ),
                TraceEvent::ExecEnd { task, started, t } => format!(
                    "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{TID_TASKS},\"name\":\"exec\",\"ts\":{:.3},\"dur\":{:.3},\"args\":{{\"task\":{}}}}}",
                    us(started),
                    us((t - started).max(0.0)),
                    task.0,
                ),
                // ready/start feed the queue-wait histogram; the span view
                // only needs the ExecEnd-carried interval
                TraceEvent::TaskReady { .. } | TraceEvent::ExecStart { .. } => continue,
                TraceEvent::MigratedOut { task, to, t } => instant(
                    pid, TID_TASKS, "migrated_out", t,
                    format!("\"task\":{},\"peer\":{}", task.0, to.0),
                ),
                TraceEvent::MigratedIn { task, from, t } => instant(
                    pid, TID_TASKS, "migrated_in", t,
                    format!("\"task\":{},\"peer\":{}", task.0, from.0),
                ),
                TraceEvent::ResultReturned { task, t } => instant(
                    pid, TID_TASKS, "result_returned", t,
                    format!("\"task\":{}", task.0),
                ),
                TraceEvent::MsgFlight { kind, from, sent, t } => format!(
                    "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{TID_NET},\"name\":\"{kind}\",\"ts\":{:.3},\"dur\":{:.3},\"args\":{{\"from\":{}}}}}",
                    us(sent),
                    us((t - sent).max(0.0)),
                    from.0,
                ),
            };
            emit(&mut w, line)?;
        }
    }

    // queue-depth counter tracks from the w_i(t) step functions
    for (pid, tr) in workloads.per_process.iter().enumerate() {
        for &(t, depth) in tr.samples() {
            emit(
                &mut w,
                format!(
                    "{{\"ph\":\"C\",\"pid\":{pid},\"name\":\"queue depth\",\"ts\":{:.3},\"args\":{{\"ready\":{depth}}}}}",
                    us(t),
                ),
            )?;
        }
    }

    writeln!(w, "]}}")?;
    w.flush()
}

fn instant(pid: usize, tid: u32, name: &str, t: f64, args: String) -> String {
    format!(
        "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{tid},\"name\":\"{name}\",\"ts\":{:.3},\"s\":\"t\",\"args\":{{{args}}}}}",
        us(t),
    )
}

/// Shape summary of an emitted trace file, from the line-oriented parser.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct TraceStats {
    pub total: usize,
    pub spans: usize,
    pub instants: usize,
    pub counters: usize,
    pub metadata: usize,
    /// Distinct event names seen (round, exec, pair_request, ...).
    pub names: usize,
}

/// Validate a trace file written by [`write_trace`]: the envelope must be
/// present, every event line must parse, and there must be at least one
/// non-metadata event.  Returns counts per event phase for reporting.
pub fn validate_file(path: impl AsRef<Path>) -> Result<TraceStats> {
    let path = path.as_ref();
    let body = std::fs::read_to_string(path)
        .map_err(|e| Error::msg(format!("cannot read trace {}: {e}", path.display())))?;
    if !body.contains("\"traceEvents\"") {
        return Err(Error::msg(format!("{}: missing traceEvents envelope", path.display())));
    }
    if !body.trim_end().ends_with("]}") {
        return Err(Error::msg(format!("{}: truncated (no closing ]}})", path.display())));
    }
    let mut stats = TraceStats::default();
    let mut names = std::collections::BTreeSet::new();
    for line in body.lines() {
        let Some(ph) = crate::util::json::field(line, "ph") else { continue };
        let trimmed = line.trim_start_matches(',').trim();
        if !trimmed.starts_with('{') || !trimmed.ends_with("}") {
            return Err(Error::msg(format!("{}: malformed event line: {line}", path.display())));
        }
        stats.total += 1;
        match ph {
            "X" => stats.spans += 1,
            "i" => stats.instants += 1,
            "C" => stats.counters += 1,
            "M" => stats.metadata += 1,
            other => {
                return Err(Error::msg(format!(
                    "{}: unexpected event phase {other:?}",
                    path.display()
                )))
            }
        }
        if ph != "M" {
            if let Some(name) = crate::util::json::field(line, "name") {
                names.insert(name.to_string());
            }
        }
    }
    stats.names = names.len();
    if stats.total - stats.metadata == 0 {
        return Err(Error::msg(format!("{}: no events beyond metadata", path.display())));
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ids::{ProcessId, TaskId};
    use crate::metrics::recorder::{RoundOutcome, TraceRecorder};
    use crate::net::message::{Msg, Role};

    fn sample_trace() -> (RunTrace, RunTraces) {
        let mut rec = TraceRecorder::new(true, 4);
        rec.task_ready(TaskId(0), 0.0);
        rec.protocol_send(
            &Msg::PairRequest { round: 1, role: Role::Idle, load: 0, eta: 0.0 },
            ProcessId(1),
            1.0e-4,
        );
        rec.protocol_recv(&Msg::PairAccept { round: 1, load: 5, eta: 0.0 }, ProcessId(1), 2.0e-4);
        rec.protocol_send(&Msg::PairConfirm { round: 1, load: 0, eta: 0.0 }, ProcessId(1), 2.1e-4);
        rec.msg_flight("task_export", ProcessId(1), 2.5e-4, 3.0e-4);
        rec.migrated_in(TaskId(2), ProcessId(1), 3.0e-4);
        rec.round_granted(1, 1, 3.0e-4);
        rec.exec_start(TaskId(0), 4.0e-4);
        rec.exec_end(TaskId(0), 2.0e-4, 6.0e-4);
        rec.run_end(1.0e-3);

        let mut run = RunTrace::new(2);
        run.per_process[0] = rec.take_events();
        let mut wl = RunTraces::new(2);
        wl.record(ProcessId(0), 0.0, 1);
        wl.record(ProcessId(0), 6.0e-4, 0);
        wl.record(ProcessId(1), 0.0, 3);
        (run, wl)
    }

    #[test]
    fn roundtrip_write_then_validate() {
        let (run, wl) = sample_trace();
        let p = std::env::temp_dir().join("ductr_chrome_rt.json");
        write_trace(&p, &run, &wl).expect("write");
        let body = std::fs::read_to_string(&p).expect("read");
        assert!(body.starts_with("{\"displayTimeUnit\""));
        // spans: round + exec + flight; counter samples: 3
        let stats = validate_file(&p).expect("valid");
        assert_eq!(stats.spans, 3);
        assert!(stats.instants >= 4, "{stats:?}"); // request/accept/confirm/migrated_in
        assert_eq!(stats.counters, 3);
        // ≥ 4 distinct event types: round, exec, task_export, queue depth, ...
        assert!(stats.names >= 4, "{stats:?}");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn spans_fold_start_into_end() {
        let (run, wl) = sample_trace();
        assert!(run
            .per_process[0]
            .iter()
            .any(|e| matches!(e, TraceEvent::RoundEnd { outcome: RoundOutcome::Granted, .. })));
        let p = std::env::temp_dir().join("ductr_chrome_spans.json");
        write_trace(&p, &run, &wl).expect("write");
        let body = std::fs::read_to_string(&p).expect("read");
        let round_line = body
            .lines()
            .find(|l| l.contains("\"name\":\"round\""))
            .expect("round span present");
        assert_eq!(crate::util::json::field(round_line, "ph"), Some("X"));
        // round 1 opened at 100µs, granted at 300µs
        assert_eq!(crate::util::json::field(round_line, "ts"), Some("100.000"));
        assert_eq!(crate::util::json::field(round_line, "dur"), Some("200.000"));
        assert!(round_line.contains("\"outcome\":\"granted\""));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn validate_rejects_garbage_and_empty() {
        let p = std::env::temp_dir().join("ductr_chrome_bad.json");
        std::fs::write(&p, "not json at all").expect("write");
        assert!(validate_file(&p).is_err());
        std::fs::write(&p, "{\"traceEvents\":[\n]}\n").expect("write");
        assert!(validate_file(&p).is_err(), "no events must fail");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn empty_processes_still_produce_valid_envelope_with_counters() {
        let run = RunTrace::new(1);
        let mut wl = RunTraces::new(1);
        wl.record(ProcessId(0), 0.0, 2);
        let p = std::env::temp_dir().join("ductr_chrome_empty.json");
        write_trace(&p, &run, &wl).expect("write");
        let stats = validate_file(&p).expect("valid");
        assert_eq!(stats.spans, 0);
        assert_eq!(stats.counters, 1);
        let _ = std::fs::remove_file(p);
    }
}
