//! Log-bucketed latency histograms over recorder events.
//!
//! The recorder ([`super::recorder`]) captures raw spans; this module folds
//! them into fixed-size log₂ histograms so `ductr run`/`compare`/`bench`
//! can print p50/p95/p99 without keeping every sample.  Buckets cover
//! 1 ns .. ~1100 s with 4 sub-buckets per octave (≈ 19 % relative
//! resolution), which is far finer than the scheduling noise of either
//! engine.  Histograms merge associatively, so per-process recorders can
//! be folded into one run-wide report in any order.

use super::recorder::{RoundOutcome, RunTrace, TraceEvent};

/// Smallest distinguishable latency: everything at or below lands in
/// bucket 0.
const MIN_LAT: f64 = 1e-9;
/// Sub-buckets per factor-of-two.
const SUB: usize = 4;
/// 40 octaves × 4 ⇒ 1 ns .. ~1100 s before the overflow bucket.
const BUCKETS: usize = 40 * SUB;

/// Fixed-memory log₂ latency histogram with exact count/sum/min/max.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

fn bucket_of(x: f64) -> usize {
    if x <= MIN_LAT {
        return 0;
    }
    (((x / MIN_LAT).log2() * SUB as f64) as usize).min(BUCKETS - 1)
}

/// Upper edge of bucket `i` in seconds.
fn bucket_hi(i: usize) -> f64 {
    MIN_LAT * ((i + 1) as f64 / SUB as f64).exp2()
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency in seconds.  Negative and non-finite samples are
    /// dropped (they indicate a recorder bug, which the property tests
    /// catch directly on the raw events).
    pub fn record(&mut self, x: f64) {
        if !x.is_finite() || x < 0.0 {
            return;
        }
        self.buckets[bucket_of(x)] += 1;
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.sum / self.count as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.max }
    }

    /// Quantile estimate (`q` in [0, 1]): walk the cumulative bucket counts
    /// and report the matched bucket's upper edge, clamped to the exact
    /// observed [min, max].  NaN on an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return bucket_hi(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn merge(&mut self, o: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&o.buckets) {
            *a += b;
        }
        self.count += o.count;
        self.sum += o.sum;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

/// Seconds formatted with an auto-scaled unit; `—` for NaN (empty sample).
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "—".to_string();
    }
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// The four standing latency distributions of one run.
#[derive(Debug, Clone, Default)]
pub struct LatencyReport {
    /// Pair-search round latency: `RoundStart` → terminal outcome.
    pub round: LatencyHistogram,
    /// Steal-grant latency: last request sent → tasks arrived (granted
    /// rounds only).
    pub grant: LatencyHistogram,
    /// Task queue wait: ready → execution start.
    pub queue_wait: LatencyHistogram,
    /// Message flight time: send → delivery (DES only; the threaded
    /// runtime's channels have no stamped send time).
    pub flight: LatencyHistogram,
}

impl LatencyReport {
    /// Fold every process's recorded events into run-wide histograms.
    pub fn from_trace(rt: &RunTrace) -> LatencyReport {
        let mut rep = LatencyReport::default();
        for evs in &rt.per_process {
            for e in evs {
                match *e {
                    TraceEvent::RoundEnd { outcome, started, requested, t, .. } => {
                        rep.round.record(t - started);
                        if outcome == RoundOutcome::Granted {
                            rep.grant.record(t - requested);
                        }
                    }
                    TraceEvent::ExecStart { queue_wait, .. } => {
                        rep.queue_wait.record(queue_wait);
                    }
                    TraceEvent::MsgFlight { sent, t, .. } => {
                        rep.flight.record(t - sent);
                    }
                    _ => {}
                }
            }
        }
        rep
    }

    /// Quick-look table: one line per distribution with n/p50/p95/p99/max.
    pub fn render(&self) -> String {
        let mut s = String::from("latency            n        p50        p95        p99        max\n");
        for (name, h) in [
            ("round", &self.round),
            ("grant", &self.grant),
            ("queue-wait", &self.queue_wait),
            ("msg-flight", &self.flight),
        ] {
            s.push_str(&format!(
                "  {:<12} {:>8} {:>10} {:>10} {:>10} {:>10}\n",
                name,
                h.count(),
                fmt_secs(h.quantile(0.50)),
                fmt_secs(h.quantile(0.95)),
                fmt_secs(h.quantile(0.99)),
                fmt_secs(h.max()),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_nan_everywhere() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.quantile(0.5).is_nan());
        assert!(h.mean().is_nan());
        assert!(h.min().is_nan() && h.max().is_nan());
        assert_eq!(fmt_secs(h.quantile(0.5)), "—");
    }

    #[test]
    fn quantiles_bracket_the_sample_within_bucket_resolution() {
        let mut h = LatencyHistogram::new();
        // 1..=1000 µs uniform
        for i in 1..=1000 {
            h.record(i as f64 * 1e-6);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        // log buckets at 4/octave have ≤ 19% upward error
        assert!(p50 >= 500e-6 && p50 <= 500e-6 * 1.2, "p50={p50}");
        assert!(p99 >= 990e-6 && p99 <= 990e-6 * 1.2, "p99={p99}");
        assert!(p50 <= h.quantile(0.95) && h.quantile(0.95) <= p99);
        assert!((h.mean() - 500.5e-6).abs() < 1e-9);
        assert_eq!(h.min(), 1e-6);
        assert_eq!(h.max(), 1000e-6);
    }

    #[test]
    fn quantile_clamps_to_observed_range() {
        let mut h = LatencyHistogram::new();
        h.record(3.0e-3);
        assert_eq!(h.quantile(0.0), 3.0e-3);
        assert_eq!(h.quantile(0.5), 3.0e-3);
        assert_eq!(h.quantile(1.0), 3.0e-3);
    }

    #[test]
    fn zero_and_tiny_latencies_land_in_bucket_zero() {
        let mut h = LatencyHistogram::new();
        h.record(0.0);
        h.record(1e-12);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.99) <= MIN_LAT * 2.0);
    }

    #[test]
    fn invalid_samples_are_dropped() {
        let mut h = LatencyHistogram::new();
        h.record(-1.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn merge_equals_single_pass() {
        let xs: Vec<f64> = (1..200).map(|i| i as f64 * 7.3e-7).collect();
        let mut whole = LatencyHistogram::new();
        for &x in &xs {
            whole.record(x);
        }
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for &x in &xs[..71] {
            a.record(x);
        }
        for &x in &xs[71..] {
            b.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.quantile(0.5), whole.quantile(0.5));
        assert_eq!(a.quantile(0.99), whole.quantile(0.99));
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn fmt_secs_picks_sane_units() {
        assert_eq!(fmt_secs(2.5), "2.500s");
        assert_eq!(fmt_secs(2.5e-3), "2.500ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500µs");
        assert_eq!(fmt_secs(2.5e-9), "2.5ns");
    }
}
