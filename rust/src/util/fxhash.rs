//! Vendored FxHash — the rustc/Firefox multiply-rotate hash — for maps and
//! sets keyed by small integers where SipHash's DoS resistance buys nothing
//! and its per-lookup cost is measurable (the offline crate set has no
//! `rustc-hash`).
//!
//! Not DoS-resistant: use only on keys an attacker does not control (dense
//! internal ids, process indices).  For the truly hot, fully dense tables
//! the runtime goes further and uses plain `Vec` indexing (see
//! `core::data::DataStore`); `Fx*` is for the cases where keys are sparse
//! or unbounded.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// A `HashMap` seeded with [`FxHasher`].
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
/// A `HashSet` seeded with [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;
/// Zero-sized default-seeding builder (deterministic across runs, unlike
/// `RandomState` — which also matters for reproducible simulations).
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;
const ROTATE: u32 = 5;

/// The FxHash state machine: `hash = (hash.rotate_left(5) ^ word) * SEED`
/// per input word.
#[derive(Debug, Clone, Copy, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(ROTATE) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Consume the tail as 4/2/1-byte reads (as rustc-hash does) rather
        // than zero-padding one word: padding would hash e.g. "ab" and
        // "ab\0" identically.
        let mut b = bytes;
        while b.len() >= 8 {
            self.add_to_hash(u64::from_le_bytes(b[..8].try_into().expect("8 bytes")));
            b = &b[8..];
        }
        if b.len() >= 4 {
            self.add_to_hash(u32::from_le_bytes(b[..4].try_into().expect("4 bytes")) as u64);
            b = &b[4..];
        }
        if b.len() >= 2 {
            self.add_to_hash(u16::from_le_bytes(b[..2].try_into().expect("2 bytes")) as u64);
            b = &b[2..];
        }
        if let Some(&x) = b.first() {
            self.add_to_hash(x as u64);
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<u32, &str> = FxHashMap::default();
        m.insert(3, "three");
        m.insert(u32::MAX, "max");
        assert_eq!(m.get(&3), Some(&"three"));
        assert_eq!(m.get(&u32::MAX), Some(&"max"));
        assert_eq!(m.len(), 2);
        assert!(m.remove(&3).is_some());
        assert!(!m.contains_key(&3));
    }

    #[test]
    fn set_membership() {
        let s: FxHashSet<usize> = (0..100).filter(|i| i % 3 == 0).collect();
        assert!(s.contains(&99));
        assert!(!s.contains(&98));
        assert_eq!(s.len(), 34);
    }

    #[test]
    fn deterministic_across_hasher_instances() {
        use std::hash::Hash;
        let hash_of = |x: u64| {
            let mut h = FxHasher::default();
            x.hash(&mut h);
            h.finish()
        };
        assert_eq!(hash_of(42), hash_of(42));
        assert_ne!(hash_of(42), hash_of(43));
    }

    #[test]
    fn byte_streams_differing_only_in_tail_differ() {
        let hash_bytes = |b: &[u8]| {
            let mut h = FxHasher::default();
            h.write(b);
            h.finish()
        };
        assert_ne!(hash_bytes(b"123456789"), hash_bytes(b"123456780"));
        assert_ne!(hash_bytes(b"12345678"), hash_bytes(b"12345678\0"));
    }
}
