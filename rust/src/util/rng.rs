//! Deterministic, seedable PRNG (xoshiro256** seeded via splitmix64).
//!
//! The `rand` crate is not available offline, and determinism is a design
//! requirement anyway (DESIGN.md §7.6): every stochastic choice in an
//! experiment flows from one `u64` seed so that runs — including the
//! "lucky/unlucky" pair of Fig 5 — are bit-reproducible.

/// splitmix64 step; used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Build from a single seed via splitmix64 (the reference seeding scheme).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per simulated process).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// to avoid modulo bias. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Sample `k` **distinct** values from `0..n`, excluding `exclude`.
    ///
    /// This is the paper's partner-draw: `n` tries without replacement
    /// (the hypergeometric model of eq. (1) assumes no-replacement draws).
    /// Floyd's algorithm over an *implicit* pool — O(k²) worst case for the
    /// duplicate scan (k ≤ tries = 5 in practice), **zero allocation beyond
    /// the result**: the exclusion is handled by index remapping instead of
    /// materializing the filtered pool (§Perf: the pool allocation dominated
    /// the pairing round at P = 128).
    pub fn sample_distinct(&mut self, n: usize, k: usize, exclude: Option<usize>) -> Vec<usize> {
        // implicit pool = 0..m, remapped around the excluded element
        let (m, remap) = match exclude {
            Some(e) if e < n => (n - 1, Some(e)),
            _ => (n, None),
        };
        let k = k.min(m);
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (m - k)..m {
            let t = self.range_usize(0, j + 1);
            let cand = if chosen.contains(&t) { j } else { t };
            debug_assert!(!chosen.contains(&cand));
            chosen.push(cand);
        }
        if let Some(e) = remap {
            for x in chosen.iter_mut() {
                if *x >= e {
                    *x += 1;
                }
            }
        }
        chosen
    }

    /// Exponential variate with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.next_f64(); // (0, 1]
        -u.ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_under_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values of 0..10 hit in 1000 draws");
    }

    #[test]
    fn gen_range_unbiased_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: u64 = (0..n).map(|_| r.gen_range(100)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 49.5).abs() < 0.5, "mean {mean}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(5);
        for _ in 0..200 {
            let n = r.range_usize(2, 50);
            let k = r.range_usize(1, n);
            let ex = r.range_usize(0, n);
            let s = r.sample_distinct(n, k, Some(ex));
            assert_eq!(s.len(), k.min(n - 1));
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), s.len(), "distinct");
            assert!(!s.contains(&ex), "excluded");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn sample_distinct_k_exceeds_pool() {
        let mut r = Rng::new(9);
        let s = r.sample_distinct(4, 10, Some(0));
        let mut sorted = s.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![1, 2, 3]);
    }

    #[test]
    fn sample_distinct_uniformity() {
        // every element of 0..10 (minus exclude) appears ~equally often
        let mut r = Rng::new(13);
        let mut counts = [0u32; 10];
        for _ in 0..20_000 {
            for x in r.sample_distinct(10, 3, Some(9)) {
                counts[x] += 1;
            }
        }
        assert_eq!(counts[9], 0);
        let expect = 20_000.0 * 3.0 / 9.0;
        for &c in &counts[..9] {
            assert!((c as f64 - expect).abs() < expect * 0.1, "count {c} vs {expect}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(23);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(29);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
