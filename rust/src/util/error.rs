//! Minimal vendored replacement for the `anyhow` crate.
//!
//! The build is fully offline (DESIGN.md §0: no external dependencies), so
//! the tiny subset of `anyhow` this codebase actually uses is provided
//! here: a string-backed [`Error`], a defaulted [`Result`] alias, the
//! [`Context`] extension trait, and the [`anyhow!`]/[`bail!`] macros
//! (exported at the crate root, as macros must be).
//!
//! Mirroring `anyhow`'s design, [`Error`] deliberately does **not**
//! implement `std::error::Error`: that is what makes the blanket
//! `From<E: std::error::Error>` impl coexist with the reflexive
//! `From<Error> for Error` from `core`.

use std::fmt;

/// A string-backed error value, `anyhow::Error`-shaped.
pub struct Error {
    msg: String,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build from a preformatted message (what `anyhow!` expands to).
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into() }
    }

    /// Build from any displayable error value.
    pub fn new<E: fmt::Display>(e: E) -> Self {
        Error { msg: e.to_string() }
    }

    /// Prepend a context line, innermost cause last.
    pub fn context(self, c: impl fmt::Display) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::new(e)
    }
}

/// `.context(...)` / `.with_context(|| ...)` on any displayable-error
/// `Result`, matching the `anyhow::Context` call sites in this crate.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Format an [`Error`] from a message, `anyhow!`-style.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(::std::format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`], `bail!`-style.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().expect_err("must fail");
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), String> = Err("inner".to_string());
        let e = r.context("outer").expect_err("err");
        assert_eq!(e.to_string(), "outer: inner");
        let r: std::result::Result<(), String> = Err("inner".to_string());
        let e = r.with_context(|| format!("outer {}", 2)).expect_err("err");
        assert_eq!(e.to_string(), "outer 2: inner");
    }

    #[test]
    fn macros_format() {
        let e = crate::anyhow!("bad value {}", 3);
        assert_eq!(e.to_string(), "bad value 3");
        fn f() -> Result<()> {
            crate::bail!("nope {}", "x");
        }
        assert_eq!(f().expect_err("err").to_string(), "nope x");
    }

    #[test]
    fn error_passes_through_question_mark() {
        fn inner() -> Result<()> {
            Err(Error::msg("boom"))
        }
        fn outer() -> Result<()> {
            inner()?;
            Ok(())
        }
        assert_eq!(outer().expect_err("err").to_string(), "boom");
    }
}
