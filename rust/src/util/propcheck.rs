//! Minimal property-based testing framework (proptest is unavailable
//! offline).
//!
//! Deterministic by construction: each case derives from a case index and a
//! base seed, so a failure report ("case #k, seed s") is immediately
//! reproducible.  On failure the runner performs *input-size shrinking* for
//! the common generator shapes (vectors shrink by halving, integers shrink
//! toward the range minimum) by re-running the property on derived smaller
//! inputs.
//!
//! Usage:
//! ```no_run
//! use ductr::util::propcheck::{forall, Gen};
//! forall(200, 0xDEC0DE, |g| g.vec_usize(0..64, 0..100), |v| {
//!     let mut s = v.clone();
//!     s.sort_unstable();
//!     s.len() == v.len()
//! });
//! ```

use std::fmt::Debug;
use std::ops::Range;

use crate::util::rng::Rng;

/// Generator context handed to the generating closure.
pub struct Gen {
    rng: Rng,
    /// Size hint in `[0, 1]`; grows over the case sequence so early cases are
    /// small (fast failure on trivial inputs) and later cases are large.
    pub size: f64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end, "empty range");
        // bias the width by the size hint
        let span = (r.end - r.start).max(1);
        let scaled = ((span as f64 * self.size).ceil() as usize).clamp(1, span);
        r.start + self.rng.gen_range(scaled as u64) as usize
    }

    pub fn u64_in(&mut self, r: Range<u64>) -> u64 {
        assert!(r.start < r.end);
        r.start + self.rng.gen_range(r.end - r.start)
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        self.rng.range_f64(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn vec_usize(&mut self, len: Range<usize>, vals: Range<usize>) -> Vec<usize> {
        let n = self.usize_in(len.start.max(0)..len.end.max(1));
        (0..n).map(|_| self.rng.range_usize(vals.start, vals.end)).collect()
    }

    pub fn vec_f64(&mut self, len: Range<usize>, vals: Range<f64>) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.range_f64(vals.start, vals.end)).collect()
    }
}

/// Outcome of a property over one input.
pub trait PropResult {
    fn passed(&self) -> bool;
    fn message(&self) -> String;
}

impl PropResult for bool {
    fn passed(&self) -> bool {
        *self
    }
    fn message(&self) -> String {
        if *self { "ok".into() } else { "property returned false".into() }
    }
}

impl PropResult for Result<(), String> {
    fn passed(&self) -> bool {
        self.is_ok()
    }
    fn message(&self) -> String {
        match self {
            Ok(()) => "ok".into(),
            Err(e) => e.clone(),
        }
    }
}

/// Run `prop` over `cases` inputs produced by `gen`. Panics with a
/// reproducible report on the first failure.
pub fn forall<T, G, P, R>(cases: usize, seed: u64, mut gen: G, mut prop: P)
where
    T: Debug + Clone,
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> R,
    R: PropResult,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen {
            rng: Rng::new(case_seed),
            size: ((case + 1) as f64 / cases as f64).min(1.0),
        };
        let input = gen(&mut g);
        let r = prop(&input);
        if !r.passed() {
            // one-level shrink attempt: re-generate with smaller sizes
            let mut smallest: Option<T> = None;
            for shrink_step in 1..=8 {
                let mut gs = Gen {
                    rng: Rng::new(case_seed),
                    size: g.size / (1 << shrink_step) as f64,
                };
                let cand = gen(&mut gs);
                if !prop(&cand).passed() {
                    smallest = Some(cand);
                }
            }
            panic!(
                "property failed at case #{case} (seed {case_seed:#x}): {}\n  input: {:?}\n  shrunk: {:?}",
                r.message(),
                input,
                smallest,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            50,
            1,
            |g| g.vec_usize(0..32, 0..100),
            |v| {
                count += 1;
                let mut s = v.clone();
                s.sort_unstable();
                s.len() == v.len()
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_report() {
        // u64_in is not size-scaled, so some case almost surely lands ≥ 5
        forall(100, 2, |g| g.u64_in(0..100), |&x| x < 5);
    }

    #[test]
    fn result_prop_messages() {
        forall(
            10,
            3,
            |g| g.u64_in(0..10),
            |&x| -> Result<(), String> {
                if x < 10 { Ok(()) } else { Err(format!("{x} out of range")) }
            },
        );
    }

    #[test]
    fn sizes_grow() {
        let mut maxlen = 0;
        forall(
            100,
            4,
            |g| g.vec_usize(0..256, 0..2),
            |v| {
                maxlen = maxlen.max(v.len());
                true
            },
        );
        assert!(maxlen > 64, "late cases should be large, got max {maxlen}");
    }
}
