//! Minimal line-oriented JSON field extraction (the offline crate set has
//! no serde).  Works on the one-object-per-line layout that every hand-
//! rolled writer in this repo emits (`experiments/bench.rs` baselines,
//! `metrics/chrome.rs` trace events), so readers can validate or diff
//! generated artifacts without a parser dependency.

/// Extract `"key": <value>` from a single JSON-object line.  Quoted string
/// values are returned without their quotes; bare values (numbers, bools)
/// are returned trimmed, terminated by `,` or `}`.
pub fn field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)? + pat.len();
    let rest = line[at..].trim_start();
    if let Some(stripped) = rest.strip_prefix('"') {
        return Some(&stripped[..stripped.find('"')?]);
    }
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    Some(rest[..end].trim())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_extracts_strings_numbers_bools() {
        let line = r#"    {"name": "cholesky nb=8 P=4", "coalesce": true, "events": 123, "events_per_sec": 4567.8},"#;
        assert_eq!(field(line, "name"), Some("cholesky nb=8 P=4"));
        assert_eq!(field(line, "coalesce"), Some("true"));
        assert_eq!(field(line, "events"), Some("123"));
        assert_eq!(field(line, "events_per_sec"), Some("4567.8"));
        assert_eq!(field(line, "absent"), None);
    }

    #[test]
    fn field_handles_chrome_trace_lines() {
        let line = r#"{"ph":"X","pid":3,"tid":1,"name":"exec","ts":12.500,"dur":4.250,"args":{"task":17}},"#;
        assert_eq!(field(line, "ph"), Some("X"));
        assert_eq!(field(line, "pid"), Some("3"));
        assert_eq!(field(line, "ts"), Some("12.500"));
        assert_eq!(field(line, "dur"), Some("4.250"));
    }

    #[test]
    fn field_tolerates_unterminated_values() {
        assert_eq!(field(r#"{"k": 12"#, "k"), Some("12"));
        assert_eq!(field(r#"{"k": "unclosed"#, "k"), None);
    }
}
