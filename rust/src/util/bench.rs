//! Criterion-style micro/macro benchmark harness (criterion is unavailable
//! offline; this provides the subset we need with robust statistics).
//!
//! Every `rust/benches/*.rs` target is a `harness = false` binary built on
//! this module.  Protocol per benchmark:
//!
//! 1. warm up for `warmup` iterations (or until `min_warmup_time`),
//! 2. collect `samples` timed samples of `iters_per_sample` iterations,
//! 3. report mean ± 95% CI, median, p05/p95 from `util::stats::Summary`.
//!
//! `Runner` collects rows and prints an aligned table, plus optional CSV next
//! to the binary for EXPERIMENTS.md.

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::stats::Summary;

pub use std::hint::black_box as bb;

/// Configuration for one benchmark run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: u64,
    pub samples: usize,
    pub iters_per_sample: u64,
    pub min_warmup_time: Duration,
    pub max_total_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            samples: 20,
            iters_per_sample: 1,
            min_warmup_time: Duration::from_millis(20),
            max_total_time: Duration::from_secs(60),
        }
    }
}

impl BenchConfig {
    /// Fast profile for heavy end-to-end benches (few samples).
    pub fn macro_bench() -> Self {
        BenchConfig { warmup_iters: 1, samples: 5, ..Default::default() }
    }

    /// High-resolution profile for nanosecond-scale hot-path benches.
    pub fn micro_bench() -> Self {
        BenchConfig {
            warmup_iters: 1000,
            samples: 30,
            iters_per_sample: 10_000,
            ..Default::default()
        }
    }
}

/// Result of one benchmark: per-sample seconds-per-iteration.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// Optional user metric (e.g. makespan seconds, tasks migrated).
    pub extra: Vec<(String, f64)>,
}

impl BenchResult {
    pub fn secs_per_iter(&self) -> f64 {
        self.summary.mean
    }
}

/// Time `f` under `cfg`, returning per-iteration seconds samples.
pub fn run_with<F: FnMut() -> R, R>(cfg: &BenchConfig, name: &str, mut f: F) -> BenchResult {
    // Warmup: at least warmup_iters and at least min_warmup_time.
    let wstart = Instant::now();
    let mut w = 0;
    while w < cfg.warmup_iters || wstart.elapsed() < cfg.min_warmup_time {
        black_box(f());
        w += 1;
        if wstart.elapsed() > cfg.max_total_time / 4 {
            break;
        }
    }
    let mut samples = Vec::with_capacity(cfg.samples);
    let total_start = Instant::now();
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..cfg.iters_per_sample {
            black_box(f());
        }
        samples.push(t0.elapsed().as_secs_f64() / cfg.iters_per_sample as f64);
        if total_start.elapsed() > cfg.max_total_time {
            break;
        }
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples), extra: Vec::new() }
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return format!("{s}");
    }
    let a = s.abs();
    if a >= 1.0 {
        format!("{s:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Collects results and prints a criterion-like report table.
pub struct Runner {
    pub title: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Runner {
    pub fn new(title: impl Into<String>, cfg: BenchConfig) -> Self {
        let title = title.into();
        println!("\n=== bench: {title} ===");
        Runner { title, cfg, results: Vec::new() }
    }

    /// Run and record one benchmark.
    pub fn bench<F: FnMut() -> R, R>(&mut self, name: &str, f: F) -> &BenchResult {
        let r = run_with(&self.cfg, name, f);
        println!(
            "{:<44} {:>12} ± {:>10}  (median {:>12}, n={})",
            r.name,
            fmt_secs(r.summary.mean),
            fmt_secs(r.summary.ci95()),
            fmt_secs(r.summary.median),
            r.summary.n
        );
        self.results.push(r);
        self.results.last().expect("just pushed")
    }

    /// Record an externally-measured scalar row (for figure regeneration
    /// benches where the "measurement" is e.g. a simulated makespan).
    pub fn record(&mut self, name: &str, value: f64, unit: &str) {
        println!("{name:<44} {value:>12.6} {unit}");
        self.results.push(BenchResult {
            name: name.to_string(),
            summary: Summary::of(&[value]),
            extra: vec![(unit.to_string(), value)],
        });
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write `name,mean,ci95,median,min,max` CSV.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "name,mean,ci95,median,min,max")?;
        for r in &self.results {
            writeln!(
                f,
                "{},{},{},{},{},{}",
                r.name, r.summary.mean, r.summary.ci95(), r.summary.median, r.summary.min,
                r.summary.max
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep_scale() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            samples: 5,
            iters_per_sample: 1,
            min_warmup_time: Duration::from_millis(1),
            max_total_time: Duration::from_secs(5),
        };
        let r = run_with(&cfg, "sleep1ms", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.summary.mean >= 0.001, "mean {}", r.summary.mean);
        assert!(r.summary.mean < 0.05);
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
    }

    #[test]
    fn runner_collects_and_writes_csv() {
        let mut r = Runner::new("t", BenchConfig {
            warmup_iters: 0,
            samples: 3,
            iters_per_sample: 10,
            min_warmup_time: Duration::ZERO,
            max_total_time: Duration::from_secs(1),
        });
        r.bench("noop", || 1 + 1);
        r.record("makespan", 1.25, "s");
        assert_eq!(r.results().len(), 2);
        let p = std::env::temp_dir().join("ductr_bench_test.csv");
        r.write_csv(p.to_str().expect("utf8 path")).expect("csv write");
        let body = std::fs::read_to_string(&p).expect("csv read");
        assert!(body.starts_with("name,mean"));
        assert_eq!(body.lines().count(), 3);
        let _ = std::fs::remove_file(p);
    }
}
