//! Terminal ASCII plots for quick-look experiment output.
//!
//! The figures of the paper are line/area plots of per-process workload over
//! time (Fig 4/5) and probability/latency curves (Fig 1/3).  `metrics::csv`
//! writes machine-readable data for real plotting; this module renders the
//! same series as ASCII so every experiment is inspectable straight from the
//! terminal (and in EXPERIMENTS.md).

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>, points: Vec<(f64, f64)>) -> Self {
        Series { name: name.into(), points }
    }
}

const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&', '=', '~'];

/// Render multiple series into a `width`×`height` character grid with axes.
pub fn render(series: &[Series], width: usize, height: usize) -> String {
    assert!(width >= 16 && height >= 4, "plot too small");
    let pts: Vec<&(f64, f64)> = series.iter().flat_map(|s| &s.points).collect();
    if pts.is_empty() {
        return String::from("(empty plot)\n");
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &&(x, y) in &pts {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-300 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-300 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let g = GLYPHS[si % GLYPHS.len()];
        // draw connected segments so sparse series stay readable
        for w in s.points.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            let steps = (width * 2).max(2);
            for t in 0..=steps {
                let f = t as f64 / steps as f64;
                let x = x0 + (x1 - x0) * f;
                let y = y0 + (y1 - y0) * f;
                let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
                let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
                grid[height - 1 - cy][cx] = g;
            }
        }
        if s.points.len() == 1 {
            let (x, y) = s.points[0];
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = g;
        }
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let yv = ymax - (ymax - ymin) * i as f64 / (height - 1) as f64;
        out.push_str(&format!("{yv:>10.3} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>10}  {:<w$.3}{:>10.3}\n",
        "",
        xmin,
        xmax,
        w = width.saturating_sub(10)
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", GLYPHS[si % GLYPHS.len()], s.name));
    }
    out
}

/// Convenience: render with a title banner.
pub fn plot(title: &str, series: &[Series], width: usize, height: usize) -> String {
    format!("== {title} ==\n{}", render(series, width, height))
}

/// Horizontal bar chart for categorical summaries (e.g. makespan per config).
pub fn bars(rows: &[(String, f64)], width: usize) -> String {
    if rows.is_empty() {
        return String::from("(no data)\n");
    }
    let maxv = rows.iter().map(|r| r.1).fold(f64::NEG_INFINITY, f64::max).max(1e-300);
    let label_w = rows.iter().map(|r| r.0.len()).max().unwrap_or(4).min(28);
    let mut out = String::new();
    for (name, v) in rows {
        let n = ((v / maxv) * width as f64).round().max(0.0) as usize;
        out.push_str(&format!(
            "{:<label_w$} |{} {v:.4}\n",
            &name[..name.len().min(label_w)],
            "#".repeat(n),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_glyphs_and_axes() {
        let s = vec![
            Series::new("a", vec![(0.0, 0.0), (1.0, 1.0)]),
            Series::new("b", vec![(0.0, 1.0), (1.0, 0.0)]),
        ];
        let out = render(&s, 40, 10);
        assert!(out.contains('*'));
        assert!(out.contains('o'));
        assert!(out.contains('|'));
        assert!(out.contains("a\n"));
    }

    #[test]
    fn empty_plot_ok() {
        assert_eq!(render(&[], 40, 10), "(empty plot)\n");
    }

    #[test]
    fn single_point_series() {
        let s = vec![Series::new("pt", vec![(0.5, 0.5)])];
        let out = render(&s, 20, 5);
        assert!(out.contains('*'));
    }

    #[test]
    fn constant_series_no_panic() {
        let s = vec![Series::new("c", vec![(0.0, 2.0), (1.0, 2.0), (2.0, 2.0)])];
        let _ = render(&s, 30, 6);
    }

    #[test]
    fn bars_scale() {
        let rows = vec![("x".to_string(), 1.0), ("yy".to_string(), 2.0)];
        let out = bars(&rows, 10);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[1].matches('#').count() > lines[0].matches('#').count());
    }
}
