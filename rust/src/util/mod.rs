//! Foundation utilities built in-repo (the offline crate set has no `rand`,
//! `criterion`, or `proptest`): PRNG, statistics, ASCII plotting, a bench
//! harness, and a property-testing mini-framework.

pub mod bench;
pub mod error;
pub mod fxhash;
pub mod json;
pub mod plot;
pub mod propcheck;
pub mod rng;
pub mod stats;
