//! Small statistics toolkit: running moments, summaries, percentiles.
//!
//! Used by the bench harness (`util::bench`), the DLB performance recorder
//! (`dlb::perfmodel`) and the experiment drivers.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n−1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel Welford).
    pub fn merge(&mut self, o: &Running) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        let mean = self.mean + d * o.n as f64 / n as f64;
        let m2 = self.m2 + o.m2 + d * d * self.n as f64 * o.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

/// Full five-number-plus summary of a sample.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p05: f64,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let mut run = Running::new();
        for &x in xs {
            run.push(x);
        }
        Summary {
            n: xs.len(),
            mean: run.mean(),
            std: run.std(),
            min: s[0],
            p05: percentile_sorted(&s, 0.05),
            median: percentile_sorted(&s, 0.50),
            p95: percentile_sorted(&s, 0.95),
            max: s[s.len() - 1],
        }
    }

    /// Half-width of the 95% normal-approximation CI of the mean.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 { 0.0 } else { 1.96 * self.std / (self.n as f64).sqrt() }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `q` in `[0, 1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Arithmetic mean of a slice (NaN on empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { f64::NAN } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, -1.0, 0.5];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((r.mean() - m).abs() < 1e-12);
        assert!((r.var() - v).abs() < 1e-12);
        assert_eq!(r.min(), -1.0);
        assert_eq!(r.max(), 5.0);
        assert_eq!(r.count(), 7);
    }

    #[test]
    fn running_merge_equals_single_pass() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Running::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Running::new();
        let mut b = Running::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.var() - whole.var()).abs() < 1e-10);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Running::new();
        a.push(1.0);
        a.push(2.0);
        let before = (a.mean(), a.var());
        a.merge(&Running::new());
        assert_eq!(before, (a.mean(), a.var()));
        let mut e = Running::new();
        e.merge(&a);
        assert_eq!((e.mean(), e.var()), before);
    }

    #[test]
    fn percentile_endpoints() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&s, 0.0), 1.0);
        assert_eq!(percentile_sorted(&s, 1.0), 4.0);
        assert!((percentile_sorted(&s, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_basics() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!((s.median - 50.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!(s.p05 < s.median && s.median < s.p95);
        assert!(s.ci95() > 0.0);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }
}
