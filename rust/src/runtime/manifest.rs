//! Parser for `artifacts/manifest.txt`, the contract between the AOT
//! compile path (`python -m compile.aot`) and the Rust runtime.
//!
//! Line format (see python/compile/aot.py):
//!
//! ```text
//! version 1
//! kernel <name> <block> <file> <arity> <dtype> <shape>... <flops> <doubles>
//! ```
//!
//! with shapes `AxB` or `A`.  `#` starts a comment.

use std::path::{Path, PathBuf};

use crate::core::task::TaskKind;

#[derive(Debug)]
pub struct ManifestError(pub String);

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "manifest error: {}", self.0)
    }
}

impl std::error::Error for ManifestError {}

/// One AOT-compiled kernel artifact.
#[derive(Debug, Clone)]
pub struct KernelEntry {
    pub name: String,
    pub block: usize,
    pub path: PathBuf,
    pub arity: usize,
    pub dtype: String,
    /// Argument shapes in execution order.
    pub shapes: Vec<Vec<usize>>,
    pub flops: u64,
    pub doubles: u64,
}

/// The parsed artifact index.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<KernelEntry>,
}

fn parse_shape(tok: &str) -> Result<Vec<usize>, ManifestError> {
    tok.split('x')
        .map(|d| d.parse::<usize>().map_err(|_| ManifestError(format!("bad shape: {tok}"))))
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            ManifestError(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest, ManifestError> {
        let mut entries = Vec::new();
        let mut saw_version = false;
        for (i, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("version") => {
                    let v = parts.next().unwrap_or("");
                    if v != "1" {
                        return Err(ManifestError(format!("unsupported manifest version {v}")));
                    }
                    saw_version = true;
                }
                Some("kernel") => {
                    let toks: Vec<&str> = parts.collect();
                    if toks.len() < 7 {
                        return Err(ManifestError(format!("line {}: too few fields", i + 1)));
                    }
                    let name = toks[0].to_string();
                    let block: usize = toks[1]
                        .parse()
                        .map_err(|_| ManifestError(format!("line {}: bad block", i + 1)))?;
                    let file = toks[2];
                    let arity: usize = toks[3]
                        .parse()
                        .map_err(|_| ManifestError(format!("line {}: bad arity", i + 1)))?;
                    let dtype = toks[4].to_string();
                    let nshapes = toks.len() - 5 - 2;
                    if nshapes != arity {
                        return Err(ManifestError(format!(
                            "line {}: {nshapes} shapes but arity {arity}",
                            i + 1
                        )));
                    }
                    let shapes = toks[5..5 + nshapes]
                        .iter()
                        .map(|s| parse_shape(s))
                        .collect::<Result<Vec<_>, _>>()?;
                    let flops: u64 = toks[5 + nshapes]
                        .parse()
                        .map_err(|_| ManifestError(format!("line {}: bad flops", i + 1)))?;
                    let doubles: u64 = toks[6 + nshapes]
                        .parse()
                        .map_err(|_| ManifestError(format!("line {}: bad doubles", i + 1)))?;
                    entries.push(KernelEntry {
                        name,
                        block,
                        path: dir.join(file),
                        arity,
                        dtype,
                        shapes,
                        flops,
                        doubles,
                    });
                }
                Some(other) => {
                    return Err(ManifestError(format!("line {}: unknown record {other}", i + 1)))
                }
                None => {}
            }
        }
        if !saw_version {
            return Err(ManifestError("missing version line".to_string()));
        }
        if entries.is_empty() {
            return Err(ManifestError("no kernel entries".to_string()));
        }
        Ok(Manifest { dir, entries })
    }

    /// Look up the artifact for a task kind at a block size.
    pub fn find(&self, kind: TaskKind, block: usize) -> Option<&KernelEntry> {
        let name = kind.kernel_name()?;
        self.entries.iter().find(|e| e.name == name && e.block == block)
    }

    /// All block sizes available for a kind, ascending.
    pub fn blocks_for(&self, kind: TaskKind) -> Vec<usize> {
        let Some(name) = kind.kernel_name() else { return Vec::new() };
        let mut v: Vec<usize> =
            self.entries.iter().filter(|e| e.name == name).map(|e| e.block).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Verify every referenced HLO file exists.
    pub fn check_files(&self) -> Result<(), ManifestError> {
        for e in &self.entries {
            if !e.path.exists() {
                return Err(ManifestError(format!("missing artifact file {}", e.path.display())));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
version 1
kernel potrf 32 potrf_b32.hlo.txt 1 f32 32x32 10922 2048
kernel gemm 32 gemm_b32.hlo.txt 3 f32 32x32 32x32 32x32 65536 4096
kernel gemv 32 gemv_b32.hlo.txt 2 f32 32x32 32 2048 1088
";

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).expect("parse");
        assert_eq!(m.entries.len(), 3);
        let g = m.find(TaskKind::Gemm, 32).expect("gemm");
        assert_eq!(g.arity, 3);
        assert_eq!(g.shapes, vec![vec![32, 32]; 3]);
        assert_eq!(g.flops, 65536);
        let v = m.find(TaskKind::Gemv, 32).expect("gemv");
        assert_eq!(v.shapes[1], vec![32]);
        assert!(m.find(TaskKind::Trsm, 32).is_none());
        assert!(m.find(TaskKind::Gemm, 64).is_none());
    }

    #[test]
    fn blocks_for_sorted() {
        let doubled = format!(
            "{SAMPLE}kernel gemm 64 gemm_b64.hlo.txt 3 f32 64x64 64x64 64x64 524288 16384\n"
        );
        let m = Manifest::parse(&doubled, PathBuf::from("/tmp/a")).expect("parse");
        assert_eq!(m.blocks_for(TaskKind::Gemm), vec![32, 64]);
        assert_eq!(m.blocks_for(TaskKind::Synthetic), Vec::<usize>::new());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("version 2\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("kernel x\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("version 1\n", PathBuf::new()).is_err(), "no entries");
        // arity/shape mismatch
        let bad = "version 1\nkernel gemm 32 f.hlo 3 f32 32x32 65536 4096\n";
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        // integration: the repo's own artifacts (skip silently if not built)
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).expect("load");
            m.check_files().expect("files exist");
            for kind in [TaskKind::Potrf, TaskKind::Trsm, TaskKind::Syrk, TaskKind::Gemm] {
                assert!(!m.blocks_for(kind).is_empty(), "missing artifacts for {kind}");
            }
        }
    }
}
