//! Kernel execution for the threaded real mode.
//!
//! The original design loaded AOT-lowered HLO artifacts through a PJRT CPU
//! client.  PJRT (the `xla` crate plus its native `xla_extension` library)
//! is not available in the offline build, so `KernelLibrary` executes
//! vendored pure-Rust reference kernels instead.  Semantics mirror
//! `python/compile/kernels/ref.py` exactly (the correctness ground truth
//! the Pallas kernels are themselves validated against):
//!
//! - `potrf(a)`      → lower Cholesky factor, zero upper triangle
//! - `trsm(l, b)`    → X with X·Lᵀ = B (right-side lower-transposed solve)
//! - `syrk(c, a)`    → C − A·Aᵀ
//! - `gemm(c, a, b)` → C − A·Bᵀ
//! - `gemv(a, x)`    → A·x
//!
//! The manifest contract is kept: arity and argument shapes are validated
//! against `artifacts/manifest.txt`, so the AOT pipeline remains the source
//! of truth for kernel signatures and the numeric verification
//! (`cholesky::verify::residual`) exercises the same data flow.

use std::sync::Arc;

use crate::util::error::Result;
use crate::{anyhow, bail};

use crate::core::task::TaskKind;

use super::manifest::Manifest;

/// A kernel executor bound to one block size (one per worker thread, as in
/// the PJRT design — the reference kernels are stateless, the per-thread
/// instance keeps the execution counter local).
pub struct KernelLibrary {
    manifest: Arc<Manifest>,
    block: usize,
    /// Executions performed (for perf accounting).
    pub executions: u64,
}

impl KernelLibrary {
    /// Create a library serving kernels at `block` size.
    pub fn new(manifest: Arc<Manifest>, block: usize) -> Result<Self> {
        Ok(KernelLibrary { manifest, block, executions: 0 })
    }

    pub fn block(&self) -> usize {
        self.block
    }

    /// Execute `kind` on `args` (row-major f32 buffers matching the
    /// manifest shapes).  Returns the output buffer.
    pub fn execute(&mut self, kind: TaskKind, args: &[&[f32]]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(kind, self.block)
            .ok_or_else(|| anyhow!("no artifact for {kind} at block {}", self.block))?;
        if args.len() != entry.arity {
            bail!("{kind}: expected {} args, got {}", entry.arity, args.len());
        }
        for (i, (&buf, shape)) in args.iter().zip(&entry.shapes).enumerate() {
            let elems: usize = shape.iter().product();
            if buf.len() != elems {
                bail!("{kind} arg {i}: expected {elems} elems (shape {shape:?}), got {}", buf.len());
            }
        }
        let b = self.block;
        let out = match kind {
            TaskKind::Potrf => potrf(args[0], b),
            TaskKind::Trsm => trsm(args[0], args[1], b),
            TaskKind::Syrk => gemm_update(args[0], args[1], args[1], b),
            TaskKind::Gemm => gemm_update(args[0], args[1], args[2], b),
            TaskKind::Gemv => gemv(args[0], args[1], b),
            TaskKind::Synthetic => bail!("synthetic tasks have no kernel"),
        };
        self.executions += 1;
        Ok(out)
    }

    /// Smoke-test every kernel the manifest lists at this block size (the
    /// `ductr artifacts-check` command).
    pub fn smoke_all(&mut self) -> Result<Vec<(TaskKind, f64)>> {
        use std::time::Instant;
        let b = self.block;
        let mut report = Vec::new();
        for kind in [TaskKind::Potrf, TaskKind::Trsm, TaskKind::Syrk, TaskKind::Gemm, TaskKind::Gemv]
        {
            if self.manifest.find(kind, b).is_none() {
                continue;
            }
            // SPD block for potrf/trsm stability: A = I·(b) + small noise
            let spd: Vec<f32> = (0..b * b)
                .map(|i| {
                    let (r, c) = (i / b, i % b);
                    if r == c { b as f32 } else { 0.1 / (1.0 + (r as f32 - c as f32).abs()) }
                })
                .collect();
            let gen: Vec<f32> = (0..b * b).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
            let vecx: Vec<f32> = (0..b).map(|i| (i % 5) as f32 - 2.0).collect();
            let t0 = Instant::now();
            let out = match kind {
                TaskKind::Potrf => self.execute(kind, &[&spd])?,
                TaskKind::Trsm => self.execute(kind, &[&spd, &gen])?,
                TaskKind::Syrk => self.execute(kind, &[&gen, &gen])?,
                TaskKind::Gemm => self.execute(kind, &[&gen, &gen, &gen])?,
                TaskKind::Gemv => self.execute(kind, &[&gen, &vecx])?,
                TaskKind::Synthetic => unreachable!(),
            };
            let dt = t0.elapsed().as_secs_f64();
            if out.iter().any(|x| !x.is_finite()) {
                bail!("{kind}: non-finite output");
            }
            report.push((kind, dt));
        }
        Ok(report)
    }
}

/// Lower Cholesky factor of the SPD block `a` (Cholesky–Banachiewicz),
/// upper triangle explicitly zero — the `jnp.tril(cholesky(a))` oracle.
fn potrf(a: &[f32], n: usize) -> Vec<f32> {
    let mut l = vec![0.0f32; n * n];
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= l[j * n + k] * l[j * n + k];
        }
        let d = d.max(0.0).sqrt();
        l[j * n + j] = d;
        for i in (j + 1)..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = if d != 0.0 { s / d } else { 0.0 };
        }
    }
    l
}

/// Solve X·Lᵀ = B for X: forward substitution over columns,
/// `x[:, j] = (b[:, j] − X[:, :j] · L[j, :j]ᵀ) / l[j, j]`.
fn trsm(l: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; n * n];
    for j in 0..n {
        let d = l[j * n + j];
        for i in 0..n {
            let mut s = b[i * n + j];
            for k in 0..j {
                s -= x[i * n + k] * l[j * n + k];
            }
            x[i * n + j] = if d != 0.0 { s / d } else { 0.0 };
        }
    }
    x
}

/// C − A·Bᵀ (the gemm oracle; syrk is gemm with B = A).
fn gemm_update(c: &[f32], a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut out = c.to_vec();
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0f32;
            for k in 0..n {
                s += a[i * n + k] * b[j * n + k];
            }
            out[i * n + j] -= s;
        }
    }
    out
}

/// A·x.
fn gemv(a: &[f32], x: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for i in 0..n {
        let mut s = 0.0f32;
        for k in 0..n {
            s += a[i * n + k] * x[k];
        }
        out[i] = s;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    /// Synthetic manifest covering all five kernels at one block size —
    /// the reference kernels need no HLO files on disk.
    fn lib(b: usize) -> KernelLibrary {
        let text = format!(
            "version 1\n\
             kernel potrf {b} potrf.hlo.txt 1 f32 {b}x{b} 1 1\n\
             kernel trsm {b} trsm.hlo.txt 2 f32 {b}x{b} {b}x{b} 1 1\n\
             kernel syrk {b} syrk.hlo.txt 2 f32 {b}x{b} {b}x{b} 1 1\n\
             kernel gemm {b} gemm.hlo.txt 3 f32 {b}x{b} {b}x{b} {b}x{b} 1 1\n\
             kernel gemv {b} gemv.hlo.txt 2 f32 {b}x{b} {b} 1 1\n"
        );
        let m = Arc::new(Manifest::parse(&text, PathBuf::from("/tmp")).expect("manifest"));
        KernelLibrary::new(m, b).expect("lib")
    }

    fn spd(b: usize) -> Vec<f32> {
        // diagonally dominant SPD
        (0..b * b)
            .map(|i| {
                let (r, c) = (i / b, i % b);
                if r == c { (b + 1) as f32 } else { 1.0 / (1.0 + (r as f32 - c as f32).abs()) }
            })
            .collect()
    }

    #[test]
    fn potrf_reconstructs() {
        let mut lib = lib(32);
        let b = 32;
        let a = spd(b);
        let l = lib.execute(TaskKind::Potrf, &[&a]).expect("potrf");
        // L·Lᵀ ≈ A
        let mut err: f32 = 0.0;
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0f32;
                for k in 0..=j.min(i) {
                    s += l[i * b + k] * l[j * b + k];
                }
                err = err.max((s - a[i * b + j]).abs());
            }
        }
        assert!(err < 1e-3, "reconstruction err {err}");
        // strict upper triangle zero
        for i in 0..b {
            for j in (i + 1)..b {
                assert_eq!(l[i * b + j], 0.0);
            }
        }
    }

    #[test]
    fn trsm_solves_right_transposed_system() {
        let mut lib = lib(16);
        let b = 16;
        let l = lib.execute(TaskKind::Potrf, &[&spd(b)]).expect("potrf");
        let rhs: Vec<f32> = (0..b * b).map(|i| ((i % 13) as f32 - 6.0) / 6.0).collect();
        let x = lib.execute(TaskKind::Trsm, &[&l, &rhs]).expect("trsm");
        // X·Lᵀ ≈ B
        let mut err: f32 = 0.0;
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0f32;
                for k in 0..b {
                    s += x[i * b + k] * l[j * b + k];
                }
                err = err.max((s - rhs[i * b + j]).abs());
            }
        }
        assert!(err < 1e-3, "solve err {err}");
    }

    #[test]
    fn gemm_matches_reference() {
        let mut lib = lib(32);
        let b = 32;
        let c: Vec<f32> = (0..b * b).map(|i| (i % 7) as f32).collect();
        let x: Vec<f32> = (0..b * b).map(|i| ((i % 5) as f32 - 2.0) / 2.0).collect();
        let y: Vec<f32> = (0..b * b).map(|i| ((i % 3) as f32 - 1.0) / 3.0).collect();
        let out = lib.execute(TaskKind::Gemm, &[&c, &x, &y]).expect("gemm");
        // reference: c - x @ yᵀ
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0f32;
                for k in 0..b {
                    s += x[i * b + k] * y[j * b + k];
                }
                let expect = c[i * b + j] - s;
                let got = out[i * b + j];
                assert!((got - expect).abs() < 1e-3, "({i},{j}): {got} vs {expect}");
            }
        }
    }

    #[test]
    fn syrk_is_gemm_with_self() {
        let mut lib = lib(16);
        let b = 16;
        let c: Vec<f32> = (0..b * b).map(|i| (i % 9) as f32).collect();
        let a: Vec<f32> = (0..b * b).map(|i| ((i % 5) as f32 - 2.0) / 4.0).collect();
        let syrk = lib.execute(TaskKind::Syrk, &[&c, &a]).expect("syrk");
        let gemm = lib.execute(TaskKind::Gemm, &[&c, &a, &a]).expect("gemm");
        assert_eq!(syrk, gemm);
    }

    #[test]
    fn gemv_matches_reference() {
        let mut lib = lib(32);
        let b = 32;
        let a: Vec<f32> = (0..b * b).map(|i| ((i % 11) as f32 - 5.0) / 5.0).collect();
        let x: Vec<f32> = (0..b).map(|i| (i % 4) as f32 - 1.5).collect();
        let out = lib.execute(TaskKind::Gemv, &[&a, &x]).expect("gemv");
        for i in 0..b {
            let mut s = 0.0f32;
            for k in 0..b {
                s += a[i * b + k] * x[k];
            }
            assert!((out[i] - s).abs() < 1e-3);
        }
    }

    #[test]
    fn wrong_arity_rejected() {
        let mut lib = lib(32);
        let a = spd(32);
        assert!(lib.execute(TaskKind::Gemm, &[&a]).is_err());
    }

    #[test]
    fn wrong_size_rejected() {
        let mut lib = lib(32);
        let small = vec![0.0f32; 4];
        assert!(lib.execute(TaskKind::Potrf, &[&small]).is_err());
    }

    #[test]
    fn smoke_all_runs() {
        let mut lib = lib(32);
        let report = lib.smoke_all().expect("smoke");
        assert_eq!(report.len(), 5);
        assert!(report.iter().all(|(_, dt)| *dt >= 0.0));
        assert_eq!(lib.executions, 5);
    }
}
