//! Kernel execution for the threaded real mode.
//!
//! The original design loaded AOT-lowered HLO artifacts through a PJRT CPU
//! client.  PJRT (the `xla` crate plus its native `xla_extension` library)
//! is not available in the offline build, so `KernelLibrary` executes
//! vendored pure-Rust reference kernels instead.  Semantics mirror
//! `python/compile/kernels/ref.py` exactly (the correctness ground truth
//! the Pallas kernels are themselves validated against):
//!
//! - `potrf(a)`      → lower Cholesky factor, zero upper triangle
//! - `trsm(l, b)`    → X with X·Lᵀ = B (right-side lower-transposed solve)
//! - `syrk(c, a)`    → C − A·Aᵀ
//! - `gemm(c, a, b)` → C − A·Bᵀ
//! - `gemv(a, x)`    → A·x
//!
//! The manifest contract is kept: arity and argument shapes are validated
//! against `artifacts/manifest.txt`, so the AOT pipeline remains the source
//! of truth for kernel signatures and the numeric verification
//! (`cholesky::verify::residual`) exercises the same data flow.

use std::sync::Arc;

use crate::util::error::Result;
use crate::{anyhow, bail};

use crate::core::task::TaskKind;

use super::manifest::Manifest;

/// A kernel executor bound to one block size (one per worker thread, as in
/// the PJRT design — the reference kernels are stateless, the per-thread
/// instance keeps the execution counter local).
pub struct KernelLibrary {
    manifest: Arc<Manifest>,
    block: usize,
    /// Executions performed (for perf accounting).
    pub executions: u64,
}

impl KernelLibrary {
    /// Create a library serving kernels at `block` size.
    pub fn new(manifest: Arc<Manifest>, block: usize) -> Result<Self> {
        Ok(KernelLibrary { manifest, block, executions: 0 })
    }

    pub fn block(&self) -> usize {
        self.block
    }

    /// Execute `kind` on `args` (row-major f32 buffers matching the
    /// manifest shapes).  Returns the output buffer.
    pub fn execute(&mut self, kind: TaskKind, args: &[&[f32]]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(kind, self.block)
            .ok_or_else(|| anyhow!("no artifact for {kind} at block {}", self.block))?;
        if args.len() != entry.arity {
            bail!("{kind}: expected {} args, got {}", entry.arity, args.len());
        }
        for (i, (&buf, shape)) in args.iter().zip(&entry.shapes).enumerate() {
            let elems: usize = shape.iter().product();
            if buf.len() != elems {
                bail!("{kind} arg {i}: expected {elems} elems (shape {shape:?}), got {}", buf.len());
            }
        }
        let b = self.block;
        let out = match kind {
            TaskKind::Potrf => potrf(args[0], b),
            TaskKind::Trsm => trsm(args[0], args[1], b),
            TaskKind::Syrk => gemm_update(args[0], args[1], args[1], b),
            TaskKind::Gemm => gemm_update(args[0], args[1], args[2], b),
            TaskKind::Gemv => gemv(args[0], args[1], b),
            TaskKind::Synthetic => bail!("synthetic tasks have no kernel"),
        };
        self.executions += 1;
        Ok(out)
    }

    /// Smoke-test every kernel the manifest lists at this block size (the
    /// `ductr artifacts-check` command).
    pub fn smoke_all(&mut self) -> Result<Vec<(TaskKind, f64)>> {
        use std::time::Instant;
        let b = self.block;
        let mut report = Vec::new();
        for kind in [TaskKind::Potrf, TaskKind::Trsm, TaskKind::Syrk, TaskKind::Gemm, TaskKind::Gemv]
        {
            if self.manifest.find(kind, b).is_none() {
                continue;
            }
            // SPD block for potrf/trsm stability: A = I·(b) + small noise
            let spd: Vec<f32> = (0..b * b)
                .map(|i| {
                    let (r, c) = (i / b, i % b);
                    if r == c { b as f32 } else { 0.1 / (1.0 + (r as f32 - c as f32).abs()) }
                })
                .collect();
            let gen: Vec<f32> = (0..b * b).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
            let vecx: Vec<f32> = (0..b).map(|i| (i % 5) as f32 - 2.0).collect();
            let t0 = Instant::now();
            let out = match kind {
                TaskKind::Potrf => self.execute(kind, &[&spd])?,
                TaskKind::Trsm => self.execute(kind, &[&spd, &gen])?,
                TaskKind::Syrk => self.execute(kind, &[&gen, &gen])?,
                TaskKind::Gemm => self.execute(kind, &[&gen, &gen, &gen])?,
                TaskKind::Gemv => self.execute(kind, &[&gen, &vecx])?,
                TaskKind::Synthetic => unreachable!(),
            };
            let dt = t0.elapsed().as_secs_f64();
            if out.iter().any(|x| !x.is_finite()) {
                bail!("{kind}: non-finite output");
            }
            report.push((kind, dt));
        }
        Ok(report)
    }
}

/// Cache-block edge for the tiled kernels.  The k reduction is kept whole
/// (see `gemm_update`), so the working set of one (i, j) tile pair is two
/// TILE-row bands of length n — 64 kB per operand at n = 256 — sized for
/// L2 residency; what the tiling buys is that each B row loaded into cache
/// is reused TILE times (once per i of the tile) instead of once per full
/// i sweep.
const TILE: usize = 64;

/// Contiguous dot product with eight-lane partial accumulators — the shape
/// LLVM reliably autovectorizes (one fused multiply-add per lane, reduction
/// at the end).  Every kernel below funnels its inner loop through this.
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut acc = [0.0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (ca, cb) in (&mut ac).zip(&mut bc) {
        for lane in 0..8 {
            acc[lane] += ca[lane] * cb[lane];
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        s += x * y;
    }
    s
}

/// Lower Cholesky factor of the SPD block `a` (Cholesky–Banachiewicz),
/// upper triangle explicitly zero — the `jnp.tril(cholesky(a))` oracle.
/// Row-major storage makes every inner product a contiguous row-prefix
/// `dot`; the column order itself is a data dependence and cannot tile.
fn potrf(a: &[f32], n: usize) -> Vec<f32> {
    let mut l = vec![0.0f32; n * n];
    for j in 0..n {
        // rows 0..=j in `head`, rows j+1.. in `tail`: the write targets
        // below are disjoint from the shared row-j prefix.
        let (head, tail) = l.split_at_mut((j + 1) * n);
        let ljrow = &head[j * n..j * n + j];
        let d = (a[j * n + j] - dot(ljrow, ljrow)).max(0.0).sqrt();
        head[j * n + j] = d;
        let ljrow = &head[j * n..j * n + j];
        for i in (j + 1)..n {
            let ti = (i - j - 1) * n;
            let s = a[i * n + j] - dot(&tail[ti..ti + j], ljrow);
            tail[ti + j] = if d != 0.0 { s / d } else { 0.0 };
        }
    }
    l
}

/// Solve X·Lᵀ = B for X: forward substitution over columns,
/// `x[:, j] = (b[:, j] − X[:, :j] · L[j, :j]ᵀ) / l[j, j]`.
///
/// Columns are a data dependence (column j reads columns < j of the same
/// row) but rows are independent, so rows are blocked in TILE bands — the
/// j-sweep over one band keeps its X rows cache-resident — and the inner
/// reduction is a contiguous row-prefix `dot`.
fn trsm(l: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; n * n];
    for i0 in (0..n).step_by(TILE) {
        let imax = (i0 + TILE).min(n);
        for j in 0..n {
            let d = l[j * n + j];
            let lrow = &l[j * n..j * n + j];
            for i in i0..imax {
                let s = b[i * n + j] - dot(&x[i * n..i * n + j], lrow);
                x[i * n + j] = if d != 0.0 { s / d } else { 0.0 };
            }
        }
    }
    x
}

/// C − A·Bᵀ (the gemm oracle; syrk is gemm with B = A).
///
/// i/j tiles bound the working set to one band of A rows against one band
/// of B rows; because the product is against Bᵀ, the k reduction is
/// contiguous in *both* operands and stays un-split (one `dot` per output
/// element — no partial-sum reordering across tiles).
fn gemm_update(c: &[f32], a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut out = c.to_vec();
    for i0 in (0..n).step_by(TILE) {
        let imax = (i0 + TILE).min(n);
        for j0 in (0..n).step_by(TILE) {
            let jmax = (j0 + TILE).min(n);
            for i in i0..imax {
                let arow = &a[i * n..i * n + n];
                for j in j0..jmax {
                    let brow = &b[j * n..j * n + n];
                    out[i * n + j] -= dot(arow, brow);
                }
            }
        }
    }
    out
}

/// A·x.
fn gemv(a: &[f32], x: &[f32], n: usize) -> Vec<f32> {
    (0..n).map(|i| dot(&a[i * n..i * n + n], x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    /// Synthetic manifest covering all five kernels at one block size —
    /// the reference kernels need no HLO files on disk.
    fn lib(b: usize) -> KernelLibrary {
        let text = format!(
            "version 1\n\
             kernel potrf {b} potrf.hlo.txt 1 f32 {b}x{b} 1 1\n\
             kernel trsm {b} trsm.hlo.txt 2 f32 {b}x{b} {b}x{b} 1 1\n\
             kernel syrk {b} syrk.hlo.txt 2 f32 {b}x{b} {b}x{b} 1 1\n\
             kernel gemm {b} gemm.hlo.txt 3 f32 {b}x{b} {b}x{b} {b}x{b} 1 1\n\
             kernel gemv {b} gemv.hlo.txt 2 f32 {b}x{b} {b} 1 1\n"
        );
        let m = Arc::new(Manifest::parse(&text, PathBuf::from("/tmp")).expect("manifest"));
        KernelLibrary::new(m, b).expect("lib")
    }

    fn spd(b: usize) -> Vec<f32> {
        // diagonally dominant SPD
        (0..b * b)
            .map(|i| {
                let (r, c) = (i / b, i % b);
                if r == c { (b + 1) as f32 } else { 1.0 / (1.0 + (r as f32 - c as f32).abs()) }
            })
            .collect()
    }

    #[test]
    fn potrf_reconstructs() {
        let mut lib = lib(32);
        let b = 32;
        let a = spd(b);
        let l = lib.execute(TaskKind::Potrf, &[&a]).expect("potrf");
        // L·Lᵀ ≈ A
        let mut err: f32 = 0.0;
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0f32;
                for k in 0..=j.min(i) {
                    s += l[i * b + k] * l[j * b + k];
                }
                err = err.max((s - a[i * b + j]).abs());
            }
        }
        assert!(err < 1e-3, "reconstruction err {err}");
        // strict upper triangle zero
        for i in 0..b {
            for j in (i + 1)..b {
                assert_eq!(l[i * b + j], 0.0);
            }
        }
    }

    #[test]
    fn trsm_solves_right_transposed_system() {
        let mut lib = lib(16);
        let b = 16;
        let l = lib.execute(TaskKind::Potrf, &[&spd(b)]).expect("potrf");
        let rhs: Vec<f32> = (0..b * b).map(|i| ((i % 13) as f32 - 6.0) / 6.0).collect();
        let x = lib.execute(TaskKind::Trsm, &[&l, &rhs]).expect("trsm");
        // X·Lᵀ ≈ B
        let mut err: f32 = 0.0;
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0f32;
                for k in 0..b {
                    s += x[i * b + k] * l[j * b + k];
                }
                err = err.max((s - rhs[i * b + j]).abs());
            }
        }
        assert!(err < 1e-3, "solve err {err}");
    }

    #[test]
    fn gemm_matches_reference() {
        let mut lib = lib(32);
        let b = 32;
        let c: Vec<f32> = (0..b * b).map(|i| (i % 7) as f32).collect();
        let x: Vec<f32> = (0..b * b).map(|i| ((i % 5) as f32 - 2.0) / 2.0).collect();
        let y: Vec<f32> = (0..b * b).map(|i| ((i % 3) as f32 - 1.0) / 3.0).collect();
        let out = lib.execute(TaskKind::Gemm, &[&c, &x, &y]).expect("gemm");
        // reference: c - x @ yᵀ
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0f32;
                for k in 0..b {
                    s += x[i * b + k] * y[j * b + k];
                }
                let expect = c[i * b + j] - s;
                let got = out[i * b + j];
                assert!((got - expect).abs() < 1e-3, "({i},{j}): {got} vs {expect}");
            }
        }
    }

    #[test]
    fn syrk_is_gemm_with_self() {
        let mut lib = lib(16);
        let b = 16;
        let c: Vec<f32> = (0..b * b).map(|i| (i % 9) as f32).collect();
        let a: Vec<f32> = (0..b * b).map(|i| ((i % 5) as f32 - 2.0) / 4.0).collect();
        let syrk = lib.execute(TaskKind::Syrk, &[&c, &a]).expect("syrk");
        let gemm = lib.execute(TaskKind::Gemm, &[&c, &a, &a]).expect("gemm");
        assert_eq!(syrk, gemm);
    }

    #[test]
    fn gemv_matches_reference() {
        let mut lib = lib(32);
        let b = 32;
        let a: Vec<f32> = (0..b * b).map(|i| ((i % 11) as f32 - 5.0) / 5.0).collect();
        let x: Vec<f32> = (0..b).map(|i| (i % 4) as f32 - 1.5).collect();
        let out = lib.execute(TaskKind::Gemv, &[&a, &x]).expect("gemv");
        for i in 0..b {
            let mut s = 0.0f32;
            for k in 0..b {
                s += a[i * b + k] * x[k];
            }
            assert!((out[i] - s).abs() < 1e-3);
        }
    }

    #[test]
    fn wrong_arity_rejected() {
        let mut lib = lib(32);
        let a = spd(32);
        assert!(lib.execute(TaskKind::Gemm, &[&a]).is_err());
    }

    #[test]
    fn wrong_size_rejected() {
        let mut lib = lib(32);
        let small = vec![0.0f32; 4];
        assert!(lib.execute(TaskKind::Potrf, &[&small]).is_err());
    }

    #[test]
    fn smoke_all_runs() {
        let mut lib = lib(32);
        let report = lib.smoke_all().expect("smoke");
        assert_eq!(report.len(), 5);
        assert!(report.iter().all(|(_, dt)| *dt >= 0.0));
        assert_eq!(lib.executions, 5);
    }

    // ------------------------------------------------------------------
    // naive reference oracles (the pre-blocking implementations, kept
    // verbatim) + property tests pitting the tiled kernels against them
    // on random sizes, including non-multiples of TILE.
    // ------------------------------------------------------------------

    fn naive_potrf(a: &[f32], n: usize) -> Vec<f32> {
        let mut l = vec![0.0f32; n * n];
        for j in 0..n {
            let mut d = a[j * n + j];
            for k in 0..j {
                d -= l[j * n + k] * l[j * n + k];
            }
            let d = d.max(0.0).sqrt();
            l[j * n + j] = d;
            for i in (j + 1)..n {
                let mut s = a[i * n + j];
                for k in 0..j {
                    s -= l[i * n + k] * l[j * n + k];
                }
                l[i * n + j] = if d != 0.0 { s / d } else { 0.0 };
            }
        }
        l
    }

    fn naive_trsm(l: &[f32], b: &[f32], n: usize) -> Vec<f32> {
        let mut x = vec![0.0f32; n * n];
        for j in 0..n {
            let d = l[j * n + j];
            for i in 0..n {
                let mut s = b[i * n + j];
                for k in 0..j {
                    s -= x[i * n + k] * l[j * n + k];
                }
                x[i * n + j] = if d != 0.0 { s / d } else { 0.0 };
            }
        }
        x
    }

    fn naive_gemm_update(c: &[f32], a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
        let mut out = c.to_vec();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0f32;
                for k in 0..n {
                    s += a[i * n + k] * b[j * n + k];
                }
                out[i * n + j] -= s;
            }
        }
        out
    }

    fn naive_gemv(a: &[f32], x: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n];
        for i in 0..n {
            let mut s = 0.0f32;
            for k in 0..n {
                s += a[i * n + k] * x[k];
            }
            out[i] = s;
        }
        out
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    /// Random matrix with entries in [-1, 1].
    fn rand_mat(g: &mut crate::util::propcheck::Gen, elems: usize) -> Vec<f32> {
        (0..elems).map(|_| g.f64_in(-1.0..1.0) as f32).collect()
    }

    /// Sizes that straddle the tile edge: 1..TILE, TILE exactly, and
    /// TILE+remainder shapes.
    fn rand_n(g: &mut crate::util::propcheck::Gen) -> usize {
        g.usize_in(1..(2 * TILE + 9))
    }

    #[test]
    fn prop_blocked_gemm_matches_naive() {
        use crate::util::propcheck::forall;
        forall(
            30,
            0x6E66,
            |g| {
                let n = rand_n(g);
                (n, rand_mat(g, n * n), rand_mat(g, n * n), rand_mat(g, n * n))
            },
            |(n, c, a, b)| -> Result<(), String> {
                let fast = gemm_update(c, a, b, *n);
                let slow = naive_gemm_update(c, a, b, *n);
                let err = max_abs_diff(&fast, &slow);
                // n ≤ 137 accumulation terms in [-1,1]: rounding only
                if err < 2e-4 {
                    Ok(())
                } else {
                    Err(format!("n={n}: max |Δ| = {err}"))
                }
            },
        );
    }

    #[test]
    fn prop_blocked_gemv_matches_naive() {
        use crate::util::propcheck::forall;
        forall(
            30,
            0x6E76,
            |g| {
                let n = rand_n(g);
                (n, rand_mat(g, n * n), rand_mat(g, n))
            },
            |(n, a, x)| -> Result<(), String> {
                let err = max_abs_diff(&gemv(a, x, *n), &naive_gemv(a, x, *n));
                if err < 2e-4 {
                    Ok(())
                } else {
                    Err(format!("n={n}: max |Δ| = {err}"))
                }
            },
        );
    }

    #[test]
    fn prop_blocked_trsm_matches_naive() {
        use crate::util::propcheck::forall;
        forall(
            30,
            0x7257,
            |g| {
                let n = rand_n(g);
                // a well-conditioned lower factor: unit-ish diagonal,
                // small off-diagonal mass keeps the substitution stable
                let mut l = vec![0.0f32; n * n];
                for i in 0..n {
                    for j in 0..i {
                        l[i * n + j] = g.f64_in(-0.3..0.3) as f32 / n as f32;
                    }
                    l[i * n + i] = 1.0 + g.f64_in(0.0..1.0) as f32;
                }
                (n, l, rand_mat(g, n * n))
            },
            |(n, l, b)| -> Result<(), String> {
                let err = max_abs_diff(&trsm(l, b, *n), &naive_trsm(l, b, *n));
                if err < 1e-3 {
                    Ok(())
                } else {
                    Err(format!("n={n}: max |Δ| = {err}"))
                }
            },
        );
    }

    #[test]
    fn prop_blocked_potrf_matches_naive() {
        use crate::util::propcheck::forall;
        forall(
            30,
            0x9076,
            |g| {
                let n = rand_n(g);
                // diagonally dominant SPD: stable under both variants
                let mut a = vec![0.0f32; n * n];
                for i in 0..n {
                    for j in 0..i {
                        let v = g.f64_in(-1.0..1.0) as f32;
                        a[i * n + j] = v;
                        a[j * n + i] = v;
                    }
                    a[i * n + i] = n as f32 + 1.0 + g.f64_in(0.0..1.0) as f32;
                }
                (n, a)
            },
            |(n, a)| -> Result<(), String> {
                let err = max_abs_diff(&potrf(a, *n), &naive_potrf(a, *n));
                if err < 1e-3 {
                    Ok(())
                } else {
                    Err(format!("n={n}: max |Δ| = {err}"))
                }
            },
        );
    }

    #[test]
    fn blocked_kernels_handle_tile_edges_exactly() {
        // deterministic spot checks at the awkward shapes: below, at, and
        // just past the tile boundary, plus two tiles + remainder
        for n in [1usize, 7, TILE - 1, TILE, TILE + 1, 2 * TILE + 5] {
            let c: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
            let a: Vec<f32> = (0..n * n).map(|i| ((i % 5) as f32 - 2.0) / 2.0).collect();
            let b: Vec<f32> = (0..n * n).map(|i| ((i % 3) as f32 - 1.0) / 1.5).collect();
            let err = max_abs_diff(&gemm_update(&c, &a, &b, n), &naive_gemm_update(&c, &a, &b, n));
            assert!(err < 2e-4, "gemm n={n}: {err}");
            let x: Vec<f32> = (0..n).map(|i| (i % 4) as f32 - 1.5).collect();
            let err = max_abs_diff(&gemv(&a, &x, n), &naive_gemv(&a, &x, n));
            assert!(err < 2e-4, "gemv n={n}: {err}");
        }
    }

    #[test]
    fn dot_matches_scalar_sum() {
        // lengths across the 8-lane boundary
        for len in [0usize, 1, 7, 8, 9, 16, 31, 64, 65] {
            let a: Vec<f32> = (0..len).map(|i| (i as f32 * 0.25).sin()).collect();
            let b: Vec<f32> = (0..len).map(|i| (i as f32 * 0.5).cos()).collect();
            let scalar: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - scalar).abs() < 1e-4, "len={len}");
        }
    }
}
