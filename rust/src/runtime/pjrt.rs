//! PJRT kernel execution: load HLO-text artifacts, compile once, execute on
//! the task hot path.
//!
//! One `KernelLibrary` per OS thread: `xla::PjRtClient` is internally
//! reference-counted (`Rc`) and not `Send`, so each process thread builds
//! its own client and compiles lazily the kinds it actually executes (the
//! HLO modules are tiny; compile is milliseconds).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::core::task::TaskKind;

use super::manifest::Manifest;

/// A compiled-kernel cache bound to one PJRT CPU client (one thread).
pub struct KernelLibrary {
    client: xla::PjRtClient,
    manifest: Arc<Manifest>,
    block: usize,
    compiled: HashMap<TaskKind, xla::PjRtLoadedExecutable>,
    /// Executions performed (for perf accounting).
    pub executions: u64,
}

impl KernelLibrary {
    /// Create a library serving kernels at `block` size.
    pub fn new(manifest: Arc<Manifest>, block: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(KernelLibrary { client, manifest, block, compiled: HashMap::new(), executions: 0 })
    }

    pub fn block(&self) -> usize {
        self.block
    }

    fn ensure_compiled(&mut self, kind: TaskKind) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(&kind) {
            let entry = self
                .manifest
                .find(kind, self.block)
                .ok_or_else(|| anyhow!("no artifact for {kind} at block {}", self.block))?;
            let path = entry
                .path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?
                .to_string();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {kind}: {e:?}"))?;
            self.compiled.insert(kind, exe);
        }
        Ok(self.compiled.get(&kind).expect("just inserted"))
    }

    /// Execute `kind` on `args` (row-major f32 buffers matching the
    /// manifest shapes).  Returns the output buffer.
    pub fn execute(&mut self, kind: TaskKind, args: &[&[f32]]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(kind, self.block)
            .ok_or_else(|| anyhow!("no artifact for {kind} at block {}", self.block))?
            .clone();
        if args.len() != entry.arity {
            bail!("{kind}: expected {} args, got {}", entry.arity, args.len());
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (&buf, shape)) in args.iter().zip(&entry.shapes).enumerate() {
            let elems: usize = shape.iter().product();
            if buf.len() != elems {
                bail!("{kind} arg {i}: expected {elems} elems (shape {shape:?}), got {}", buf.len());
            }
            let lit = xla::Literal::vec1(buf);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = if dims.len() > 1 {
                lit.reshape(&dims).map_err(|e| anyhow!("reshape arg {i}: {e:?}"))?
            } else {
                lit
            };
            literals.push(lit);
        }
        let exe = self.ensure_compiled(kind)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {kind}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // AOT lowers with return_tuple=True → unwrap the 1-tuple.
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let v = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        self.executions += 1;
        Ok(v)
    }

    /// Compile-and-smoke-test every kernel the manifest lists at this block
    /// size (the `ductr artifacts-check` command).
    pub fn smoke_all(&mut self) -> Result<Vec<(TaskKind, f64)>> {
        use std::time::Instant;
        let b = self.block;
        let mut report = Vec::new();
        for kind in [TaskKind::Potrf, TaskKind::Trsm, TaskKind::Syrk, TaskKind::Gemm, TaskKind::Gemv]
        {
            if self.manifest.find(kind, b).is_none() {
                continue;
            }
            // SPD block for potrf/trsm stability: A = I·(b) + small noise
            let spd: Vec<f32> = (0..b * b)
                .map(|i| {
                    let (r, c) = (i / b, i % b);
                    if r == c { b as f32 } else { 0.1 / (1.0 + (r as f32 - c as f32).abs()) }
                })
                .collect();
            let gen: Vec<f32> = (0..b * b).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
            let vecx: Vec<f32> = (0..b).map(|i| (i % 5) as f32 - 2.0).collect();
            let t0 = Instant::now();
            let out = match kind {
                TaskKind::Potrf => self.execute(kind, &[&spd])?,
                TaskKind::Trsm => self.execute(kind, &[&spd, &gen])?,
                TaskKind::Syrk => self.execute(kind, &[&gen, &gen])?,
                TaskKind::Gemm => self.execute(kind, &[&gen, &gen, &gen])?,
                TaskKind::Gemv => self.execute(kind, &[&gen, &vecx])?,
                TaskKind::Synthetic => unreachable!(),
            };
            let dt = t0.elapsed().as_secs_f64();
            if out.iter().any(|x| !x.is_finite()) {
                bail!("{kind}: non-finite output");
            }
            report.push((kind, dt));
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    //! These tests require built artifacts; they self-skip when
    //! `artifacts/manifest.txt` is absent so `cargo test` works pre-build.
    use super::*;

    fn lib(block: usize) -> Option<KernelLibrary> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let m = Arc::new(Manifest::load(dir).expect("manifest"));
        Some(KernelLibrary::new(m, block).expect("client"))
    }

    fn spd(b: usize) -> Vec<f32> {
        // diagonally dominant SPD
        (0..b * b)
            .map(|i| {
                let (r, c) = (i / b, i % b);
                if r == c { (b + 1) as f32 } else { 1.0 / (1.0 + (r as f32 - c as f32).abs()) }
            })
            .collect()
    }

    #[test]
    fn potrf_reconstructs() {
        let Some(mut lib) = lib(32) else { return };
        let b = 32;
        let a = spd(b);
        let l = lib.execute(TaskKind::Potrf, &[&a]).expect("potrf");
        // L·Lᵀ ≈ A
        let mut err: f32 = 0.0;
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0f32;
                for k in 0..=j.min(i) {
                    s += l[i * b + k] * l[j * b + k];
                }
                err = err.max((s - a[i * b + j]).abs());
            }
        }
        assert!(err < 1e-3, "reconstruction err {err}");
    }

    #[test]
    fn gemm_matches_reference() {
        let Some(mut lib) = lib(32) else { return };
        let b = 32;
        let c: Vec<f32> = (0..b * b).map(|i| (i % 7) as f32).collect();
        let x: Vec<f32> = (0..b * b).map(|i| ((i % 5) as f32 - 2.0) / 2.0).collect();
        let y: Vec<f32> = (0..b * b).map(|i| ((i % 3) as f32 - 1.0) / 3.0).collect();
        let out = lib.execute(TaskKind::Gemm, &[&c, &x, &y]).expect("gemm");
        // reference: c - x @ yᵀ
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0f32;
                for k in 0..b {
                    s += x[i * b + k] * y[j * b + k];
                }
                let expect = c[i * b + j] - s;
                let got = out[i * b + j];
                assert!((got - expect).abs() < 1e-3, "({i},{j}): {got} vs {expect}");
            }
        }
    }

    #[test]
    fn gemv_matches_reference() {
        let Some(mut lib) = lib(32) else { return };
        let b = 32;
        let a: Vec<f32> = (0..b * b).map(|i| ((i % 11) as f32 - 5.0) / 5.0).collect();
        let x: Vec<f32> = (0..b).map(|i| (i % 4) as f32 - 1.5).collect();
        let out = lib.execute(TaskKind::Gemv, &[&a, &x]).expect("gemv");
        for i in 0..b {
            let mut s = 0.0f32;
            for k in 0..b {
                s += a[i * b + k] * x[k];
            }
            assert!((out[i] - s).abs() < 1e-3);
        }
    }

    #[test]
    fn wrong_arity_rejected() {
        let Some(mut lib) = lib(32) else { return };
        let a = spd(32);
        assert!(lib.execute(TaskKind::Gemm, &[&a]).is_err());
    }

    #[test]
    fn wrong_size_rejected() {
        let Some(mut lib) = lib(32) else { return };
        let small = vec![0.0f32; 4];
        assert!(lib.execute(TaskKind::Potrf, &[&small]).is_err());
    }

    #[test]
    fn smoke_all_runs() {
        let Some(mut lib) = lib(32) else { return };
        let report = lib.smoke_all().expect("smoke");
        assert_eq!(report.len(), 5);
        assert!(report.iter().all(|(_, dt)| *dt >= 0.0));
    }
}
