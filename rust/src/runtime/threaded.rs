//! Threaded real mode: one coordinator thread + `cores_per_process` worker
//! threads per process, mpsc mailboxes, wallclock time, and actual kernel
//! execution through PJRT.
//!
//! Architecture matches the paper's hybrid MPI-thread model (and DuctTeip's
//! dedicated management thread): the coordinator thread owns the
//! `ProcessState` and *never blocks on computation* — it services the
//! network, worker completions, and the DLB timers, and dispatches ready
//! tasks to the worker pool.  If task execution blocked the coordinator, a
//! busy process would be unreachable for a full task duration and the
//! pairing protocol would starve precisely when load balancing is needed
//! (we measured exactly that with an earlier inline-execution design: 100%
//! failed rounds).
//!
//! The fast path is built from three pieces:
//!
//! - **One unified event channel.**  Network envelopes and worker
//!   completions arrive through the same mailbox (`CoordEvent`), so the
//!   coordinator parks on a single `recv_timeout` and *any* event wakes it
//!   immediately.  An earlier loop polled both sources and parked on the
//!   mailbox alone with a 1 ms cap — a completion landing mid-park waited
//!   out the full millisecond, 25% of a 4 ms task.
//! - **Asynchronous sends.**  `Router::send` is an O(1) enqueue; the mesh's
//!   net thread waits out the shaped wire delay (see `net::transport`).
//! - **A shared dispatch queue.**  Workers pop from one `Injector` instead
//!   of private round-robin channels, so an idle core never sits behind a
//!   long task assigned to a busy sibling (`sched::injector`).
//!
//! The coordinator contains no scheduling/DLB logic of its own — it is an
//! interpreter over the same `ProcessState` the DES drives.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::util::error::{Context, Result};

use crate::config::Config;
use crate::core::data::{DataStore, Payload};
use crate::core::graph::TaskGraph;
use crate::core::ids::{DataId, ProcessId, TaskId};
use crate::core::process::{Effect, ProcessParams, ProcessState};
use crate::core::task::TaskKind;
use crate::metrics::counters::DlbCounters;
use crate::metrics::trace::RunTraces;
use crate::metrics::RunTrace;
use crate::net::message::Envelope;
use crate::net::transport::{mesh_on, FromEnvelope, Mailbox, Router, Shaper};
use crate::sched::injector::Injector;
use crate::sched::queue::ReadyTask;

use super::manifest::Manifest;
use super::pjrt::KernelLibrary;

/// Result of a threaded run.
#[derive(Debug)]
pub struct RealRunResult {
    /// Wallclock seconds from start to last task completion.
    pub makespan: f64,
    pub traces: RunTraces,
    /// Structured span/instant events (empty unless `cfg.trace_enabled`).
    /// Threaded runs have no network track: channels carry no send stamp,
    /// so `MsgFlight` events are DES-only.
    pub trace: RunTrace,
    pub counters: DlbCounters,
    pub per_process_counters: Vec<DlbCounters>,
    /// Final data stores (for numeric verification).
    pub stores: Vec<DataStore>,
    pub kernel_executions: u64,
}

/// Per-process initial data (handle → value), indexed by process.
pub type InitialData = Vec<Vec<(DataId, Payload)>>;

/// Everything that can wake a coordinator: a network envelope or a worker
/// completion, multiplexed over the process's one mailbox channel.
enum CoordEvent {
    Net(Envelope),
    Done(ExecDone),
}

impl FromEnvelope for CoordEvent {
    fn from_envelope(env: Envelope) -> Self {
        CoordEvent::Net(env)
    }
}

/// A task dispatched to a worker: everything needed without touching the
/// coordinator's state.
struct ExecReq {
    rt: ReadyTask,
    kind: TaskKind,
    flops: u64,
    /// Shared handles to the kernel inputs (real mode) — pointer-sized
    /// aliases of the store's blocks, not copies.
    args: Vec<Arc<[f32]>>,
}

struct ExecDone {
    rt: ReadyTask,
    output: Payload,
    duration: f64,
}

/// Run `graph` under `cfg` on real threads.  `use_pjrt` selects kernel
/// execution (requires artifacts); synthetic-only graphs may pass `false`.
pub fn run_threaded(
    cfg: &Config,
    graph: Arc<TaskGraph>,
    initial: InitialData,
    use_pjrt: bool,
) -> Result<RealRunResult> {
    let p = cfg.processes;
    if initial.len() != p {
        return Err(anyhow!("initial data for {} processes, config has {p}", initial.len()));
    }
    let manifest: Option<Arc<Manifest>> = if use_pjrt {
        Some(Arc::new(Manifest::load(&cfg.artifacts_dir).map_err(|e| anyhow!("{e}"))?))
    } else {
        None
    };

    // Same cost model as the DES (`NetworkModel`): hops × latency + size/R.
    // Bandwidth comes from the config like everything else — an earlier
    // version pinned it to infinity and silently dropped the size term.
    let shaper = if cfg.net_latency > 0.0 {
        Some(Shaper {
            latency: Duration::from_secs_f64(cfg.net_latency),
            doubles_per_sec: cfg.doubles_per_sec,
        })
    } else {
        None
    };
    let (router, mailboxes) = mesh_on::<CoordEvent>(p, shaper, cfg.build_topology());
    let params = ProcessParams::from_config(cfg);
    let epoch = Instant::now();

    let mut handles = Vec::with_capacity(p);
    for (i, mailbox) in mailboxes.into_iter().enumerate() {
        let graph = Arc::clone(&graph);
        let router = router.clone();
        let params = params.clone();
        let manifest = manifest.clone();
        let block = cfg.block;
        let seed = cfg.seed;
        let data = initial[i].clone();
        let flops_per_sec = cfg.flops_per_sec;
        handles.push(std::thread::spawn(move || -> Result<ProcessWrap> {
            let me = ProcessId(i as u32);
            let cores = params.cores.max(1);
            let mut ps = ProcessState::new(me, p, graph, params, seed);
            for (d, v) in data {
                ps.store.insert(d, v);
            }
            // worker pool over one shared queue; completions go into the
            // coordinator's own mailbox channel (unshaped — they are local)
            let queue: Arc<Injector<ExecReq>> = Arc::new(Injector::new());
            let done_tx = router.direct_sender(me);
            let mut workers = Vec::with_capacity(cores);
            for w in 0..cores {
                let queue = Arc::clone(&queue);
                let done_tx = done_tx.clone();
                let manifest = manifest.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("ductr-p{i}-w{w}"))
                        .spawn(move || worker_loop(queue, done_tx, manifest, block, flops_per_sec))
                        .expect("spawn worker"),
                );
            }

            let r = coordinator_loop(&mut ps, mailbox, router, epoch, &queue);
            queue.close();
            let mut kernel_execs = 0;
            for w in workers {
                kernel_execs += w.join().map_err(|e| anyhow!("worker panicked: {e:?}"))?;
            }
            r?;
            Ok(ProcessWrap {
                trace: ps.trace.clone(),
                events: ps.recorder.take_events(),
                counters: *ps.counters(),
                store: std::mem::take(&mut ps.store),
                last_completion: ps.last_completion,
                kernel_executions: kernel_execs,
            })
        }));
    }

    let mut traces = RunTraces::new(p);
    let mut trace = RunTrace::new(p);
    let mut counters = DlbCounters::default();
    let mut per = Vec::with_capacity(p);
    let mut stores = Vec::with_capacity(p);
    let mut makespan: f64 = 0.0;
    let mut kexecs = 0u64;
    for (i, h) in handles.into_iter().enumerate() {
        let w = h
            .join()
            .map_err(|e| anyhow!("process {i} panicked: {e:?}"))?
            .with_context(|| format!("process {i} failed"))?;
        makespan = makespan.max(w.last_completion);
        counters.merge(&w.counters);
        per.push(w.counters);
        traces.per_process[i] = w.trace;
        trace.per_process[i] = w.events;
        stores.push(w.store);
        kexecs += w.kernel_executions;
    }
    traces.makespan = makespan;
    Ok(RealRunResult {
        makespan,
        traces,
        trace,
        counters,
        per_process_counters: per,
        stores,
        kernel_executions: kexecs,
    })
}

struct ProcessWrap {
    trace: crate::metrics::trace::WorkloadTrace,
    events: Vec<crate::metrics::TraceEvent>,
    counters: DlbCounters,
    store: DataStore,
    last_completion: f64,
    kernel_executions: u64,
}

/// Worker: pop tasks off the shared queue as cores free up; returns its
/// kernel-execution count.  Exits when the queue closes (normal shutdown)
/// or the coordinator's channel is gone (it halted mid-flight).
fn worker_loop(
    queue: Arc<Injector<ExecReq>>,
    done_tx: Sender<CoordEvent>,
    manifest: Option<Arc<Manifest>>,
    block: usize,
    flops_per_sec: f64,
) -> u64 {
    // PJRT client per worker thread (Rc-internal, not Send)
    let mut lib: Option<KernelLibrary> = manifest.and_then(|m| KernelLibrary::new(m, block).ok());
    let mut kernel_execs = 0u64;
    while let Some(req) = queue.pop_blocking() {
        let t0 = Instant::now();
        let output = match req.kind {
            TaskKind::Synthetic => {
                // Emulate the modeled duration without pinning the core for
                // all of it: sleep the bulk, spin only the precision residue
                // (same hybrid the Shaper uses).  Trade-off: sleeping
                // workers no longer contend for CPU, so an oversubscribed
                // run (processes × cores > physical cores) completes in
                // modeled time instead of stretching under contention — the
                // synthetic mode measures protocol behavior, not machine
                // saturation (real-kernel tasks still burn real CPU).
                let dur = req.flops as f64 / flops_per_sec;
                crate::net::transport::precise_wait(Duration::from_secs_f64(dur));
                Payload::Sim
            }
            kind => {
                let lib = lib.as_mut().expect("kernel task but PJRT disabled");
                let bufs: Vec<&[f32]> = req.args.iter().map(|a| a.as_ref()).collect();
                match lib.execute(kind, &bufs) {
                    Ok(out) => {
                        kernel_execs += 1;
                        Payload::real_from(out)
                    }
                    Err(e) => panic!("kernel {kind} failed: {e:#}"),
                }
            }
        };
        let duration = t0.elapsed().as_secs_f64();
        if done_tx.send(CoordEvent::Done(ExecDone { rt: req.rt, output, duration })).is_err() {
            break; // coordinator gone (halted)
        }
    }
    kernel_execs
}

/// The coordinator event loop: one unified channel (network + completions)
/// plus a deadline-aware park until exactly the next DLB timer.  Never
/// blocks on compute, never sleeps on the wire, and wakes the instant
/// anything happens — the event-driven replacement for the old
/// poll-then-park-1ms cycle.
fn coordinator_loop(
    ps: &mut ProcessState,
    events: Mailbox<CoordEvent>,
    router: Router<CoordEvent>,
    epoch: Instant,
    queue: &Injector<ExecReq>,
) -> Result<()> {
    let now = || epoch.elapsed().as_secs_f64();
    /// Liveness backstop when no timer is armed: bounds the damage of a
    /// missed-wake bug to a visible stall instead of a hang.  NOT a poll
    /// interval — any event interrupts it, so it is never on the hot path.
    const IDLE_BACKSTOP: Duration = Duration::from_millis(100);
    // One scratch buffer for the whole run: every ProcessState step appends
    // into it, the apply pass below drains it in order.
    let mut pending: Vec<Effect> = Vec::with_capacity(64);
    ps.start(now(), &mut pending);
    let mut next_tick = f64::INFINITY;

    loop {
        // apply effects of the last step (sends are O(1) enqueues)
        let mut halted = false;
        for e in pending.drain(..) {
            match e {
                Effect::Send(env) => router.send(env).map_err(|e| anyhow!("router: {e}"))?,
                Effect::StartExec { task } => dispatch_exec(ps, task, queue)?,
                Effect::ScheduleTick { at } => next_tick = next_tick.min(at),
                Effect::Halt => halted = true,
            }
        }
        if halted {
            // workers stop when the shared queue closes (caller's job)
            return Ok(());
        }
        // due timer?
        if now() >= next_tick {
            next_tick = f64::INFINITY;
            ps.on_tick(now(), &mut pending);
            continue;
        }
        // drain without parking while events are queued; park only when
        // idle, until exactly the next timer (or the liveness backstop)
        let ev = match events.try_recv() {
            Some(ev) => Some(ev),
            None => {
                let wait = if next_tick.is_finite() {
                    Duration::from_secs_f64((next_tick - now()).max(0.0))
                } else {
                    IDLE_BACKSTOP
                };
                events.recv_timeout(wait)
            }
        };
        match ev {
            Some(CoordEvent::Net(env)) => ps.on_message(env, now(), &mut pending),
            Some(CoordEvent::Done(d)) => {
                ps.on_exec_complete(d.rt, d.output, d.duration, now(), &mut pending)
            }
            None => {} // timer due (or backstop); handled at the loop top
        }
    }
}

/// Gather the task's inputs as shared handles and enqueue it for whichever
/// worker frees up first.
fn dispatch_exec(ps: &ProcessState, rt: ReadyTask, queue: &Injector<ExecReq>) -> Result<()> {
    let node = ps.graph.task(rt.task);
    let args: Vec<Arc<[f32]>> = if node.kind == TaskKind::Synthetic {
        Vec::new()
    } else {
        let mut v = Vec::with_capacity(node.args.len());
        for &a in &node.args {
            let p = ps
                .store
                .get(a)
                .ok_or_else(|| anyhow!("missing input {a} for {}", TaskId::idx(rt.task)))?;
            match p.real_arc() {
                Some(buf) => v.push(buf), // aliases the store's block
                None => return Err(anyhow!("non-real payload for {a} in real mode")),
            }
        }
        v
    };
    queue.push(ExecReq { rt, kind: node.kind, flops: node.flops, args });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::graph::GraphBuilder;

    /// Synthetic imbalanced bag over threads — no PJRT needed.
    fn bag(n: usize, p: usize, dlb: bool) -> (Config, Arc<TaskGraph>, InitialData) {
        let mut cfg = Config::default();
        cfg.processes = p;
        cfg.dlb_enabled = dlb;
        cfg.wt = 2;
        cfg.delta = 0.001;
        cfg.flops_per_sec = 1e9; // 4 ms per 4e6-flop task
        cfg.net_latency = 0.0;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            let d = b.data(ProcessId(0), 16, 16);
            b.task(TaskKind::Synthetic, vec![], d, 4_000_000, None);
        }
        (cfg, b.build(), vec![vec![]; p])
    }

    #[test]
    fn threaded_bag_completes() {
        let (cfg, g, init) = bag(12, 3, false);
        let r = run_threaded(&cfg, g, init, false).expect("run");
        assert!(r.makespan > 0.0);
        assert_eq!(r.counters.tasks_exported, 0);
    }

    #[test]
    fn threaded_dlb_migrates_and_speeds_up() {
        let (cfg0, g0, i0) = bag(32, 4, false);
        let off = run_threaded(&cfg0, g0, i0, false).expect("off");
        let (cfg1, g1, i1) = bag(32, 4, true);
        let on = run_threaded(&cfg1, g1, i1, false).expect("on");
        assert!(on.counters.tasks_exported > 0, "must migrate");
        assert!(
            on.makespan < off.makespan * 0.7,
            "DLB should help: on={} off={}",
            on.makespan,
            off.makespan
        );
    }

    #[test]
    fn threaded_stealing_and_diffusion_migrate() {
        use crate::config::PolicyKind;
        for policy in [PolicyKind::WorkStealing, PolicyKind::Diffusion] {
            let (mut cfg, g, init) = bag(24, 3, true);
            cfg.policy = policy;
            let r = run_threaded(&cfg, g, init, false)
                .unwrap_or_else(|e| panic!("{policy} failed: {e}"));
            assert!(r.makespan > 0.0);
            assert!(r.counters.tasks_exported > 0, "{policy} must migrate work");
            assert_eq!(r.counters.tasks_exported, r.counters.tasks_received, "{policy}");
        }
    }

    #[test]
    fn threaded_run_collects_trace_events_when_enabled() {
        use crate::metrics::TraceEvent;
        let (mut cfg, g, init) = bag(12, 2, true);
        cfg.trace_enabled = true;
        let r = run_threaded(&cfg, g, init, false).expect("run");
        assert!(!r.trace.is_empty(), "tracing on must record events");
        let all: Vec<&TraceEvent> = r.trace.per_process.iter().flatten().collect();
        assert!(all.iter().any(|e| matches!(e, TraceEvent::ExecEnd { .. })));
        assert!(all.iter().any(|e| matches!(e, TraceEvent::TaskReady { .. })));
        // wallclock stamps are monotone per process stream
        for stream in &r.trace.per_process {
            for w in stream.windows(2) {
                assert!(w[0].time() <= w[1].time() + 1e-9);
            }
        }
    }

    #[test]
    fn dependency_chain_is_ordered_across_threads() {
        // chain alternating between two processes — forces TaskDone routing
        let mut cfg = Config::default();
        cfg.processes = 2;
        cfg.dlb_enabled = false;
        cfg.flops_per_sec = 1e9;
        cfg.net_latency = 0.0;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        let mut prev: Option<DataId> = None;
        for i in 0..10 {
            let d = b.data(ProcessId(i % 2), 8, 8);
            let args = prev.map(|x| vec![x]).unwrap_or_default();
            b.task(TaskKind::Synthetic, args, d, 500_000, None);
            prev = Some(d);
        }
        let g = b.build();
        let r = run_threaded(&cfg, g, vec![vec![], vec![]], false).expect("run");
        assert!(r.makespan > 0.0);
    }

    #[test]
    fn multicore_process_runs_parallel() {
        // one process, 4 cores, 8 independent 10ms tasks → ~2 batches
        let mut cfg = Config::default();
        cfg.processes = 1;
        cfg.cores_per_process = 4;
        cfg.dlb_enabled = false;
        cfg.flops_per_sec = 1e9;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        for _ in 0..8 {
            let d = b.data(ProcessId(0), 8, 8);
            b.task(TaskKind::Synthetic, vec![], d, 10_000_000, None);
        }
        let g = b.build();
        let r = run_threaded(&cfg, g, vec![vec![]], false).expect("run");
        assert!(
            r.makespan < 0.060,
            "4 cores × 2 waves of 10ms ≈ 20ms, got {}",
            r.makespan
        );
    }

    /// Regression for the mid-park completion stall: an `ExecDone` must
    /// wake the coordinator in ≪ 1 ms.  The old loop parked on the mailbox
    /// alone with a 1 ms cap, so 30 back-to-back 0.2 ms tasks paid ~1 ms
    /// each (≈ 30 ms total); the unified channel finishes in ~6 ms.
    #[test]
    fn completion_wakes_coordinator_immediately() {
        let mut cfg = Config::default();
        cfg.processes = 1;
        cfg.cores_per_process = 1;
        cfg.dlb_enabled = false;
        cfg.flops_per_sec = 1e9;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        for _ in 0..30 {
            let d = b.data(ProcessId(0), 8, 8);
            b.task(TaskKind::Synthetic, vec![], d, 200_000, None); // 0.2 ms
        }
        let g = b.build();
        let r = run_threaded(&cfg, g, vec![vec![]], false).expect("run");
        assert!(
            r.makespan < 0.015,
            "completions must wake the coordinator, not wait out a poll: {}",
            r.makespan
        );
    }

    /// End-to-end satellite check for the bandwidth plumb: a 4096-double
    /// TaskDone crossing the wire at R = 1e6 doubles/s must cost ≥ ~4 ms.
    /// The old runtime pinned `doubles_per_sec` to infinity, so this chain
    /// finished in well under a millisecond of wire time.
    #[test]
    fn threaded_charges_the_bandwidth_term() {
        let mut cfg = Config::default();
        cfg.processes = 2;
        cfg.dlb_enabled = false;
        cfg.flops_per_sec = 1e9;
        cfg.net_latency = 0.0002;
        cfg.doubles_per_sec = 1e6;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        let d0 = b.data(ProcessId(0), 64, 64); // 4096 doubles on the wire
        b.task(TaskKind::Synthetic, vec![], d0, 100_000, None);
        let d1 = b.data(ProcessId(1), 8, 8);
        b.task(TaskKind::Synthetic, vec![d0], d1, 100_000, None);
        let g = b.build();
        let r = run_threaded(&cfg, g, vec![vec![], vec![]], false).expect("run");
        assert!(
            r.makespan >= 0.004,
            "4096 doubles at 1e6/s must charge ≥ 4 ms of wire time, got {}",
            r.makespan
        );
    }

    /// Shared-queue head-of-line test: 1 long + 6 short tasks on 2 cores.
    /// With pop-time assignment one worker takes the long task and the
    /// other drains every short one (all done by ~30 ms); the old
    /// round-robin channels parked half the shorts behind the long task
    /// (≥ 55 ms).
    #[test]
    fn shared_queue_avoids_head_of_line_blocking() {
        use crate::metrics::TraceEvent;
        let mut cfg = Config::default();
        cfg.processes = 1;
        cfg.cores_per_process = 2;
        cfg.dlb_enabled = false;
        cfg.flops_per_sec = 1e9;
        cfg.trace_enabled = true;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        let d = b.data(ProcessId(0), 8, 8);
        b.task(TaskKind::Synthetic, vec![], d, 50_000_000, None); // 50 ms
        for _ in 0..6 {
            let d = b.data(ProcessId(0), 8, 8);
            b.task(TaskKind::Synthetic, vec![], d, 5_000_000, None); // 5 ms
        }
        let g = b.build();
        let r = run_threaded(&cfg, g, vec![vec![]], false).expect("run");
        let mut short_ends = Vec::new();
        for e in r.trace.per_process.iter().flatten() {
            if let TraceEvent::ExecEnd { task, t, .. } = e {
                if task.idx() > 0 {
                    short_ends.push(*t);
                }
            }
        }
        assert_eq!(short_ends.len(), 6, "all short tasks traced");
        let worst = short_ends.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            worst < 0.045,
            "a short task waited behind the long one (head-of-line): {worst}"
        );
    }

    /// Satellite: the coordinator must keep answering the pairing protocol
    /// while 5 ms-latency envelopes are in flight.  With the old blocking
    /// sends the coordinator slept the wire time out per message; now the
    /// imbalanced bag still migrates and beats the 64 ms serial floor.
    #[test]
    fn dlb_pairs_under_shaped_sends() {
        let (mut cfg, g, init) = bag(24, 4, true);
        cfg.net_latency = 0.005;
        cfg.validate().expect("valid");
        let r = run_threaded(&cfg, g, init, false).expect("run");
        assert!(r.counters.tasks_exported > 0, "must migrate despite shaped sends");
        assert!(
            r.makespan < 0.090,
            "migration must beat the 24 × 4 ms serial floor: {}",
            r.makespan
        );
    }
}
