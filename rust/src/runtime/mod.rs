//! Request-path runtime: the artifact manifest, the PJRT kernel library,
//! and the threaded real-mode driver.

pub mod manifest;
pub mod pjrt;
pub mod threaded;

pub use manifest::{KernelEntry, Manifest, ManifestError};
pub use pjrt::KernelLibrary;
pub use threaded::{run_threaded, InitialData, RealRunResult};
