//! End-to-end Cholesky drivers: set up the DAG + data, run in DES or
//! threaded mode, verify, and report.

use std::sync::Arc;

use crate::anyhow;
use crate::util::error::Result;

use crate::config::Config;
use crate::core::data::Payload;
use crate::core::ids::DataId;
use crate::metrics::counters::DlbCounters;
use crate::metrics::trace::RunTraces;
use crate::metrics::RunTrace;
use crate::runtime::threaded::{run_threaded, InitialData};
use crate::util::rng::Rng;

use super::dag::{build, CholeskyDag};
use super::grid::ProcessGrid;
use super::verify::{gather_lower, residual, Dense};

/// Unified report for one Cholesky run in either mode.
#[derive(Debug)]
pub struct CholeskyReport {
    pub makespan: f64,
    pub traces: RunTraces,
    /// Structured span/instant events (empty unless `cfg.trace_enabled`).
    pub trace: RunTrace,
    pub counters: DlbCounters,
    pub per_process_counters: Vec<DlbCounters>,
    /// Relative residual of L·Lᵀ vs A (real mode only).
    pub residual: Option<f64>,
    /// Fraction of S·P·makespan actually spent on task flops (sim mode).
    pub utilization: Option<f64>,
    pub tasks: usize,
    pub static_imbalance: f64,
}

/// Generate the deterministic SPD test matrix A = M·Mᵀ + n·I (f32).
pub fn make_spd(n: usize, seed: u64) -> Dense {
    let mut rng = Rng::new(seed ^ 0x5bd1e995);
    let mut m = Dense::zeros(n);
    for v in m.a.iter_mut() {
        *v = (rng.next_f64() as f32) - 0.5;
    }
    let mut a = Dense::zeros(n);
    for i in 0..n {
        for j in 0..=i {
            let mut acc = 0.0f32;
            for k in 0..n {
                acc += m.get(i, k) * m.get(j, k);
            }
            a.set(i, j, acc + if i == j { n as f32 } else { 0.0 });
            a.set(j, i, acc + if i == j { n as f32 } else { 0.0 });
        }
    }
    a
}

/// Slice block (i, j) out of a dense matrix.
fn block_of(a: &Dense, i: usize, j: usize, b: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(b * b);
    for r in 0..b {
        for c in 0..b {
            out.push(a.get(i * b + r, j * b + c));
        }
    }
    out
}

/// Build the per-process initial data for a real run: each process gets the
/// version-0 values of the lower-triangle blocks it owns.
pub fn initial_data(dag: &CholeskyDag, a: &Dense, processes: usize) -> InitialData {
    let mut init: InitialData = vec![Vec::new(); processes];
    for i in 0..dag.nb {
        for j in 0..=i {
            let h: DataId = dag.handle(i, j);
            let home = dag.graph.meta(h).home;
            init[home.idx()].push((h, Payload::real_from(block_of(a, i, j, dag.block))));
        }
    }
    init
}

/// Run the paper's benchmark in DES mode at any scale.
pub fn run_sim(cfg: &Config) -> Result<CholeskyReport> {
    let grid = ProcessGrid::new(cfg.effective_grid());
    if grid.size() != cfg.processes {
        return Err(anyhow!("grid {}x{} != {} processes", grid.rows, grid.cols, cfg.processes));
    }
    let dag = build(cfg.nb, cfg.block, grid);
    let tasks = dag.graph.num_tasks();
    // sim.threads picks the engine: sharded parallel (> 1) or the
    // single-threaded oracle — bit-identical either way.
    let r = crate::sim::run_config(cfg, Arc::clone(&dag.graph)).map_err(|e| anyhow!("sim: {e}"))?;
    Ok(CholeskyReport {
        makespan: r.makespan,
        traces: r.traces,
        trace: r.trace,
        counters: r.counters,
        per_process_counters: r.per_process_counters,
        residual: None,
        utilization: Some(r.utilization),
        tasks,
        static_imbalance: grid.imbalance(cfg.nb),
    })
}

/// Run the benchmark on real threads with PJRT kernels and verify numerics.
pub fn run_real(cfg: &Config) -> Result<CholeskyReport> {
    let grid = ProcessGrid::new(cfg.effective_grid());
    if grid.size() != cfg.processes {
        return Err(anyhow!("grid {}x{} != {} processes", grid.rows, grid.cols, cfg.processes));
    }
    let dag = build(cfg.nb, cfg.block, grid);
    let tasks = dag.graph.num_tasks();
    let n = cfg.nb * cfg.block;
    let a = make_spd(n, cfg.seed);
    let init = initial_data(&dag, &a, cfg.processes);
    let r = run_threaded(cfg, Arc::clone(&dag.graph), init, true)?;
    let l = gather_lower(&dag, &r.stores).map_err(|e| anyhow!("gather: {e}"))?;
    let res = residual(&l, &a);
    Ok(CholeskyReport {
        makespan: r.makespan,
        traces: r.traces,
        trace: r.trace,
        counters: r.counters,
        per_process_counters: r.per_process_counters,
        residual: Some(res),
        utilization: None,
        tasks,
        static_imbalance: grid.imbalance(cfg.nb),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Grid;

    fn sim_cfg(nb: usize, p: usize, grid: (usize, usize), dlb: bool, seed: u64) -> Config {
        let mut c = Config::default();
        c.processes = p;
        c.grid = Some(Grid::new(grid.0, grid.1));
        c.nb = nb;
        c.block = 128;
        c.dlb_enabled = dlb;
        c.seed = seed;
        c.wt = 3;
        c.delta = 0.001;
        c.validate().expect("valid");
        c
    }

    #[test]
    fn sim_run_completes_and_is_deterministic() {
        let cfg = sim_cfg(8, 4, (2, 2), true, 42);
        let a = run_sim(&cfg).expect("a");
        let b = run_sim(&cfg).expect("b");
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.tasks, 8 + 2 * 28 + 56);
        assert!(a.makespan > 0.0);
    }

    #[test]
    fn spd_matrix_is_symmetric_dominant() {
        let a = make_spd(32, 1);
        for i in 0..32 {
            for j in 0..32 {
                assert_eq!(a.get(i, j), a.get(j, i));
            }
            assert!(a.get(i, i) > 16.0);
        }
    }

    #[test]
    fn initial_data_covers_lower_triangle() {
        let grid = ProcessGrid::new(Grid::new(2, 2));
        let dag = build(4, 8, grid);
        let a = make_spd(32, 2);
        let init = initial_data(&dag, &a, 4);
        let total: usize = init.iter().map(|v| v.len()).sum();
        assert_eq!(total, 4 * 5 / 2);
    }

    #[test]
    fn dlb_off_vs_on_sim_nonsquare_grid() {
        // the paper's effect: on a non-square grid, DLB should not hurt and
        // typically helps by a few percent
        let off = run_sim(&sim_cfg(12, 10, (2, 5), false, 7)).expect("off");
        let on = run_sim(&sim_cfg(12, 10, (2, 5), true, 7)).expect("on");
        assert!(on.counters.transactions > 0, "expected pairing activity");
        assert!(
            on.makespan < off.makespan * 1.05,
            "DLB must not make things much worse: on={} off={}",
            on.makespan,
            off.makespan
        );
    }
}
