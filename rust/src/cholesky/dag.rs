//! Right-looking block Cholesky task-graph generation (paper §5, Fig 2).
//!
//! For an nb×nb block matrix (lower triangle stored), column j produces:
//!
//! ```text
//! L[j,j]  = potrf(A[j,j])
//! L[i,j]  = trsm(L[j,j], A[i,j])            i = j+1..nb
//! A[i,i] -= L[i,j]·L[i,j]ᵀ        (syrk)    i = j+1..nb
//! A[i,k] -= L[i,j]·L[k,j]ᵀ        (gemm)    j < k < i
//! ```
//!
//! Must stay in sync with `python/compile/model.py::block_cholesky` — the
//! Python version is the build-time validation of the same algebra.

use std::sync::Arc;

use crate::core::graph::{GraphBuilder, TaskGraph};
use crate::core::ids::DataId;
use crate::core::task::TaskKind;

use super::grid::ProcessGrid;

/// The generated graph plus the handle map for block (i, j), i ≥ j.
pub struct CholeskyDag {
    pub graph: Arc<TaskGraph>,
    pub nb: usize,
    pub block: usize,
    /// Handle of block (i, j) for i ≥ j (row-major triangular index).
    handles: Vec<DataId>,
}

/// Triangular index of (i, j), i ≥ j.
fn tri(i: usize, j: usize) -> usize {
    debug_assert!(i >= j);
    i * (i + 1) / 2 + j
}

impl CholeskyDag {
    pub fn handle(&self, i: usize, j: usize) -> DataId {
        self.handles[tri(i, j)]
    }

    /// Expected task count: nb potrf + C(nb,2) trsm + C(nb,2) syrk +
    /// C(nb,3) gemm.
    pub fn expected_tasks(nb: usize) -> usize {
        let c2 = nb * nb.saturating_sub(1) / 2;
        let c3 = nb * nb.saturating_sub(1) * nb.saturating_sub(2) / 6;
        nb + 2 * c2 + c3
    }
}

/// Build the Cholesky DAG with block-cyclic owner-computes placement.
pub fn build(nb: usize, block: usize, grid: ProcessGrid) -> CholeskyDag {
    assert!(nb >= 1 && block >= 1);
    let b = block as u64;
    let mut gb = GraphBuilder::new();

    // lower-triangle block handles
    let mut handles = vec![DataId(0); tri(nb - 1, nb - 1) + 1];
    for i in 0..nb {
        for j in 0..=i {
            handles[tri(i, j)] = gb.data(grid.owner(i, j), block, block);
        }
    }
    let h = |i: usize, j: usize| handles[tri(i, j)];

    for j in 0..nb {
        // L[j,j] = potrf(A[j,j])
        gb.task(TaskKind::Potrf, vec![h(j, j)], h(j, j), TaskKind::Potrf.flops_for_block(b), None);
        // panel: L[i,j] = trsm(L[j,j], A[i,j])
        for i in (j + 1)..nb {
            gb.task(
                TaskKind::Trsm,
                vec![h(j, j), h(i, j)],
                h(i, j),
                TaskKind::Trsm.flops_for_block(b),
                None,
            );
        }
        // trailing updates
        for i in (j + 1)..nb {
            gb.task(
                TaskKind::Syrk,
                vec![h(i, i), h(i, j)],
                h(i, i),
                TaskKind::Syrk.flops_for_block(b),
                None,
            );
            for k in (j + 1)..i {
                gb.task(
                    TaskKind::Gemm,
                    vec![h(i, k), h(i, j), h(k, j)],
                    h(i, k),
                    TaskKind::Gemm.flops_for_block(b),
                    None,
                );
            }
        }
    }

    CholeskyDag { graph: gb.build(), nb, block, handles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Grid;
    use crate::core::ids::ProcessId;

    fn grid(r: usize, c: usize) -> ProcessGrid {
        ProcessGrid::new(Grid::new(r, c))
    }

    #[test]
    fn task_count_formula() {
        for nb in 1..=8 {
            let d = build(nb, 8, grid(2, 2));
            assert_eq!(d.graph.num_tasks(), CholeskyDag::expected_tasks(nb), "nb={nb}");
        }
        // paper Fig 4: 12×12 blocks
        let d = build(12, 8, grid(2, 5));
        assert_eq!(d.graph.num_tasks(), 12 + 2 * 66 + 220);
    }

    #[test]
    fn graph_is_acyclic() {
        let d = build(6, 8, grid(2, 3));
        d.graph.topo_order().expect("acyclic");
    }

    #[test]
    fn kind_counts() {
        let nb = 5;
        let d = build(nb, 8, grid(1, 2));
        let count = |k: TaskKind| d.graph.tasks.iter().filter(|t| t.kind == k).count();
        assert_eq!(count(TaskKind::Potrf), nb);
        assert_eq!(count(TaskKind::Trsm), nb * (nb - 1) / 2);
        assert_eq!(count(TaskKind::Syrk), nb * (nb - 1) / 2);
        assert_eq!(count(TaskKind::Gemm), nb * (nb - 1) * (nb - 2) / 6);
    }

    #[test]
    fn placement_follows_output_owner() {
        let g = grid(2, 3);
        let d = build(6, 8, g);
        for t in &d.graph.tasks {
            let meta = d.graph.meta(t.output);
            assert_eq!(t.placement, meta.home, "owner computes");
        }
    }

    #[test]
    fn first_potrf_is_sole_root_column_zero() {
        let d = build(4, 8, grid(2, 2));
        // the first task (potrf(0,0)) must have no deps
        assert!(d.graph.tasks[0].deps.is_empty());
        assert_eq!(d.graph.tasks[0].kind, TaskKind::Potrf);
        // every trsm in column 0 depends on it
        for t in &d.graph.tasks {
            if t.kind == TaskKind::Trsm && t.args[0] == d.handle(0, 0) {
                assert!(t.deps.contains(&d.graph.tasks[0].id));
            }
        }
    }

    #[test]
    fn dependency_chain_depth_is_linear_in_nb() {
        // the critical path of right-looking cholesky is Θ(nb) potrf+trsm+
        // gemm chains, so longest path flops grows ~linearly in nb.
        let d4 = build(4, 8, grid(2, 2));
        let d8 = build(8, 8, grid(2, 2));
        let c4 = d4.graph.critical_path_flops();
        let c8 = d8.graph.critical_path_flops();
        let ratio = c8 as f64 / c4 as f64;
        assert!(ratio > 1.7 && ratio < 2.7, "ratio {ratio}");
    }

    #[test]
    fn single_block_is_one_potrf() {
        let d = build(1, 16, grid(1, 1));
        assert_eq!(d.graph.num_tasks(), 1);
        assert_eq!(d.graph.tasks[0].kind, TaskKind::Potrf);
        assert_eq!(d.graph.tasks[0].placement, ProcessId(0));
    }

    #[test]
    fn last_task_is_final_potrf() {
        let d = build(5, 8, grid(1, 2));
        let last = d.graph.tasks.last().expect("nonempty");
        assert_eq!(last.kind, TaskKind::Potrf);
        assert_eq!(last.output, d.handle(4, 4));
        assert!(last.dependents.is_empty());
    }
}
