//! The block Cholesky benchmark (paper §5): DAG generation, block-cyclic
//! grids, run drivers for both modes, and numeric verification.

pub mod dag;
pub mod driver;
pub mod grid;
pub mod verify;

pub use dag::{build, CholeskyDag};
pub use driver::{initial_data, make_spd, run_real, run_sim, CholeskyReport};
pub use grid::ProcessGrid;
pub use verify::{gather_lower, residual, Dense};
