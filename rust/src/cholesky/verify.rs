//! Numeric verification of a real-mode factorization: reassemble L from the
//! distributed stores and check L·Lᵀ ≈ A.

use crate::core::data::DataStore;

use super::dag::CholeskyDag;

/// Dense column-major-free helper: row-major n×n matrix.
#[derive(Debug, Clone)]
pub struct Dense {
    pub n: usize,
    pub a: Vec<f32>,
}

impl Dense {
    pub fn zeros(n: usize) -> Self {
        Dense { n, a: vec![0.0; n * n] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.a[i * self.n + j] = v;
    }
}

/// Gather the lower-triangular factor from the per-process stores.
/// Block (i, j) lives in the store of its home process.
pub fn gather_lower(dag: &CholeskyDag, stores: &[DataStore]) -> Result<Dense, String> {
    let b = dag.block;
    let n = dag.nb * b;
    let mut l = Dense::zeros(n);
    for i in 0..dag.nb {
        for j in 0..=i {
            let h = dag.handle(i, j);
            let home = dag.graph.meta(h).home;
            let payload = stores[home.idx()]
                .get(h)
                .ok_or_else(|| format!("block ({i},{j}) missing from {home}"))?;
            let buf = payload
                .real()
                .ok_or_else(|| format!("block ({i},{j}) is not real data"))?;
            if buf.len() != b * b {
                return Err(format!("block ({i},{j}) has {} elems, want {}", buf.len(), b * b));
            }
            for r in 0..b {
                for c in 0..b {
                    let (gi, gj) = (i * b + r, j * b + c);
                    if gi >= gj {
                        l.set(gi, gj, buf[r * b + c]);
                    }
                }
            }
        }
    }
    Ok(l)
}

/// Max-norm relative residual ‖L·Lᵀ − A‖ / (n·‖A‖) over the lower triangle.
pub fn residual(l: &Dense, a: &Dense) -> f64 {
    assert_eq!(l.n, a.n);
    let n = l.n;
    let mut amax = 0.0f64;
    let mut emax = 0.0f64;
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0f64;
            for k in 0..=j {
                s += l.get(i, k) as f64 * l.get(j, k) as f64;
            }
            let av = a.get(i, j) as f64;
            amax = amax.max(av.abs());
            emax = emax.max((s - av).abs());
        }
    }
    if amax == 0.0 {
        return emax;
    }
    emax / (n as f64 * amax)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dense reference Cholesky for the tests.
    pub fn cholesky_dense(a: &Dense) -> Dense {
        let n = a.n;
        let mut l = Dense::zeros(n);
        for j in 0..n {
            let mut d = a.get(j, j) as f64;
            for k in 0..j {
                d -= (l.get(j, k) as f64).powi(2);
            }
            let d = d.sqrt();
            l.set(j, j, d as f32);
            for i in (j + 1)..n {
                let mut s = a.get(i, j) as f64;
                for k in 0..j {
                    s -= l.get(i, k) as f64 * l.get(j, k) as f64;
                }
                l.set(i, j, (s / d) as f32);
            }
        }
        l
    }

    fn spd(n: usize) -> Dense {
        let mut m = Dense::zeros(n);
        let mut s = 12345u64;
        for i in 0..n * n {
            m.a[i] = (crate::util::rng::splitmix64(&mut s) as f64 / u64::MAX as f64) as f32 - 0.5;
        }
        // a = m mᵀ + n i
        let mut a = Dense::zeros(n);
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0f32;
                for k in 0..n {
                    acc += m.get(i, k) * m.get(j, k);
                }
                a.set(i, j, acc + if i == j { n as f32 } else { 0.0 });
            }
        }
        a
    }

    #[test]
    fn dense_cholesky_residual_small() {
        let a = spd(24);
        let l = cholesky_dense(&a);
        assert!(residual(&l, &a) < 1e-6);
    }

    #[test]
    fn residual_detects_corruption() {
        let a = spd(16);
        let mut l = cholesky_dense(&a);
        l.set(7, 3, l.get(7, 3) + 1.0);
        assert!(residual(&l, &a) > 1e-4);
    }
}
