//! Block-cyclic distribution over a 2-D process grid (§5).
//!
//! Block (i, j) of the matrix lives at grid position (i mod pr, j mod pc);
//! the paper's experiments deliberately use non-square grids (2×5, 3×5,
//! 11×1) whose inherent imbalance DLB is asked to repair.

use crate::config::Grid;
use crate::core::ids::ProcessId;

/// A process grid with block-cyclic ownership.
#[derive(Debug, Clone, Copy)]
pub struct ProcessGrid {
    pub rows: usize,
    pub cols: usize,
}

impl ProcessGrid {
    pub fn new(g: Grid) -> Self {
        ProcessGrid { rows: g.rows, cols: g.cols }
    }

    pub fn size(&self) -> usize {
        self.rows * self.cols
    }

    /// Owner of block (i, j): row-major rank of (i mod pr, j mod pc).
    pub fn owner(&self, i: usize, j: usize) -> ProcessId {
        let r = i % self.rows;
        let c = j % self.cols;
        ProcessId((r * self.cols + c) as u32)
    }

    /// Number of lower-triangle blocks (i ≥ j) of an nb×nb block matrix
    /// owned by each process — the static load distribution whose imbalance
    /// Fig 4/5 visualize.
    pub fn lower_triangle_counts(&self, nb: usize) -> Vec<usize> {
        let mut counts = vec![0usize; self.size()];
        for i in 0..nb {
            for j in 0..=i {
                counts[self.owner(i, j).idx()] += 1;
            }
        }
        counts
    }

    /// Static imbalance ratio: max / mean of the block counts (1.0 = even).
    pub fn imbalance(&self, nb: usize) -> f64 {
        let counts = self.lower_triangle_counts(nb);
        let max = *counts.iter().max().expect("nonempty") as f64;
        let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_is_block_cyclic() {
        let g = ProcessGrid::new(Grid::new(2, 3));
        assert_eq!(g.owner(0, 0), ProcessId(0));
        assert_eq!(g.owner(0, 1), ProcessId(1));
        assert_eq!(g.owner(0, 2), ProcessId(2));
        assert_eq!(g.owner(1, 0), ProcessId(3));
        assert_eq!(g.owner(2, 0), ProcessId(0)); // wraps rows
        assert_eq!(g.owner(0, 3), ProcessId(0)); // wraps cols
    }

    #[test]
    fn all_processes_used() {
        let g = ProcessGrid::new(Grid::new(2, 5));
        let counts = g.lower_triangle_counts(12);
        assert_eq!(counts.len(), 10);
        assert!(counts.iter().all(|&c| c > 0));
        let total: usize = counts.iter().sum();
        assert_eq!(total, 12 * 13 / 2);
    }

    #[test]
    fn square_grid_is_more_balanced_than_column() {
        // paper §5: imbalance is minimized for square-ish grids
        let nb = 12;
        let sq = ProcessGrid::new(Grid::new(3, 4)).imbalance(nb);
        let col = ProcessGrid::new(Grid::new(12, 1)).imbalance(nb);
        assert!(sq < col, "square {sq} vs column {col}");
    }

    #[test]
    fn paper_grids_are_imbalanced() {
        // the Fig 4/5 configurations have real static imbalance to repair
        assert!(ProcessGrid::new(Grid::new(2, 5)).imbalance(12) > 1.05);
        assert!(ProcessGrid::new(Grid::new(3, 5)).imbalance(12) > 1.05);
        assert!(ProcessGrid::new(Grid::new(11, 1)).imbalance(11) > 1.05);
    }
}
