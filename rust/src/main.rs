//! `ductr` — CLI launcher for the DLB task-runtime reproduction.
//!
//! Subcommands:
//! - `run`             one workload run (sim or real mode), full knobs
//! - `experiment`      regenerate a paper figure: fig1|fig3|fig4|fig5|sec4
//! - `calibrate-wt`    the §6 offline W_T calibration (run without DLB)
//! - `artifacts-check` load + compile + smoke-run every AOT kernel
//!
//! `ductr help` prints the full usage.

use std::process::ExitCode;

mod commands;

fn main() -> ExitCode {
    match commands::dispatch() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
