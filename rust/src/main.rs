//! `ductr` — CLI launcher for the DLB task-runtime reproduction.
//!
//! Subcommands:
//! - `run`             one workload run (sim or real mode), full knobs
//! - `experiment`      regenerate a paper figure: fig1|fig3|fig4|fig5|sec4
//! - `calibrate-wt`    the §6 offline W_T calibration (run without DLB)
//! - `artifacts-check` load + compile + smoke-run every AOT kernel
//!
//! `ductr help` prints the full usage.

use std::process::ExitCode;

mod commands;

fn main() -> ExitCode {
    env_logger_lite();
    match commands::dispatch() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Minimal RUST_LOG-style gate for the `log` macros (no env_logger offline).
fn env_logger_lite() {
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("trace") => log::LevelFilter::Trace,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("info") => log::LevelFilter::Info,
        Ok("warn") => log::LevelFilter::Warn,
        _ => log::LevelFilter::Error,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}
