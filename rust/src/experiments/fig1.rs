//! Figure 1: probability of finding ≥ 1 of K busy processes among P with n
//! uniform no-replacement tries — exact hypergeometric (eq. 1) validated by
//! Monte Carlo over the *implementation's* partner draw.

use crate::prob::hypergeom::Hypergeometric;
use crate::util::plot::{self, Series};
use crate::util::rng::Rng;

/// One curve: fixed (P, K), success probability vs tries n = 1..=n_max.
#[derive(Debug, Clone)]
pub struct Curve {
    pub population: u64,
    pub busy: u64,
    /// (n, exact, monte-carlo) triples.
    pub points: Vec<(u64, f64, f64)>,
}

#[derive(Debug, Clone)]
pub struct Fig1Result {
    pub curves: Vec<Curve>,
    /// The paper's asymptote check: success at K = P/2, n = 5 vs 1 − 2⁻⁵.
    pub k_half_n5: f64,
    pub asymptote_n5: f64,
}

/// Reproduce both panels (P = 10 and P = 100, K/P ∈ {0.1, 0.3, 0.5, 0.7,
/// 0.9}); `mc_reps` = Monte-Carlo repetitions per point (0 disables).
pub fn run(n_max: u64, mc_reps: usize, seed: u64) -> Fig1Result {
    let mut rng = Rng::new(seed);
    let mut curves = Vec::new();
    for &p in &[10u64, 100u64] {
        for frac in [0.1, 0.3, 0.5, 0.7, 0.9] {
            let k = ((p as f64) * frac).round() as u64;
            let mut points = Vec::new();
            for n in 1..=n_max.min(p) {
                let h = Hypergeometric::new(p, k, n);
                let exact = h.success_probability();
                let mc = if mc_reps > 0 {
                    h.monte_carlo_success(mc_reps, &mut rng)
                } else {
                    f64::NAN
                };
                points.push((n, exact, mc));
            }
            curves.push(Curve { population: p, busy: k, points });
        }
    }
    let k_half_n5 = Hypergeometric::new(100, 50, 5).success_probability();
    Fig1Result { curves, k_half_n5, asymptote_n5: Hypergeometric::asymptotic_success(0.5, 5) }
}

impl Fig1Result {
    /// ASCII rendering of one panel (`population` = 10 or 100).
    pub fn render_panel(&self, population: u64) -> String {
        let series: Vec<Series> = self
            .curves
            .iter()
            .filter(|c| c.population == population)
            .map(|c| {
                Series::new(
                    format!("K={}", c.busy),
                    c.points.iter().map(|&(n, e, _)| (n as f64, e)).collect(),
                )
            })
            .collect();
        plot::plot(
            &format!("Fig 1: success probability, P = {population}"),
            &series,
            60,
            16,
        )
    }

    /// CSV rows: population, busy, tries, exact, monte_carlo.
    pub fn csv_rows(&self) -> Vec<Vec<f64>> {
        let mut rows = Vec::new();
        for c in &self.curves {
            for &(n, e, mc) in &c.points {
                rows.push(vec![c.population as f64, c.busy as f64, n as f64, e, mc]);
            }
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_shape() {
        let r = run(10, 2000, 1);
        // paper: n = 5 at K = P/2 gives > 96%
        assert!(r.k_half_n5 > 0.96);
        assert!((r.asymptote_n5 - 0.96875).abs() < 1e-12);
        // monotone in n for every curve; MC close to exact
        for c in &r.curves {
            let mut prev = 0.0;
            for &(_, exact, mc) in &c.points {
                assert!(exact >= prev - 1e-12);
                prev = exact;
                assert!((mc - exact).abs() < 0.05, "MC {mc} vs exact {exact}");
            }
        }
        assert_eq!(r.curves.len(), 10);
    }

    #[test]
    fn renders_both_panels() {
        let r = run(8, 0, 1);
        assert!(r.render_panel(10).contains("P = 10"));
        assert!(r.render_panel(100).contains("K=50"));
        assert_eq!(r.csv_rows().len(), 5 * 8 + 5 * 8);
    }
}
