//! §4 analysis: the migration cost ratio Q = (S/R)(D/F) — analytic table
//! plus a measured crossover in the simulator.
//!
//! Paper's worked numbers (S/R = 40): block GEMM → Q = 60/m (negligible for
//! large m); GEMV → Q = 20 ("20 tasks can be executed locally in the same
//! time as one task is migrated").  The measured half validates the
//! *consequence*: DLB pays off for an imbalanced bag of GEMM-intensity
//! tasks but not for GEMV chains, unless queues are far deeper than Q.

use std::sync::Arc;

use crate::apps::{bag, gemv_chain};
use crate::config::Config;
use crate::core::task::TaskKind;
use crate::dlb::costmodel::CostModel;
use crate::sim::engine::SimEngine;

/// Analytic Q table row.
#[derive(Debug, Clone)]
pub struct QRow {
    pub kind: TaskKind,
    pub block: u64,
    pub q: f64,
    pub wt_guideline: usize,
}

/// The analytic table for the paper's machine balance.
pub fn q_table(model: &CostModel, blocks: &[u64]) -> Vec<QRow> {
    let mut rows = Vec::new();
    for &kind in &[TaskKind::Gemm, TaskKind::Syrk, TaskKind::Trsm, TaskKind::Potrf, TaskKind::Gemv]
    {
        for &b in blocks {
            rows.push(QRow {
                kind,
                block: b,
                q: model.q_kind(kind, b),
                wt_guideline: model.wt_guideline(kind, b),
            });
        }
    }
    rows
}

/// A measured DLB on/off comparison.
#[derive(Debug, Clone)]
pub struct MeasuredCase {
    pub name: String,
    pub makespan_off: f64,
    pub makespan_on: f64,
    pub migrations: u64,
}

impl MeasuredCase {
    pub fn improvement(&self) -> f64 {
        (self.makespan_off - self.makespan_on) / self.makespan_off
    }
}

fn base_cfg(p: usize, wt: usize, seed: u64, dlb: bool) -> Config {
    let mut c = Config::default();
    c.processes = p;
    c.grid = None;
    c.dlb_enabled = dlb;
    c.wt = wt;
    c.delta = 0.002;
    c.seed = seed;
    c.validate().expect("sec4 config");
    c
}

/// High-intensity case: imbalanced bag of GEMM-sized synthetic tasks.
pub fn measure_bag(p: usize, block: usize, tasks: usize, seed: u64) -> crate::util::error::Result<MeasuredCase> {
    let params = bag::BagParams {
        tasks,
        mean_flops: TaskKind::Gemm.flops_for_block(block as u64),
        skew: 3.0,
        size_spread: 0.3,
        block,
    };
    let mut result = [0.0f64; 2];
    let mut migrations = 0;
    for (i, dlb) in [false, true].iter().enumerate() {
        let cfg = base_cfg(p, 3, seed, *dlb);
        let g = bag::build(p, params, seed);
        let r = SimEngine::from_config(&cfg, Arc::clone(&g)).run().map_err(crate::util::error::Error::new)?;
        result[i] = r.makespan;
        if *dlb {
            migrations = r.counters.tasks_exported;
        }
    }
    Ok(MeasuredCase {
        name: format!("gemm-bag b={block}"),
        makespan_off: result[0],
        makespan_on: result[1],
        migrations,
    })
}

/// Low-intensity case: GEMV chains on half the processes.
pub fn measure_gemv(p: usize, block: usize, seed: u64) -> crate::util::error::Result<MeasuredCase> {
    let loaded = (p / 2).max(1);
    let mut result = [0.0f64; 2];
    let mut migrations = 0;
    for (i, dlb) in [false, true].iter().enumerate() {
        let cfg = base_cfg(p, 3, seed, *dlb);
        let g = gemv_chain::build(p, loaded, 6, 40, block);
        let r = SimEngine::from_config(&cfg, Arc::clone(&g)).run().map_err(crate::util::error::Error::new)?;
        result[i] = r.makespan;
        if *dlb {
            migrations = r.counters.tasks_exported;
        }
    }
    Ok(MeasuredCase {
        name: format!("gemv-chains b={block}"),
        makespan_off: result[0],
        makespan_on: result[1],
        migrations,
    })
}

#[derive(Debug)]
pub struct Sec4Result {
    pub table: Vec<QRow>,
    pub cases: Vec<MeasuredCase>,
}

pub fn run(seed: u64) -> crate::util::error::Result<Sec4Result> {
    let model = CostModel::new(8.8e9, 2.2e8); // the paper's S/R = 40
    let table = q_table(&model, &[32, 64, 128, 512, 1667, 2500]);
    let cases = vec![
        measure_bag(8, 512, 192, seed)?,
        measure_gemv(8, 512, seed)?,
    ];
    Ok(Sec4Result { table, cases })
}

impl Sec4Result {
    pub fn render(&self) -> String {
        let mut out = String::from(
            "§4 cost model (S/R = 40): Q = (S/R)(D/F) and the W_T guideline\n\
             kind     block      Q        W_T floor\n",
        );
        for r in &self.table {
            out.push_str(&format!(
                "{:<8} {:>6} {:>10.4} {:>8}\n",
                r.kind.to_string(),
                r.block,
                r.q,
                r.wt_guideline
            ));
        }
        out.push_str("\nmeasured DLB benefit (sim):\n");
        for c in &self.cases {
            out.push_str(&format!(
                "{:<22} off {:>8.4}s  on {:>8.4}s  improvement {:+.2}%  ({} migrations)\n",
                c.name,
                c.makespan_off,
                c.makespan_on,
                c.improvement() * 100.0,
                c.migrations
            ));
        }
        out
    }

    pub fn csv_rows(&self) -> Vec<Vec<f64>> {
        self.table
            .iter()
            .map(|r| vec![r.kind.index() as f64, r.block as f64, r.q, r.wt_guideline as f64])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_table_matches_paper_examples() {
        let model = CostModel::new(8.8e9, 2.2e8);
        let t = q_table(&model, &[1000]);
        let gemv = t.iter().find(|r| r.kind == TaskKind::Gemv).expect("gemv row");
        assert!((gemv.q - 20.0).abs() < 0.2, "gemv Q = {}", gemv.q);
        let gemm = t.iter().find(|r| r.kind == TaskKind::Gemm).expect("gemm row");
        assert!(gemm.q < 0.1, "gemm at m=1000 nearly free: {}", gemm.q);
    }

    #[test]
    fn bag_benefits_gemv_does_not() {
        let bag = measure_bag(6, 256, 96, 5).expect("bag");
        assert!(
            bag.improvement() > 0.15,
            "gemm-intensity bag should clearly benefit: {:+.2}%",
            bag.improvement() * 100.0
        );
        let gemv = measure_gemv(6, 256, 5).expect("gemv");
        // §4: Q≈20 ⇒ shallow gemv queues gain little or lose; allow noise
        assert!(
            gemv.improvement() < bag.improvement(),
            "gemv ({:+.2}%) must benefit less than gemm bag ({:+.2}%)",
            gemv.improvement() * 100.0,
            bag.improvement() * 100.0
        );
    }

    #[test]
    fn render_contains_table() {
        let r = run(2).expect("sec4");
        let s = r.render();
        assert!(s.contains("gemv"));
        assert!(s.contains("measured DLB benefit"));
    }
}
