//! Figure 5: non-determinism of randomized DLB — N = 100 000, 11×11 blocks
//! (b = 9091), P = 11 on an 11×1 grid.  The paper shows two executions of
//! the same configuration, one successful, one not.
//!
//! Randomized partner selection makes the outcome seed-dependent; we sweep
//! seeds, report each run's improvement over the DLB-off baseline, and name
//! the best and worst seeds — the honest reproduction of "two executions".

use crate::cholesky::driver::run_sim;
use crate::config::{Config, Grid, Strategy};
use crate::dlb::threshold::calibrate_from_traces;
use crate::metrics::trace::RunTraces;

/// The paper's Fig 5 configuration (pass a smaller `matrix_n` for tests:
/// block size shrinks, structure unchanged).
///
/// `exec_jitter = 3%`: on the real Rackham runs, task durations vary with
/// cache/NUMA/OS noise; at this scale (minutes-long tasks vs δ = 10 ms) the
/// protocol's own randomness is too fast to matter, so the run-to-run
/// variance the paper observed must come from execution noise.  The jitter
/// models that — without it every seed converges to the same schedule
/// (verified in EXPERIMENTS.md).
pub fn fig5_config(dlb: bool, wt: usize, seed: u64, matrix_n: usize) -> Config {
    let mut c = Config::default();
    c.processes = 11;
    c.grid = Some(Grid::new(11, 1));
    c.nb = 11;
    c.block = matrix_n / 11;
    c.dlb_enabled = dlb;
    c.strategy = Strategy::Basic;
    c.wt = wt;
    c.delta = 0.010;
    c.seed = seed;
    c.exec_jitter = 0.03;
    c.validate().expect("fig5 config");
    c
}

#[derive(Debug)]
pub struct SeedOutcome {
    pub seed: u64,
    pub makespan: f64,
    pub improvement: f64,
    pub migrations: u64,
    pub traces: RunTraces,
}

#[derive(Debug)]
pub struct Fig5Result {
    pub baseline_makespan: f64,
    pub calibrated_wt: usize,
    pub outcomes: Vec<SeedOutcome>,
}

impl Fig5Result {
    pub fn best(&self) -> &SeedOutcome {
        self.outcomes
            .iter()
            .max_by(|a, b| a.improvement.partial_cmp(&b.improvement).expect("no NaN"))
            .expect("nonempty")
    }

    pub fn worst(&self) -> &SeedOutcome {
        self.outcomes
            .iter()
            .min_by(|a, b| a.improvement.partial_cmp(&b.improvement).expect("no NaN"))
            .expect("nonempty")
    }

    /// The paper's qualitative claim: outcomes straddle "helps" and
    /// "does not help".
    pub fn spread(&self) -> f64 {
        self.best().improvement - self.worst().improvement
    }

    pub fn render(&self) -> String {
        let mut rows: Vec<(String, f64)> = self
            .outcomes
            .iter()
            .map(|o| (format!("seed {}", o.seed), o.improvement * 100.0))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).expect("no NaN"));
        let mut out = format!(
            "Fig 5: baseline {:.3}s, W_T = {}; improvement by seed [%]:\n",
            self.baseline_makespan, self.calibrated_wt
        );
        for (name, v) in &rows {
            out.push_str(&format!("{name:<10} {v:+.2}%\n"));
        }
        out.push_str(&format!(
            "best seed {} ({:+.2}%), worst seed {} ({:+.2}%)\n",
            self.best().seed,
            self.best().improvement * 100.0,
            self.worst().seed,
            self.worst().improvement * 100.0
        ));
        out
    }

    pub fn csv_rows(&self) -> Vec<Vec<f64>> {
        self.outcomes
            .iter()
            .map(|o| vec![o.seed as f64, o.makespan, o.improvement, o.migrations as f64])
            .collect()
    }
}

/// Run the sweep: one DLB-off baseline (calibrating W_T per §6), then one
/// DLB-on run per seed.
pub fn run(matrix_n: usize, seeds: &[u64]) -> crate::util::error::Result<Fig5Result> {
    let off = run_sim(&fig5_config(false, 5, 1, matrix_n))?;
    let wt = calibrate_from_traces(&off.traces);
    let mut outcomes = Vec::with_capacity(seeds.len());
    for &s in seeds {
        let on = run_sim(&fig5_config(true, wt, s, matrix_n))?;
        outcomes.push(SeedOutcome {
            seed: s,
            makespan: on.makespan,
            improvement: (off.makespan - on.makespan) / off.makespan,
            migrations: on.counters.tasks_exported,
            traces: on.traces,
        });
    }
    Ok(Fig5Result { baseline_makespan: off.makespan, calibrated_wt: wt, outcomes })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_sweep_produces_spread() {
        // scaled down (N = 1100 → b = 100) for test speed
        let r = run(1100, &[1, 2, 3, 4, 5, 6]).expect("fig5");
        assert_eq!(r.outcomes.len(), 6);
        assert!(r.baseline_makespan > 0.0);
        // outcomes must differ across seeds (non-determinism is the point)
        let first = r.outcomes[0].makespan;
        assert!(
            r.outcomes.iter().any(|o| (o.makespan - first).abs() > 1e-9),
            "all seeds identical — randomization broken?"
        );
        assert!(r.spread() >= 0.0);
    }

    #[test]
    fn render_names_best_and_worst() {
        let r = run(1100, &[1, 2, 3]).expect("fig5");
        let s = r.render();
        assert!(s.contains("best seed"));
        assert!(s.contains("worst seed"));
    }
}
