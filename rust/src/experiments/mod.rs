//! Paper-figure regeneration drivers.
//!
//! One module per figure/analysis of the paper's evaluation; each returns a
//! plain-data result (for benches and tests) and can render an ASCII
//! quick-look plus CSV (for EXPERIMENTS.md).  See DESIGN.md §5 for the
//! experiment index.

pub mod ablation;
pub mod bench;
pub mod bench_real;
pub mod compare;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod sec4;

/// Where experiment CSVs land (created on demand).
pub fn out_dir(sub: &str) -> std::path::PathBuf {
    let p = std::path::Path::new("results").join(sub);
    let _ = std::fs::create_dir_all(&p);
    p
}
