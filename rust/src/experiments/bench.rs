//! `ductr bench` — the repeatable DES hot-path baseline.
//!
//! Times full simulator runs on the standing workloads (block Cholesky,
//! random layered DAG, hierarchical-stealing-on-cluster, plus graph-fabric
//! cells running second-order diffusion on a random-regular interconnect —
//! a small one in the smoke profile, a P = 512 one in the full sweep)
//! across a process count sweep reaching P = 65 536, with every cell measured twice —
//! transport coalescing off and on — and writes a JSON baseline
//! (`BENCH_pr5.json` by default) so successive PRs have a perf trajectory
//! to compare against: events/sec, makespan, and the pending-event
//! high-water mark per case.
//!
//! `--sim-threads N` adds a third dimension: every (workload, P, coalesce)
//! cell is timed again under the sharded parallel engine, and the run
//! *hard-fails* if any threads = N row's deterministic outputs (events,
//! makespan bits, DLB counters) differ from its threads = 1 twin — the
//! in-run synchronization canary.  The full sweep always includes a
//! P = 65 536 frontier cell and the P = 512 graph-fabric cell with the
//! parallel rows forced on (≥ 4 shards).  Sharded rows also record the
//! coordinator's window statistics (`windows`, `window_cmds_sent`,
//! `window_cmds_skipped`) — deterministic under the seed like `events` —
//! and the graph-fabric cells re-run each sharded row under the legacy
//! scalar-lookahead protocol (`windows_scalar`), hard-failing if the
//! distance-aware horizons cost more barriers or diverge bit-wise.
//!
//! `--baseline FILE` re-reads a committed baseline and prints per-case
//! deltas; on any matching (name, coalesce, threads) case the command
//! fails on deterministic event-count drift (the machine-independent
//! canary) or an events/sec collapse beyond [`REGRESSION_TOLERANCE`].
//! A threads > 1 row checks its event count against the baseline's
//! threads = 1 row when one exists, so the canary is also
//! thread-invariant across commits.  Case names encode the profile, so
//! CI diffs its smoke run against the committed smoke baseline
//! (`bench --smoke --baseline BENCH_pr5_smoke.json`) while full sweeps
//! diff against `BENCH_pr5.json`.
//!
//! Wall-clock numbers are machine-dependent; everything else in the file
//! (events, makespan, peak pending) is deterministic under the seed, which
//! is what makes the baseline diffable across engine changes.

use std::io::Write as _;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::apps::rand_dag;
use crate::cholesky::{self, ProcessGrid};
use crate::config::{Config, PolicyKind, TopologyKind, WindowMode};
use crate::core::graph::TaskGraph;
use crate::metrics::LatencyReport;
use crate::sim::engine::SimResult;
use crate::util::bench::{run_with, BenchConfig};
use crate::util::error::{Error, Result};
use crate::util::json::field as json_field;

/// Fractional events/sec drop against the baseline that fails a
/// comparison.  Deliberately loose: wall-clock throughput on shared CI
/// runners routinely swings tens of percent, so this only catches
/// catastrophic slowdowns — the *primary* gate is deterministic
/// event-count drift, which is machine-independent and exact.
pub const REGRESSION_TOLERANCE: f64 = 0.50;

/// One timed workload/process-count/coalesce/threads cell.
#[derive(Debug, Clone)]
pub struct BenchCase {
    pub name: String,
    pub workload: &'static str,
    pub processes: usize,
    pub tasks: usize,
    /// Transport coalescing on for this cell (the A/B dimension).
    pub coalesce: bool,
    /// Simulator shards used for this cell (1 = the single-threaded
    /// oracle engine).  Deterministic outputs are thread-invariant, so a
    /// threads > 1 row differs from its threads = 1 twin only in wall
    /// clock and `peak_pending_events` (a sum of per-shard peaks).
    pub threads: usize,
    /// Events dispatched by one run (deterministic under the seed).
    pub events: u64,
    pub makespan: f64,
    /// Pending-event high-water mark of the scheduler.
    pub peak_pending_events: usize,
    /// Messages that rode an existing flight instead of their own event.
    pub messages_coalesced: u64,
    /// Median wall-clock seconds per run.
    pub wall_secs: f64,
    pub events_per_sec: f64,
    /// Latency quantiles from one extra *untimed* run with the span
    /// recorder armed (recording overhead must not contaminate the timed
    /// samples).  `0.0` = not traced (the very largest cells) or no
    /// samples in the distribution.
    pub round_p50: f64,
    pub round_p95: f64,
    pub round_p99: f64,
    pub qwait_p50: f64,
    pub qwait_p95: f64,
    pub qwait_p99: f64,
    /// Coordinator barrier windows of this run (0 for threads = 1 rows —
    /// the single-threaded engine has no windows).  Deterministic under
    /// the seed, so diffable across commits like `events`.
    pub windows: u64,
    /// `WindowCmd`s dispatched / skipped by the sparse-barrier rule.
    pub window_cmds_sent: u64,
    pub window_cmds_skipped: u64,
    /// Window count of the same cell re-run under the legacy scalar-L
    /// protocol (`[sim] window = "scalar"`), recorded only on the
    /// graph-fabric A/B cells; 0 = not measured.  `windows` ≤ this is
    /// enforced in-run — the distance-aware horizons must never cost more
    /// barriers than the global-minimum protocol they replace.
    pub windows_scalar: u64,
}

#[derive(Debug)]
pub struct BenchReport {
    pub seed: u64,
    pub smoke: bool,
    pub cases: Vec<BenchCase>,
}

fn base_cfg(p: usize, seed: u64) -> Config {
    let mut c = Config::default();
    c.processes = p;
    c.grid = None; // derive the squarest grid for the Cholesky cells
    c.dlb_enabled = true;
    c.wt = 3;
    c.delta = 0.002;
    c.seed = seed;
    c
}

/// The full-profile random-DAG cell (the P = 256 instance is the hot-path
/// acceptance workload).  Shared with `benches/hotpath.rs` so the two
/// measurements cannot drift apart.
pub fn rand_dag_case(p: usize, seed: u64) -> (Config, Arc<TaskGraph>, String) {
    let mut cfg = base_cfg(p, seed);
    cfg.validate().expect("bench config");
    let mut params = rand_dag::DagParams::default();
    params.layers = 24;
    params.width = p.max(16);
    let name = format!("rand_dag {}x{} P={p}", params.layers, params.width);
    (cfg, rand_dag::build(p, params, seed), name)
}

/// Time `graph` under `cfg` (whichever engine `cfg.sim_threads` selects);
/// returns the (seed-deterministic) sim result of the last run plus the
/// median wall seconds over the harness samples.
fn time_case(cfg: &Config, graph: &Arc<TaskGraph>, name: &str, smoke: bool) -> (SimResult, f64) {
    let bc = if smoke {
        BenchConfig {
            warmup_iters: 0,
            samples: 2,
            iters_per_sample: 1,
            min_warmup_time: Duration::ZERO,
            max_total_time: Duration::from_secs(120),
        }
    } else {
        BenchConfig {
            warmup_iters: 1,
            samples: 3,
            iters_per_sample: 1,
            min_warmup_time: Duration::from_millis(1),
            max_total_time: Duration::from_secs(300),
        }
    };
    let mut last: Option<SimResult> = None;
    let res = run_with(&bc, name, || {
        let r = crate::sim::run_config(cfg, Arc::clone(graph)).expect("bench sim run");
        last = Some(r);
    });
    (last.expect("at least one sample ran"), res.summary.median)
}

/// Time one workload cell under coalescing off *and* on; with
/// `threads > 1` each coalesce row gets a sharded-engine twin, gated
/// bit-for-bit against the single-threaded row before it is recorded.
///
/// `scalar_ab` additionally re-runs each sharded row (untimed) under the
/// legacy scalar-lookahead protocol and records its window count in
/// `windows_scalar` — the A/B that makes the distance-aware horizon win a
/// number in the baseline.  The run hard-fails if the scalar twin's
/// deterministic outputs diverge (both protocols must be bit-identical to
/// the oracle) or if the matrix protocol needed *more* windows.
#[allow(clippy::too_many_arguments)]
fn time_ab(
    cases: &mut Vec<BenchCase>,
    workload: &'static str,
    cfg: &Config,
    graph: &Arc<TaskGraph>,
    name: &str,
    smoke: bool,
    threads: usize,
    scalar_ab: bool,
) -> Result<()> {
    let start = cases.len();
    let tasks = graph.num_tasks();
    for coalesce in [false, true] {
        let mut c = cfg.clone();
        c.coalesce = coalesce;
        c.sim_threads = 1;
        let (r1, wall) = time_case(&c, graph, name, smoke);
        cases.push(case(workload, name, c.processes, tasks, coalesce, 1, &r1, wall));
        // The sharded-engine twin: identical cell, threads = N.  Events,
        // makespan bits and every DLB counter must match the oracle row —
        // any divergence is a synchronization bug, not a perf datum, so
        // the whole bench run fails rather than recording it.
        let t = threads.min(c.processes);
        if t > 1 {
            c.sim_threads = t;
            let (rp, wallp) = time_case(&c, graph, name, smoke);
            if rp.events_processed != r1.events_processed
                || rp.makespan.to_bits() != r1.makespan.to_bits()
                || rp.counters != r1.counters
            {
                return Err(Error::msg(format!(
                    "bench canary: {name} (coalesce {coalesce}) diverged under \
                     --sim-threads {t}: events {} vs {}, makespan {:?} vs {:?}, \
                     coalesced {} vs {}",
                    rp.events_processed,
                    r1.events_processed,
                    rp.makespan,
                    r1.makespan,
                    rp.counters.messages_coalesced,
                    r1.counters.messages_coalesced
                )));
            }
            cases.push(case(workload, name, c.processes, tasks, coalesce, t, &rp, wallp));
            if scalar_ab {
                let mut cs = c.clone();
                cs.sim_window = WindowMode::Scalar;
                let rs = crate::sim::run_config(&cs, Arc::clone(graph))
                    .expect("bench scalar-window run");
                if rs.events_processed != r1.events_processed
                    || rs.makespan.to_bits() != r1.makespan.to_bits()
                    || rs.counters != r1.counters
                {
                    return Err(Error::msg(format!(
                        "bench canary: {name} (coalesce {coalesce}) diverged under \
                         the scalar window protocol: events {} vs {}",
                        rs.events_processed, r1.events_processed
                    )));
                }
                if rs.window.windows < rp.window.windows {
                    return Err(Error::msg(format!(
                        "bench canary: {name} (coalesce {coalesce}) took more windows \
                         under distance-aware horizons ({}) than the scalar protocol \
                         ({}) — the per-pair lookahead must dominate the global one",
                        rp.window.windows, rs.window.windows
                    )));
                }
                cases
                    .last_mut()
                    .expect("sharded row just pushed")
                    .windows_scalar = rs.window.windows;
            }
        }
    }
    // One extra untimed run with the recorder armed fills the latency
    // quantiles for every row of the cell (tracing is a no-op on the sim
    // outcome, so one threads = 1 traced run describes them all).  Skipped
    // on the largest cells — the event buffer there costs more memory than
    // the quantiles are worth in a perf baseline.
    if cfg.processes <= 1024 {
        let mut c = cfg.clone();
        c.sim_threads = 1;
        c.trace_enabled = true;
        let r = crate::sim::run_config(&c, Arc::clone(graph)).expect("bench trace run");
        let lat = LatencyReport::from_trace(&r.trace);
        let q = |v: f64| if v.is_finite() { v } else { 0.0 };
        for cell in &mut cases[start..] {
            cell.round_p50 = q(lat.round.quantile(0.50));
            cell.round_p95 = q(lat.round.quantile(0.95));
            cell.round_p99 = q(lat.round.quantile(0.99));
            cell.qwait_p50 = q(lat.queue_wait.quantile(0.50));
            cell.qwait_p95 = q(lat.queue_wait.quantile(0.95));
            cell.qwait_p99 = q(lat.queue_wait.quantile(0.99));
        }
    }
    Ok(())
}

/// Run the sweep.  `smoke` shrinks process counts and sizes to a few
/// seconds total for CI — but keeps one P = 1024 cell so the large-P
/// scheduler and coalescing paths are exercised on every push.  `threads`
/// > 1 doubles every cell with a sharded-engine row (see [`time_ab`]);
/// the full sweep's P = 65 536 frontier cell forces those rows on so the
/// parallel engine is always exercised at scale.
pub fn run(seed: u64, smoke: bool, threads: usize) -> Result<BenchReport> {
    let ps: &[usize] = if smoke { &[4, 8] } else { &[16, 64, 256, 1024, 4096] };
    let mut cases = Vec::new();

    for &p in ps {
        // --- block Cholesky ------------------------------------------
        let mut cfg = base_cfg(p, seed);
        // keep tasks ≳ P at the top of the sweep so the large-P cells
        // measure a loaded scheduler, not just termination chatter
        cfg.nb = if smoke {
            8
        } else if p >= 1024 {
            32
        } else {
            24
        };
        cfg.block = if smoke { 128 } else { 256 };
        cfg.validate().map_err(Error::new)?;
        let dag = cholesky::build(cfg.nb, cfg.block, ProcessGrid::new(cfg.effective_grid()));
        let name = format!("cholesky nb={} P={p}", cfg.nb);
        time_ab(&mut cases, "cholesky", &cfg, &dag.graph, &name, smoke, threads, false)?;

        // --- random layered DAG --------------------------------------
        let (cfg, graph, name) = if smoke {
            let mut c = base_cfg(p, seed);
            c.validate().map_err(Error::new)?;
            let mut params = rand_dag::DagParams::default();
            params.layers = 6;
            params.width = 8;
            let name = format!("rand_dag {}x{} P={p}", params.layers, params.width);
            (c, rand_dag::build(p, params, seed), name)
        } else {
            rand_dag_case(p, seed)
        };
        time_ab(&mut cases, "rand_dag", &cfg, &graph, &name, smoke, threads, false)?;

        // --- locality layer: hierarchical stealing + adaptive δ on the
        //     cluster fabric (PR 4's policy hot path) -------------------
        let mut c = base_cfg(p, seed);
        c.policy = PolicyKind::Hierarchical;
        c.topology = TopologyKind::Cluster;
        c.adaptive_delta = true;
        c.validate().map_err(Error::new)?;
        let mut params = rand_dag::DagParams::default();
        if smoke {
            params.layers = 6;
            params.width = 8;
        } else {
            params.layers = 24;
            params.width = p.max(16);
        }
        let name = format!("hier_cluster {}x{} P={p}", params.layers, params.width);
        let graph = rand_dag::build(p, params, seed);
        time_ab(&mut cases, "hier_cluster", &c, &graph, &name, smoke, threads, false)?;
    }

    if smoke {
        // the CI large-P canary: a small DAG over 1024 processes drives
        // the calendar queue through boot-storm, rebuild and termination
        let p = 1024;
        let mut c = base_cfg(p, seed);
        c.validate().map_err(Error::new)?;
        let mut params = rand_dag::DagParams::default();
        params.layers = 4;
        params.width = 64;
        let name = format!("rand_dag {}x{} P={p}", params.layers, params.width);
        let graph = rand_dag::build(p, params, seed);
        time_ab(&mut cases, "rand_dag", &c, &graph, &name, smoke, threads, false)?;

        // the graph-fabric leg: second-order diffusion on a random-regular
        // interconnect, so every push times the BFS-table topology path and
        // the SOS policy hot loop (and, under --sim-threads, the sharded
        // engine over a graph partition)
        let p = 8;
        let mut c = base_cfg(p, seed);
        c.policy = PolicyKind::SosDiffusion;
        c.topology = TopologyKind::RandReg { d: 3 };
        c.validate().map_err(Error::new)?;
        let mut params = rand_dag::DagParams::default();
        params.layers = 6;
        params.width = 8;
        let name = format!("sos_randreg {}x{} P={p}", params.layers, params.width);
        let graph = rand_dag::build(p, params, seed);
        time_ab(&mut cases, "sos_randreg", &c, &graph, &name, smoke, threads, true)?;
    } else {
        // the P = 65 536 frontier cell: a sparse DAG over the full rank
        // count, parallel rows forced on.  DLB stays off (victim sampling
        // walks O(P) candidates at this scale) and the fabric is a ring
        // (the flat topology materializes an O(P) neighbor list per rank
        // — tens of GB at this P); the cell measures boot storm, transport
        // and termination across 64 Ki ranks, which is what the sharded
        // engine exists for.
        let p = 65_536;
        let mut c = base_cfg(p, seed);
        c.dlb_enabled = false;
        c.topology = TopologyKind::Ring;
        c.validate().map_err(Error::new)?;
        let mut params = rand_dag::DagParams::default();
        params.layers = 4;
        params.width = 64;
        let name = format!("rand_dag {}x{} P={p}", params.layers, params.width);
        let graph = rand_dag::build(p, params, seed);
        time_ab(&mut cases, "rand_dag", &c, &graph, &name, smoke, threads.max(4), false)?;

        // the graph-fabric frontier: second-order diffusion over a
        // random-regular interconnect at P = 512, parallel rows forced on
        // with the scalar-window A/B armed — the cell where the
        // distance-aware horizons have multi-hop shard separation to
        // exploit, so `windows` vs `windows_scalar` is the headline
        // number of the protocol.
        let p = 512;
        let mut c = base_cfg(p, seed);
        c.policy = PolicyKind::SosDiffusion;
        c.topology = TopologyKind::RandReg { d: 3 };
        c.validate().map_err(Error::new)?;
        let mut params = rand_dag::DagParams::default();
        params.layers = 8;
        params.width = 128;
        let name = format!("randreg_fabric {}x{} P={p}", params.layers, params.width);
        let graph = rand_dag::build(p, params, seed);
        time_ab(&mut cases, "randreg_fabric", &c, &graph, &name, smoke, threads.max(4), true)?;
    }

    Ok(BenchReport { seed, smoke, cases })
}

#[allow(clippy::too_many_arguments)]
fn case(
    workload: &'static str,
    name: &str,
    p: usize,
    tasks: usize,
    coalesce: bool,
    threads: usize,
    r: &SimResult,
    wall: f64,
) -> BenchCase {
    BenchCase {
        name: name.to_string(),
        workload,
        processes: p,
        tasks,
        coalesce,
        threads,
        events: r.events_processed,
        makespan: r.makespan,
        peak_pending_events: r.peak_pending_events,
        messages_coalesced: r.counters.messages_coalesced,
        wall_secs: wall,
        events_per_sec: if wall > 0.0 { r.events_processed as f64 / wall } else { 0.0 },
        round_p50: 0.0,
        round_p95: 0.0,
        round_p99: 0.0,
        qwait_p50: 0.0,
        qwait_p95: 0.0,
        qwait_p99: 0.0,
        windows: r.window.windows,
        window_cmds_sent: r.window.cmds_sent,
        window_cmds_skipped: r.window.cmds_skipped,
        windows_scalar: 0,
    }
}

impl BenchReport {
    /// ASCII quick-look table.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "ductr bench (seed {}{})\n{:<28} {:>6} {:>7} {:>4} {:>3} {:>10} {:>11} {:>10} {:>10} {:>12} {:>9} {:>9}\n",
            self.seed,
            if self.smoke { ", smoke" } else { "" },
            "case",
            "P",
            "tasks",
            "coal",
            "thr",
            "events",
            "makespan",
            "peak-pend",
            "coalesced",
            "events/s",
            "windows",
            "w-skip"
        ));
        for c in &self.cases {
            s.push_str(&format!(
                "{:<28} {:>6} {:>7} {:>4} {:>3} {:>10} {:>11.4} {:>10} {:>10} {:>12.0} {:>9} {:>9}\n",
                c.name,
                c.processes,
                c.tasks,
                if c.coalesce { "on" } else { "off" },
                c.threads,
                c.events,
                c.makespan,
                c.peak_pending_events,
                c.messages_coalesced,
                c.events_per_sec,
                c.windows,
                c.window_cmds_skipped
            ));
        }
        s
    }

    /// Hand-rolled JSON (the offline crate set has no serde): one object
    /// with a `cases` array, numbers emitted raw.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{{")?;
        writeln!(f, "  \"generated_by\": \"ductr bench\",")?;
        writeln!(f, "  \"placeholder\": false,")?;
        writeln!(f, "  \"seed\": {},", self.seed)?;
        writeln!(f, "  \"smoke\": {},", self.smoke)?;
        writeln!(f, "  \"cases\": [")?;
        for (i, c) in self.cases.iter().enumerate() {
            let comma = if i + 1 < self.cases.len() { "," } else { "" };
            writeln!(
                f,
                "    {{\"name\": \"{}\", \"workload\": \"{}\", \"processes\": {}, \
                 \"tasks\": {}, \"coalesce\": {}, \"threads\": {}, \"events\": {}, \
                 \"makespan\": {}, \
                 \"peak_pending_events\": {}, \"messages_coalesced\": {}, \
                 \"wall_secs\": {}, \"events_per_sec\": {}, \
                 \"round_p50\": {}, \"round_p95\": {}, \"round_p99\": {}, \
                 \"qwait_p50\": {}, \"qwait_p95\": {}, \"qwait_p99\": {}, \
                 \"windows\": {}, \"window_cmds_sent\": {}, \
                 \"window_cmds_skipped\": {}, \"windows_scalar\": {}}}{comma}",
                c.name,
                c.workload,
                c.processes,
                c.tasks,
                c.coalesce,
                c.threads,
                c.events,
                c.makespan,
                c.peak_pending_events,
                c.messages_coalesced,
                c.wall_secs,
                c.events_per_sec,
                c.round_p50,
                c.round_p95,
                c.round_p99,
                c.qwait_p50,
                c.qwait_p95,
                c.qwait_p99,
                c.windows,
                c.window_cmds_sent,
                c.window_cmds_skipped,
                c.windows_scalar
            )?;
        }
        writeln!(f, "  ]")?;
        writeln!(f, "}}")?;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// baseline comparison (`bench --baseline FILE`)
// ---------------------------------------------------------------------

/// The slice of a committed baseline needed for regression checks.
#[derive(Debug, Clone)]
pub struct BaselineCase {
    pub name: String,
    pub coalesce: bool,
    /// Engine shards the row was measured under (legacy baselines predate
    /// the field and read as 1 — they were all single-threaded).
    pub threads: usize,
    pub events: Option<u64>,
    pub events_per_sec: f64,
}

#[derive(Debug)]
pub struct Baseline {
    /// A committed file generated off-machine may be a placeholder (no
    /// toolchain where it was authored); comparisons against it are
    /// informational, never failing.
    pub placeholder: bool,
    pub cases: Vec<BaselineCase>,
}

// The `"key": value` line extractor used below (`json_field`) lives in
// `util::json` now — the trace validator shares it.

/// Load a `ductr bench` JSON baseline.  Tolerant of older layouts: missing
/// `coalesce` reads as off, missing `threads` as 1, missing `placeholder`
/// as false.
pub fn load_baseline(path: &Path) -> Result<Baseline> {
    let body = std::fs::read_to_string(path)
        .map_err(|e| Error::msg(format!("cannot read baseline {}: {e}", path.display())))?;
    let placeholder = body
        .lines()
        .find_map(|l| json_field(l, "placeholder"))
        .map(|v| v == "true")
        // legacy placeholder marker lived in the generated_by free text
        .unwrap_or_else(|| body.contains("placeholder"));
    let mut cases = Vec::new();
    for line in body.lines() {
        let Some(name) = json_field(line, "name") else { continue };
        let Some(eps) = json_field(line, "events_per_sec").and_then(|v| v.parse::<f64>().ok())
        else {
            continue;
        };
        cases.push(BaselineCase {
            name: name.to_string(),
            coalesce: json_field(line, "coalesce").map(|v| v == "true").unwrap_or(false),
            threads: json_field(line, "threads").and_then(|v| v.parse().ok()).unwrap_or(1),
            events: json_field(line, "events").and_then(|v| v.parse().ok()),
            events_per_sec: eps,
        });
    }
    Ok(Baseline { placeholder, cases })
}

impl BenchReport {
    /// Render per-case deltas against `base`.  Two failure conditions on
    /// matching (name, coalesce, threads) cases, neither of which a
    /// placeholder baseline or an unmatched case can trigger:
    ///
    /// - **event-count drift** — `events` is deterministic under the seed
    ///   and machine-independent, so any mismatch is a real behavioral
    ///   change: either a regression or an intentional engine change that
    ///   must re-bless the baseline.  This is the reliable CI canary.
    ///   Deterministic outputs are also *thread*-invariant, so a
    ///   threads > 1 row checks its event count against the baseline's
    ///   threads = 1 row when one exists: a sharded run on this commit is
    ///   gated against the single-threaded oracle of the blessed commit.
    /// - **events/sec collapse** beyond [`REGRESSION_TOLERANCE`] — a
    ///   coarse wall-clock backstop for slowdowns that keep event counts
    ///   intact; loose enough to tolerate shared-runner variance.
    pub fn compare_to_baseline(&self, base: &Baseline, label: &str) -> Result<String> {
        let mut s = format!(
            "baseline comparison vs {label}{}\n{:<28} {:>4} {:>3} {:>14} {:>14} {:>8}\n",
            if base.placeholder { " (placeholder — informational)" } else { "" },
            "case",
            "coal",
            "thr",
            "base ev/s",
            "now ev/s",
            "delta"
        );
        let mut matched = 0usize;
        let mut regressed = Vec::new();
        let mut drifted = Vec::new();
        for c in &self.cases {
            let Some(b) = base
                .cases
                .iter()
                .find(|b| b.name == c.name && b.coalesce == c.coalesce && b.threads == c.threads)
            else {
                continue;
            };
            matched += 1;
            let delta = if b.events_per_sec > 0.0 {
                c.events_per_sec / b.events_per_sec - 1.0
            } else {
                0.0
            };
            // the thread-invariant canary: prefer the oracle row's count
            let ref_events = if c.threads > 1 {
                base.cases
                    .iter()
                    .find(|o| o.name == c.name && o.coalesce == c.coalesce && o.threads == 1)
                    .and_then(|o| o.events)
                    .or(b.events)
            } else {
                b.events
            };
            let drift = matches!(ref_events, Some(be) if be != c.events);
            s.push_str(&format!(
                "{:<28} {:>4} {:>3} {:>14.0} {:>14.0} {:>+7.1}%{}\n",
                c.name,
                if c.coalesce { "on" } else { "off" },
                c.threads,
                b.events_per_sec,
                c.events_per_sec,
                delta * 100.0,
                if drift { "  [event-count drift]" } else { "" }
            ));
            if drift {
                drifted.push(format!(
                    "{} (coalesce {}, threads {}): {} → {} events",
                    c.name,
                    if c.coalesce { "on" } else { "off" },
                    c.threads,
                    ref_events.unwrap_or(0),
                    c.events
                ));
            }
            if delta < -REGRESSION_TOLERANCE {
                regressed.push(format!(
                    "{} (coalesce {}, threads {}): {:+.1}%",
                    c.name,
                    if c.coalesce { "on" } else { "off" },
                    c.threads,
                    delta * 100.0
                ));
            }
        }
        if matched == 0 {
            s.push_str("  (no matching cases — baseline profile differs from this run)\n");
        }
        if base.placeholder {
            return Ok(s);
        }
        if !drifted.is_empty() {
            return Err(Error::msg(format!(
                "{s}\ndeterministic event counts drifted from the baseline on {} case(s): {} \
                 — an engine-behavior change; re-bless the baseline if intentional",
                drifted.len(),
                drifted.join("; ")
            )));
        }
        if !regressed.is_empty() {
            return Err(Error::msg(format!(
                "{s}\nevents/sec regressed beyond {:.0}% on {} case(s): {}",
                REGRESSION_TOLERANCE * 100.0,
                regressed.len(),
                regressed.join("; ")
            )));
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_sweep_runs_and_serializes() {
        let r = run(1, true, 1).expect("smoke bench");
        // (3 workloads × 2 process counts + 1 large-P canary + 1 graph/SOS
        // leg) × coalesce A/B
        assert_eq!(r.cases.len(), 16);
        assert!(r.cases.iter().all(|c| c.threads == 1));
        assert!(r.cases.iter().all(|c| c.events > 0 && c.makespan > 0.0));
        assert!(r.cases.iter().all(|c| c.peak_pending_events > 0));
        assert!(r.cases.iter().any(|c| c.workload == "hier_cluster"));
        assert!(
            r.cases.iter().any(|c| c.workload == "sos_randreg"),
            "smoke must exercise the graph-topology + SOS leg"
        );
        assert!(
            r.cases.iter().any(|c| c.processes == 1024),
            "smoke must exercise the large-P path"
        );
        // the cholesky boot storm fans v0 blocks out per destination, so
        // the coalesce=on cells must actually coalesce
        assert!(
            r.cases
                .iter()
                .any(|c| c.coalesce && c.workload == "cholesky" && c.messages_coalesced > 0),
            "coalescing must engage on the cholesky cells"
        );
        assert!(r.cases.iter().all(|c| c.coalesce || c.messages_coalesced == 0));
        // every smoke cell is ≤ 1024 processes, so all get the traced run:
        // tasks always queue (qwait counted) and DLB is on (rounds happen
        // somewhere); quantiles are finite and non-negative everywhere
        assert!(r.cases.iter().all(|c| {
            [c.round_p50, c.round_p95, c.round_p99, c.qwait_p50, c.qwait_p95, c.qwait_p99]
                .iter()
                .all(|v| v.is_finite() && *v >= 0.0)
        }));
        assert!(
            r.cases.iter().any(|c| c.round_p95 > 0.0),
            "some smoke cell must record pair-search rounds"
        );
        // threads = 1 everywhere → the single-threaded engine, which has no
        // coordinator windows; the window columns must read zero
        assert!(r.cases.iter().all(|c| {
            c.windows == 0
                && c.window_cmds_sent == 0
                && c.window_cmds_skipped == 0
                && c.windows_scalar == 0
        }));
        let rendered = r.render();
        assert!(rendered.contains("events/s"));
        let p = std::env::temp_dir().join("ductr_bench_smoke.json");
        r.write_json(&p).expect("json write");
        let body = std::fs::read_to_string(&p).expect("json read");
        assert!(body.starts_with('{') && body.trim_end().ends_with('}'));
        assert_eq!(body.matches("\"name\"").count(), 16);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn sharded_smoke_rows_match_their_single_thread_twins() {
        // --sim-threads 2 doubles every cell; time_ab itself hard-fails on
        // divergence, so reaching here means the canary held — the asserts
        // re-check the recorded rows pairwise for defense in depth.
        let r = run(3, true, 2).expect("sharded smoke bench");
        assert_eq!(r.cases.len(), 32);
        let twos: Vec<_> = r.cases.iter().filter(|c| c.threads == 2).collect();
        assert_eq!(twos.len(), 16);
        for c2 in twos {
            let c1 = r
                .cases
                .iter()
                .find(|c| c.threads == 1 && c.name == c2.name && c.coalesce == c2.coalesce)
                .expect("every sharded row has a single-thread twin");
            assert_eq!(c2.events, c1.events, "{}", c2.name);
            assert_eq!(c2.makespan.to_bits(), c1.makespan.to_bits(), "{}", c2.name);
            assert_eq!(c2.messages_coalesced, c1.messages_coalesced, "{}", c2.name);
            // window stats are a sharded-engine artifact
            assert!(c2.windows >= 1, "{}: sharded rows must record windows", c2.name);
            assert_eq!(c1.windows, 0, "{}: oracle rows have no windows", c1.name);
            if c2.workload == "sos_randreg" {
                // the smoke graph-fabric cell runs the scalar A/B twin
                assert!(
                    c2.windows_scalar > 0 && c2.windows <= c2.windows_scalar,
                    "{}: matrix windows {} vs scalar {}",
                    c2.name,
                    c2.windows,
                    c2.windows_scalar
                );
            } else {
                assert_eq!(c2.windows_scalar, 0, "{}: A/B only on the fabric cell", c2.name);
            }
        }
    }

    #[test]
    fn bench_metrics_deterministic_under_seed() {
        let a = run(7, true, 1).expect("a");
        let b = run(7, true, 1).expect("b");
        for (x, y) in a.cases.iter().zip(&b.cases) {
            assert_eq!(x.events, y.events, "{}", x.name);
            assert_eq!(x.makespan, y.makespan, "{}", x.name);
            assert_eq!(x.peak_pending_events, y.peak_pending_events, "{}", x.name);
            assert_eq!(x.messages_coalesced, y.messages_coalesced, "{}", x.name);
        }
    }

    fn tiny_report() -> BenchReport {
        BenchReport {
            seed: 1,
            smoke: true,
            cases: vec![BenchCase {
                name: "cell A".into(),
                workload: "rand_dag",
                processes: 4,
                tasks: 10,
                coalesce: false,
                threads: 1,
                events: 100,
                makespan: 0.5,
                peak_pending_events: 9,
                messages_coalesced: 0,
                wall_secs: 0.01,
                events_per_sec: 10_000.0,
                round_p50: 0.0,
                round_p95: 0.0,
                round_p99: 0.0,
                qwait_p50: 0.0,
                qwait_p95: 0.0,
                qwait_p99: 0.0,
                windows: 0,
                window_cmds_sent: 0,
                window_cmds_skipped: 0,
                windows_scalar: 0,
            }],
        }
    }

    #[test]
    fn baseline_roundtrip_and_comparison() {
        let r = tiny_report();
        let p = std::env::temp_dir().join("ductr_bench_baseline_rt.json");
        r.write_json(&p).expect("write");
        let base = load_baseline(&p).expect("load");
        assert!(!base.placeholder);
        assert_eq!(base.cases.len(), 1);
        assert_eq!(base.cases[0].name, "cell A");
        assert!(!base.cases[0].coalesce);
        assert_eq!(base.cases[0].threads, 1);
        assert_eq!(base.cases[0].events, Some(100));
        assert!((base.cases[0].events_per_sec - 10_000.0).abs() < 1e-6);
        // identical numbers: no regression
        let s = r.compare_to_baseline(&base, "rt").expect("no regression");
        assert!(s.contains("cell A"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn baseline_regression_fails_matching_case() {
        let r = tiny_report();
        let base = Baseline {
            placeholder: false,
            cases: vec![BaselineCase {
                name: "cell A".into(),
                coalesce: false,
                threads: 1,
                events: Some(100),
                // current run is 10k ev/s — a > 30% drop vs 100k
                events_per_sec: 100_000.0,
            }],
        };
        let err = r.compare_to_baseline(&base, "x").expect_err("must regress");
        assert!(err.to_string().contains("regressed"), "{err}");
        // the same drop against a placeholder baseline is informational
        let mut ph = base;
        ph.placeholder = true;
        let s = r.compare_to_baseline(&ph, "x").expect("placeholder never fails");
        assert!(s.contains("placeholder"));
    }

    #[test]
    fn baseline_event_drift_fails_deterministically() {
        let r = tiny_report();
        let base = Baseline {
            placeholder: false,
            cases: vec![BaselineCase {
                name: "cell A".into(),
                coalesce: false,
                threads: 1,
                // identical throughput but a different deterministic event
                // count: the machine-independent canary must fire
                events: Some(101),
                events_per_sec: 10_000.0,
            }],
        };
        let err = r.compare_to_baseline(&base, "x").expect_err("drift must fail");
        assert!(err.to_string().contains("drifted"), "{err}");
    }

    #[test]
    fn thread_rows_check_events_against_the_oracle_row() {
        // A threads = 2 row matched by a threads = 2 baseline case whose
        // own event count is stale garbage: the canary must still compare
        // against the baseline's threads = 1 (oracle) count and pass …
        let mut r = tiny_report();
        r.cases[0].threads = 2;
        let mk = |threads: usize, events: u64| BaselineCase {
            name: "cell A".into(),
            coalesce: false,
            threads,
            events: Some(events),
            events_per_sec: 10_000.0,
        };
        let base = Baseline { placeholder: false, cases: vec![mk(1, 100), mk(2, 999)] };
        r.compare_to_baseline(&base, "x").expect("oracle row count wins");
        // … and a drifted oracle count must fail the sharded row too.
        let base = Baseline { placeholder: false, cases: vec![mk(1, 101), mk(2, 100)] };
        let err = r.compare_to_baseline(&base, "x").expect_err("oracle drift gates");
        assert!(err.to_string().contains("drifted"), "{err}");
        // without an oracle row the sharded row falls back to its match
        let base = Baseline { placeholder: false, cases: vec![mk(2, 100)] };
        r.compare_to_baseline(&base, "x").expect("fallback to the matched row");
    }

    #[test]
    fn baseline_with_no_matching_cases_reports_not_fails() {
        let r = tiny_report();
        let base = Baseline { placeholder: false, cases: vec![] };
        let s = r.compare_to_baseline(&base, "empty").expect("nothing to compare");
        assert!(s.contains("no matching cases"));
    }

    #[test]
    fn json_field_extracts_strings_numbers_bools() {
        let line = r#"    {"name": "cholesky nb=8 P=4", "coalesce": true, "threads": 2, "events": 123, "events_per_sec": 4567.8},"#;
        assert_eq!(json_field(line, "name"), Some("cholesky nb=8 P=4"));
        assert_eq!(json_field(line, "coalesce"), Some("true"));
        assert_eq!(json_field(line, "threads"), Some("2"));
        assert_eq!(json_field(line, "events"), Some("123"));
        assert_eq!(json_field(line, "events_per_sec"), Some("4567.8"));
        assert_eq!(json_field(line, "absent"), None);
    }
}
