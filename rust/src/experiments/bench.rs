//! `ductr bench` — the repeatable DES hot-path baseline.
//!
//! Times full simulator runs on the two standing workloads (block Cholesky
//! and the random layered DAG) across a process-count sweep, and writes a
//! JSON baseline (`BENCH_pr3.json` by default) so successive PRs have a
//! perf trajectory to compare against: events/sec, makespan, and the event-
//! heap high-water mark per case.
//!
//! Wall-clock numbers are machine-dependent; everything else in the file
//! (events, makespan, peak heap) is deterministic under the seed, which is
//! what makes the baseline diffable across engine changes.

use std::io::Write as _;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::apps::rand_dag;
use crate::cholesky::{self, ProcessGrid};
use crate::config::{Config, PolicyKind, TopologyKind};
use crate::core::graph::TaskGraph;
use crate::sim::engine::{SimEngine, SimResult};
use crate::util::bench::{run_with, BenchConfig};
use crate::util::error::{Error, Result};

/// One timed workload/process-count cell.
#[derive(Debug, Clone)]
pub struct BenchCase {
    pub name: String,
    pub workload: &'static str,
    pub processes: usize,
    pub tasks: usize,
    /// Events dispatched by one run (deterministic under the seed).
    pub events: u64,
    pub makespan: f64,
    pub peak_event_heap: usize,
    /// Median wall-clock seconds per run.
    pub wall_secs: f64,
    pub events_per_sec: f64,
}

#[derive(Debug)]
pub struct BenchReport {
    pub seed: u64,
    pub smoke: bool,
    pub cases: Vec<BenchCase>,
}

fn base_cfg(p: usize, seed: u64) -> Config {
    let mut c = Config::default();
    c.processes = p;
    c.grid = None; // derive the squarest grid for the Cholesky cells
    c.dlb_enabled = true;
    c.wt = 3;
    c.delta = 0.002;
    c.seed = seed;
    c
}

/// The full-profile random-DAG cell (the P = 256 instance is the hot-path
/// acceptance workload).  Shared with `benches/hotpath.rs` so the two
/// measurements cannot drift apart.
pub fn rand_dag_case(p: usize, seed: u64) -> (Config, Arc<TaskGraph>, String) {
    let mut cfg = base_cfg(p, seed);
    cfg.validate().expect("bench config");
    let mut params = rand_dag::DagParams::default();
    params.layers = 24;
    params.width = p.max(16);
    let name = format!("rand_dag {}x{} P={p}", params.layers, params.width);
    (cfg, rand_dag::build(p, params, seed), name)
}

/// Time `graph` under `cfg`; returns the (seed-deterministic) sim result of
/// the last run plus the median wall seconds over the harness samples.
fn time_case(cfg: &Config, graph: &Arc<TaskGraph>, name: &str, smoke: bool) -> (SimResult, f64) {
    let bc = if smoke {
        BenchConfig {
            warmup_iters: 0,
            samples: 2,
            iters_per_sample: 1,
            min_warmup_time: Duration::ZERO,
            max_total_time: Duration::from_secs(120),
        }
    } else {
        BenchConfig {
            warmup_iters: 1,
            samples: 3,
            iters_per_sample: 1,
            min_warmup_time: Duration::from_millis(1),
            max_total_time: Duration::from_secs(300),
        }
    };
    let mut last: Option<SimResult> = None;
    let res = run_with(&bc, name, || {
        let mut eng = SimEngine::from_config(cfg, Arc::clone(graph));
        let r = eng.run().expect("bench sim run");
        last = Some(r);
    });
    (last.expect("at least one sample ran"), res.summary.median)
}

/// Run the sweep.  `smoke` shrinks process counts and sizes to a few
/// seconds total for CI.
pub fn run(seed: u64, smoke: bool) -> Result<BenchReport> {
    let ps: &[usize] = if smoke { &[4, 8] } else { &[16, 64, 256] };
    let mut cases = Vec::new();

    for &p in ps {
        // --- block Cholesky ------------------------------------------
        let mut cfg = base_cfg(p, seed);
        cfg.nb = if smoke { 8 } else { 24 };
        cfg.block = if smoke { 128 } else { 256 };
        cfg.validate().map_err(Error::new)?;
        let dag = cholesky::build(cfg.nb, cfg.block, ProcessGrid::new(cfg.effective_grid()));
        let name = format!("cholesky nb={} P={p}", cfg.nb);
        let (r, wall) = time_case(&cfg, &dag.graph, &name, smoke);
        cases.push(case("cholesky", &name, p, dag.graph.num_tasks(), &r, wall));

        // --- random layered DAG --------------------------------------
        let (cfg, graph, name) = if smoke {
            let mut c = base_cfg(p, seed);
            c.validate().map_err(Error::new)?;
            let mut params = rand_dag::DagParams::default();
            params.layers = 6;
            params.width = 8;
            let name = format!("rand_dag {}x{} P={p}", params.layers, params.width);
            (c, rand_dag::build(p, params, seed), name)
        } else {
            rand_dag_case(p, seed)
        };
        let (r, wall) = time_case(&cfg, &graph, &name, smoke);
        cases.push(case("rand_dag", &name, p, graph.num_tasks(), &r, wall));

        // --- locality layer: hierarchical stealing + adaptive δ on the
        //     cluster fabric (PR 4's policy hot path) -------------------
        let mut c = base_cfg(p, seed);
        c.policy = PolicyKind::Hierarchical;
        c.topology = TopologyKind::Cluster;
        c.adaptive_delta = true;
        c.validate().map_err(Error::new)?;
        let mut params = rand_dag::DagParams::default();
        if smoke {
            params.layers = 6;
            params.width = 8;
        } else {
            params.layers = 24;
            params.width = p.max(16);
        }
        let name = format!("hier_cluster {}x{} P={p}", params.layers, params.width);
        let graph = rand_dag::build(p, params, seed);
        let (r, wall) = time_case(&c, &graph, &name, smoke);
        cases.push(case("hier_cluster", &name, p, graph.num_tasks(), &r, wall));
    }

    Ok(BenchReport { seed, smoke, cases })
}

fn case(
    workload: &'static str,
    name: &str,
    p: usize,
    tasks: usize,
    r: &SimResult,
    wall: f64,
) -> BenchCase {
    BenchCase {
        name: name.to_string(),
        workload,
        processes: p,
        tasks,
        events: r.events_processed,
        makespan: r.makespan,
        peak_event_heap: r.peak_event_heap,
        wall_secs: wall,
        events_per_sec: if wall > 0.0 { r.events_processed as f64 / wall } else { 0.0 },
    }
}

impl BenchReport {
    /// ASCII quick-look table.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "ductr bench (seed {}{})\n{:<28} {:>6} {:>7} {:>10} {:>11} {:>10} {:>12}\n",
            self.seed,
            if self.smoke { ", smoke" } else { "" },
            "case",
            "P",
            "tasks",
            "events",
            "makespan",
            "peak-heap",
            "events/s"
        ));
        for c in &self.cases {
            s.push_str(&format!(
                "{:<28} {:>6} {:>7} {:>10} {:>11.4} {:>10} {:>12.0}\n",
                c.name, c.processes, c.tasks, c.events, c.makespan, c.peak_event_heap,
                c.events_per_sec
            ));
        }
        s
    }

    /// Hand-rolled JSON (the offline crate set has no serde): one object
    /// with a `cases` array, numbers emitted raw.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{{")?;
        writeln!(f, "  \"generated_by\": \"ductr bench\",")?;
        writeln!(f, "  \"seed\": {},", self.seed)?;
        writeln!(f, "  \"smoke\": {},", self.smoke)?;
        writeln!(f, "  \"cases\": [")?;
        for (i, c) in self.cases.iter().enumerate() {
            let comma = if i + 1 < self.cases.len() { "," } else { "" };
            writeln!(
                f,
                "    {{\"name\": \"{}\", \"workload\": \"{}\", \"processes\": {}, \
                 \"tasks\": {}, \"events\": {}, \"makespan\": {}, \
                 \"peak_event_heap\": {}, \"wall_secs\": {}, \"events_per_sec\": {}}}{comma}",
                c.name,
                c.workload,
                c.processes,
                c.tasks,
                c.events,
                c.makespan,
                c.peak_event_heap,
                c.wall_secs,
                c.events_per_sec
            )?;
        }
        writeln!(f, "  ]")?;
        writeln!(f, "}}")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_sweep_runs_and_serializes() {
        let r = run(1, true).expect("smoke bench");
        assert_eq!(r.cases.len(), 6); // 3 workloads × 2 process counts
        assert!(r.cases.iter().all(|c| c.events > 0 && c.makespan > 0.0));
        assert!(r.cases.iter().all(|c| c.peak_event_heap > 0));
        assert!(r.cases.iter().any(|c| c.workload == "hier_cluster"));
        let rendered = r.render();
        assert!(rendered.contains("events/s"));
        let p = std::env::temp_dir().join("ductr_bench_smoke.json");
        r.write_json(&p).expect("json write");
        let body = std::fs::read_to_string(&p).expect("json read");
        assert!(body.starts_with('{') && body.trim_end().ends_with('}'));
        assert_eq!(body.matches("\"name\"").count(), 6);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn bench_metrics_deterministic_under_seed() {
        let a = run(7, true).expect("a");
        let b = run(7, true).expect("b");
        for (x, y) in a.cases.iter().zip(&b.cases) {
            assert_eq!(x.events, y.events, "{}", x.name);
            assert_eq!(x.makespan, y.makespan, "{}", x.name);
            assert_eq!(x.peak_event_heap, y.peak_event_heap, "{}", x.name);
        }
    }
}
