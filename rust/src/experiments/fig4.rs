//! Figure 4: Cholesky workload traces + makespans, DLB off vs on, for the
//! paper's two configurations:
//!
//! - left:  N = 20 000, 12×12 blocks (b = 1667), P = 10 on a 2×5 grid
//! - right: N = 30 000, 12×12 blocks (b = 2500), P = 15 on a 3×5 grid
//!
//! Protocol follows §6 exactly: run once without DLB, calibrate
//! W_T = max w_i(t)/2, then run with DLB (Basic strategy, δ = 10 ms).
//! The paper reports a 5–6% execution-time reduction.

use crate::cholesky::driver::{run_sim, CholeskyReport};
use crate::config::{Config, Grid, Strategy};
use crate::dlb::threshold::calibrate_from_traces;
use crate::util::plot::{self, Series};

/// One paper configuration.
#[derive(Debug, Clone, Copy)]
pub struct CaseSpec {
    pub name: &'static str,
    pub matrix_n: usize,
    pub nb: usize,
    pub processes: usize,
    pub grid: (usize, usize),
}

/// The two Fig 4 cases.
pub const CASES: [CaseSpec; 2] = [
    CaseSpec { name: "N=20000 P=10 2x5", matrix_n: 20_000, nb: 12, processes: 10, grid: (2, 5) },
    CaseSpec { name: "N=30000 P=15 3x5", matrix_n: 30_000, nb: 12, processes: 15, grid: (3, 5) },
];

#[derive(Debug)]
pub struct CaseResult {
    pub spec: CaseSpec,
    pub calibrated_wt: usize,
    pub off: CholeskyReport,
    pub on: CholeskyReport,
}

impl CaseResult {
    /// Relative improvement of DLB (positive = faster with DLB).
    pub fn improvement(&self) -> f64 {
        (self.off.makespan - self.on.makespan) / self.off.makespan
    }
}

/// Build the Config for a case (sim mode, paper cost model S/R = 40).
pub fn case_config(spec: &CaseSpec, dlb: bool, wt: usize, seed: u64) -> Config {
    let mut c = Config::default();
    c.processes = spec.processes;
    c.grid = Some(Grid::new(spec.grid.0, spec.grid.1));
    c.nb = spec.nb;
    c.block = spec.matrix_n / spec.nb;
    c.dlb_enabled = dlb;
    c.strategy = Strategy::Basic;
    c.wt = wt;
    c.delta = 0.010;
    c.seed = seed;
    c.validate().expect("fig4 config");
    c
}

/// Run one case end-to-end with §6 calibration.
pub fn run_case(spec: &CaseSpec, seed: u64) -> crate::util::error::Result<CaseResult> {
    let off = run_sim(&case_config(spec, false, 5, seed))?;
    let wt = calibrate_from_traces(&off.traces);
    let on = run_sim(&case_config(spec, true, wt, seed))?;
    Ok(CaseResult { spec: *spec, calibrated_wt: wt, off, on })
}

/// Run both paper cases.
pub fn run(seed: u64) -> crate::util::error::Result<Vec<CaseResult>> {
    CASES.iter().map(|s| run_case(s, seed)).collect()
}

impl CaseResult {
    /// ASCII workload traces (a subset of processes for readability),
    /// off vs on in two panels — the Fig 4 quick-look.
    pub fn render(&self, max_procs: usize) -> String {
        let mut out = String::new();
        for (label, rep) in [("DLB off", &self.off), ("DLB on", &self.on)] {
            let t_end = rep.traces.makespan;
            let series: Vec<Series> = rep
                .traces
                .per_process
                .iter()
                .take(max_procs)
                .enumerate()
                .map(|(i, tr)| Series::new(format!("p{i}"), tr.resample(t_end, 80)))
                .collect();
            out.push_str(&plot::plot(
                &format!(
                    "Fig 4 [{}] {label}: w_i(t), makespan = {:.3}s",
                    self.spec.name, rep.makespan
                ),
                &series,
                70,
                12,
            ));
        }
        out.push_str(&format!(
            "improvement: {:+.2}% (W_T = {}, {} migrations)\n",
            self.improvement() * 100.0,
            self.calibrated_wt,
            self.on.counters.tasks_exported,
        ));
        out
    }

    /// CSV rows: process, time, workload, dlb(0/1).
    pub fn csv_rows(&self) -> Vec<Vec<f64>> {
        let mut rows = Vec::new();
        for (dlb, rep) in [(0.0, &self.off), (1.0, &self.on)] {
            for (p, tr) in rep.traces.per_process.iter().enumerate() {
                for &(t, w) in tr.samples() {
                    rows.push(vec![p as f64, t, w as f64, dlb]);
                }
            }
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scaled-down variant for fast tests (same structure, nb=12).
    fn small_case() -> CaseSpec {
        CaseSpec { name: "test N=1200 P=10 2x5", matrix_n: 1200, nb: 12, processes: 10, grid: (2, 5) }
    }

    #[test]
    fn calibration_and_both_runs_complete() {
        let r = run_case(&small_case(), 1).expect("case");
        assert!(r.calibrated_wt >= 1);
        assert!(r.off.makespan > 0.0 && r.on.makespan > 0.0);
        assert!(r.on.counters.rounds > 0, "DLB must have searched");
    }

    #[test]
    fn dlb_does_not_catastrophically_regress() {
        let r = run_case(&small_case(), 3).expect("case");
        assert!(
            r.improvement() > -0.15,
            "DLB may jitter but not collapse: {:+.2}%",
            r.improvement() * 100.0
        );
    }

    #[test]
    fn render_mentions_improvement() {
        let r = run_case(&small_case(), 1).expect("case");
        let s = r.render(4);
        assert!(s.contains("improvement"));
        assert!(!r.csv_rows().is_empty());
    }
}
