//! Head-to-head balancer comparison: policy × topology × adaptive-δ ×
//! workload.
//!
//! The question the policy subsystem exists to answer: how do the paper's
//! randomized pairing, classic work stealing, hierarchical locality-aware
//! stealing, and the two diffusion schemes (first-order and second-order)
//! compare — on the same workloads, the same cost model, the same
//! deterministic simulator — as the interconnect gets less flat, and does
//! the AIMD δ controller help?
//!
//! For every (workload, topology) cell the experiment runs a DLB-off
//! baseline plus one run per (policy, adaptive on/off), reporting makespan,
//! improvement over the baseline, migrated-task counts — total and
//! **inter-node** (the > 1 hop migrations locality-aware stealing exists to
//! avoid) — and control-message volume.  P = 16 on a 4×4 grid so the
//! cluster topology realizes as `cluster4x4`: four nodes of four ranks.
//! Everything is DES mode under one seed: rerunning with the same seed
//! reproduces the table bit-for-bit.

use std::sync::Arc;

use crate::apps::rand_dag;
use crate::cholesky;
use crate::config::{Config, Grid, PolicyKind, TopologyKind};
use crate::metrics::counters::DlbCounters;
use crate::metrics::histogram::fmt_secs;
use crate::metrics::LatencyReport;
use crate::sim::engine::SimEngine;
use crate::util::error::{Context, Result};

/// Workloads under comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompareWorkload {
    Cholesky,
    RandDag,
}

impl CompareWorkload {
    pub const ALL: [CompareWorkload; 2] = [CompareWorkload::Cholesky, CompareWorkload::RandDag];

    pub fn label(self) -> &'static str {
        match self {
            CompareWorkload::Cholesky => "cholesky",
            CompareWorkload::RandDag => "rand_dag",
        }
    }
}

/// Topologies under comparison (flat = the paper's network; torus and
/// cluster = the closed-form shapes where locality starts to matter;
/// randreg:3 = a graph-backed shape answering from the BFS distance table —
/// sparse, small-diameter, the regime where SOS diffusion's spectral tuning
/// pays off).
pub const TOPOLOGIES: [TopologyKind; 4] = [
    TopologyKind::Flat,
    TopologyKind::Torus,
    TopologyKind::Cluster,
    TopologyKind::RandReg { d: 3 },
];

/// One run's outcome.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub workload: CompareWorkload,
    pub topology: TopologyKind,
    /// `None` = the DLB-off baseline.
    pub policy: Option<PolicyKind>,
    /// The AIMD δ controller was active (always false for the baseline).
    pub adaptive: bool,
    pub makespan: f64,
    pub counters: DlbCounters,
    /// p95 pair-search round latency (NaN when the run had no rounds —
    /// the DLB-off baseline).
    pub round_p95: f64,
    /// p95 task queue wait (ready → execution start).
    pub queue_wait_p95: f64,
}

impl CompareRow {
    pub fn policy_label(&self) -> String {
        match self.policy {
            None => "off".to_string(),
            Some(p) => p.to_string(),
        }
    }

    pub fn adaptive_label(&self) -> &'static str {
        match (self.policy, self.adaptive) {
            (None, _) => "—",
            (_, true) => "on",
            (_, false) => "off",
        }
    }
}

#[derive(Debug)]
pub struct CompareResult {
    pub rows: Vec<CompareRow>,
    pub seed: u64,
    pub processes: usize,
}

/// P = 16 on a 4×4 grid: torus16 = torus4x4 and cluster = cluster4x4.
const PROCESSES: usize = 16;

fn base_config(w: CompareWorkload, topo: TopologyKind, seed: u64, quick: bool) -> Config {
    let mut c = Config::default();
    c.processes = PROCESSES;
    c.grid = Some(Grid::new(4, 4));
    c.cluster_nodes = 4;
    c.seed = seed;
    c.topology = topo;
    c.wt = 3;
    c.delta = 0.002;
    // Record spans everywhere: the determinism test below doubles as the
    // standing check that tracing never perturbs results, and the table
    // gains latency columns for free.
    c.trace_enabled = true;
    match w {
        CompareWorkload::Cholesky => {
            c.nb = if quick { 8 } else { 12 };
            c.block = if quick { 128 } else { 256 };
        }
        CompareWorkload::RandDag => {}
    }
    c.validate().expect("compare config");
    c
}

fn run_one(w: CompareWorkload, cfg: &Config) -> Result<(f64, DlbCounters, LatencyReport)> {
    match w {
        CompareWorkload::Cholesky => {
            let r = cholesky::run_sim(cfg)
                .with_context(|| format!("cholesky on {}", cfg.topology))?;
            Ok((r.makespan, r.counters, LatencyReport::from_trace(&r.trace)))
        }
        CompareWorkload::RandDag => {
            let mut params = rand_dag::DagParams::default();
            params.layers = 8;
            params.width = 24;
            let g = rand_dag::build(cfg.processes, params, cfg.seed);
            let r = SimEngine::from_config(cfg, Arc::clone(&g))
                .run()
                .map_err(crate::util::error::Error::new)?;
            Ok((r.makespan, r.counters, LatencyReport::from_trace(&r.trace)))
        }
    }
}

/// Run the full sweep: 2 workloads × 4 topologies × (off + 5 policies × 2
/// adaptive settings).
pub fn run(seed: u64, quick: bool) -> Result<CompareResult> {
    let mut rows = Vec::new();
    for w in CompareWorkload::ALL {
        for topo in TOPOLOGIES {
            let mut cfg = base_config(w, topo, seed, quick);
            cfg.dlb_enabled = false;
            let (makespan, counters, lat) = run_one(w, &cfg)?;
            rows.push(CompareRow {
                workload: w,
                topology: topo,
                policy: None,
                adaptive: false,
                makespan,
                counters,
                round_p95: lat.round.quantile(0.95),
                queue_wait_p95: lat.queue_wait.quantile(0.95),
            });
            for policy in PolicyKind::ALL {
                for adaptive in [false, true] {
                    let mut cfg = base_config(w, topo, seed, quick);
                    cfg.dlb_enabled = true;
                    cfg.policy = policy;
                    cfg.adaptive_delta = adaptive;
                    let (makespan, counters, lat) = run_one(w, &cfg)?;
                    rows.push(CompareRow {
                        workload: w,
                        topology: topo,
                        policy: Some(policy),
                        adaptive,
                        makespan,
                        counters,
                        round_p95: lat.round.quantile(0.95),
                        queue_wait_p95: lat.queue_wait.quantile(0.95),
                    });
                }
            }
        }
    }
    Ok(CompareResult { rows, seed, processes: PROCESSES })
}

impl CompareResult {
    /// Baseline (DLB-off) makespan for a cell.
    fn baseline(&self, w: CompareWorkload, topo: TopologyKind) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.workload == w && r.topology == topo && r.policy.is_none())
            .map(|r| r.makespan)
    }

    /// ASCII quick-look table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "balancer comparison — P = {}, seed = {} (DES, deterministic)\n",
            self.processes, self.seed
        ));
        out.push_str(&format!(
            "{:<10} {:<12} {:<13} {:<9} {:>12} {:>8} {:>10} {:>11} {:>10} {:>10} {:>10}\n",
            "workload",
            "topology",
            "policy",
            "adaptive",
            "makespan_s",
            "vs_off",
            "migrated",
            "inter_node",
            "ctrl_msgs",
            "round_p95",
            "qwait_p95"
        ));
        for r in &self.rows {
            let vs = match (r.policy, self.baseline(r.workload, r.topology)) {
                (Some(_), Some(base)) if base > 0.0 => {
                    format!("{:+.1}%", (base - r.makespan) / base * 100.0)
                }
                _ => "—".to_string(),
            };
            out.push_str(&format!(
                "{:<10} {:<12} {:<13} {:<9} {:>12.6} {:>8} {:>10} {:>11} {:>10} {:>10} {:>10}\n",
                r.workload.label(),
                r.topology.to_string(),
                r.policy_label(),
                r.adaptive_label(),
                r.makespan,
                vs,
                r.counters.tasks_exported,
                r.counters.tasks_exported_remote,
                r.counters.requests_sent,
                fmt_secs(r.round_p95),
                fmt_secs(r.queue_wait_p95),
            ));
        }
        out
    }

    /// CSV with readable labels (policy/topology as strings).
    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "workload,topology,policy,adaptive,makespan,migrated,migrated_remote,received,transactions,requests,round_p95,queue_wait_p95"
        )?;
        for r in &self.rows {
            writeln!(
                f,
                "{},{},{},{},{},{},{},{},{},{},{},{}",
                r.workload.label(),
                r.topology,
                r.policy_label(),
                r.adaptive,
                r.makespan,
                r.counters.tasks_exported,
                r.counters.tasks_exported_remote,
                r.counters.tasks_received,
                r.counters.transactions,
                r.counters.requests_sent,
                r.round_p95,
                r.queue_wait_p95,
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_compare_covers_the_grid_and_is_deterministic() {
        let a = run(3, true).expect("run a");
        // 2 workloads × 4 topologies × (1 baseline + 5 policies × 2 adaptive)
        assert_eq!(a.rows.len(), 2 * 4 * 11);
        for r in &a.rows {
            assert!(r.makespan > 0.0, "{r:?}");
            // every run executes tasks, so queue-wait always has samples;
            // the DLB-off baseline has no rounds, so its round p95 is NaN
            assert!(r.queue_wait_p95.is_finite(), "{r:?}");
            if r.policy.is_none() {
                assert_eq!(r.counters.tasks_exported, 0, "baseline must not migrate");
                assert!(r.round_p95.is_nan(), "baseline has no rounds: {r:?}");
            }
            assert!(
                r.counters.tasks_exported_remote <= r.counters.tasks_exported,
                "remote is a subset: {r:?}"
            );
            if r.topology == TopologyKind::Flat {
                assert_eq!(
                    r.counters.tasks_exported_remote, 0,
                    "flat is single-hop everywhere: {r:?}"
                );
            }
        }
        let b = run(3, true).expect("run b");
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.makespan, y.makespan, "seeded rerun must reproduce");
            assert_eq!(x.counters, y.counters);
            assert_eq!(x.round_p95.to_bits(), y.round_p95.to_bits());
            assert_eq!(x.queue_wait_p95.to_bits(), y.queue_wait_p95.to_bits());
        }
    }

    #[test]
    fn every_policy_migrates_on_some_cell() {
        let r = run(5, true).expect("run");
        for policy in PolicyKind::ALL {
            let moved: u64 = r
                .rows
                .iter()
                .filter(|row| row.policy == Some(policy))
                .map(|row| row.counters.tasks_exported)
                .sum();
            assert!(moved > 0, "{policy} never migrated anything");
        }
    }

    /// The acceptance bar of the locality layer: on the cluster fabric,
    /// hierarchical stealing must move fewer tasks *across nodes* than
    /// uniform stealing — that is the entire point of the escalation ladder.
    #[test]
    fn hierarchical_localizes_migration_on_cluster() {
        let r = run(1, true).expect("run"); // the default seed
        let remote_sum = |policy: PolicyKind| -> u64 {
            r.rows
                .iter()
                .filter(|row| {
                    row.topology == TopologyKind::Cluster
                        && row.policy == Some(policy)
                        && !row.adaptive
                })
                .map(|row| row.counters.tasks_exported_remote)
                .sum()
        };
        let hier = remote_sum(PolicyKind::Hierarchical);
        let steal = remote_sum(PolicyKind::WorkStealing);
        assert!(
            hier < steal,
            "hierarchical must migrate fewer tasks across nodes than uniform \
             stealing on cluster4x4: {hier} vs {steal}"
        );
    }

    #[test]
    fn render_and_csv_smoke() {
        let r = run(1, true).expect("run");
        let table = r.render();
        assert!(table.contains("cholesky"));
        assert!(table.contains("hierarchical"));
        assert!(table.contains("sos-diffusion"), "SOS rows in the table");
        assert!(table.contains("randreg:3"), "graph-topology leg in the table");
        assert!(table.contains("inter_node"));
        let p = std::env::temp_dir().join("ductr_compare_test.csv");
        r.write_csv(&p).expect("csv");
        let body = std::fs::read_to_string(&p).expect("read");
        assert!(body.starts_with("workload,topology,policy,adaptive"));
        assert_eq!(body.lines().count(), 1 + r.rows.len());
        let _ = std::fs::remove_file(p);
    }
}
