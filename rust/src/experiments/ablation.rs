//! Ablations over the paper's design choices (DESIGN.md §5 "beyond the
//! paper's tables"):
//!
//! - **strategy** — Basic vs Equalizing vs Smart on the Fig 4-left case
//!   (the paper suggests Equalizing where Basic overshoots, §6);
//! - **δ sweep** — sensitivity of the makespan to the back-off period;
//! - **gap model** — §3's suggested middle-zone hysteresis vs the base
//!   single-threshold model.

use crate::cholesky::driver::run_sim;
use crate::config::{Config, Grid, Strategy};

/// One ablation row.
#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub makespan: f64,
    pub improvement_vs_off: f64,
    pub migrations: u64,
    pub requests: u64,
    /// Max queue overshoot: max w_i(t) with DLB on (overshoot shows up as a
    /// receiving process spiking above the donor's original load).
    pub max_w: usize,
}

#[derive(Debug)]
pub struct AblationResult {
    pub baseline_makespan: f64,
    pub strategies: Vec<Row>,
    pub deltas: Vec<Row>,
    pub gaps: Vec<Row>,
}

fn base_cfg(seed: u64) -> Config {
    let mut c = Config::default();
    c.processes = 10;
    c.grid = Some(Grid::new(2, 5));
    c.nb = 12;
    c.block = 1667; // N = 20 004 ≈ the paper's 20 000
    c.wt = 5;
    c.delta = 0.010;
    c.seed = seed;
    c.validate().expect("ablation config");
    c
}

fn run_row(label: String, cfg: &Config, baseline: f64) -> crate::util::error::Result<Row> {
    let r = run_sim(cfg)?;
    Ok(Row {
        label,
        makespan: r.makespan,
        improvement_vs_off: (baseline - r.makespan) / baseline,
        migrations: r.counters.tasks_exported,
        requests: r.counters.requests_sent,
        max_w: r.traces.max_workload(),
    })
}

/// Run the full ablation suite.
pub fn run(seed: u64) -> crate::util::error::Result<AblationResult> {
    let mut off = base_cfg(seed);
    off.dlb_enabled = false;
    let baseline = run_sim(&off)?.makespan;

    let mut strategies = Vec::new();
    for s in [Strategy::Basic, Strategy::Equalizing, Strategy::Smart] {
        let mut c = base_cfg(seed);
        c.dlb_enabled = true;
        c.strategy = s;
        strategies.push(run_row(format!("strategy={s}"), &c, baseline)?);
    }

    let mut deltas = Vec::new();
    for d in [0.001, 0.005, 0.010, 0.050, 0.200] {
        let mut c = base_cfg(seed);
        c.dlb_enabled = true;
        c.delta = d;
        deltas.push(run_row(format!("delta={}ms", d * 1e3), &c, baseline)?);
    }

    let mut gaps = Vec::new();
    for g in [0usize, 2, 5, 10] {
        let mut c = base_cfg(seed);
        c.dlb_enabled = true;
        c.wt_gap = g;
        gaps.push(run_row(format!("gap={g}"), &c, baseline)?);
    }

    Ok(AblationResult { baseline_makespan: baseline, strategies, deltas, gaps })
}

impl AblationResult {
    pub fn render(&self) -> String {
        let mut out = format!(
            "Ablations on Fig 4-left (baseline DLB-off makespan {:.3}s)\n\
             {:<18} {:>10} {:>9} {:>7} {:>9} {:>6}\n",
            self.baseline_makespan, "variant", "makespan", "improv", "migr", "requests", "max_w"
        );
        for group in [&self.strategies, &self.deltas, &self.gaps] {
            for r in group {
                out.push_str(&format!(
                    "{:<18} {:>9.3}s {:>8.2}% {:>7} {:>9} {:>6}\n",
                    r.label,
                    r.makespan,
                    r.improvement_vs_off * 100.0,
                    r.migrations,
                    r.requests,
                    r.max_w
                ));
            }
            out.push('\n');
        }
        out
    }

    pub fn csv_rows(&self) -> Vec<Vec<f64>> {
        let all = self.strategies.iter().chain(&self.deltas).chain(&self.gaps);
        all.enumerate()
            .map(|(i, r)| {
                vec![
                    i as f64,
                    r.makespan,
                    r.improvement_vs_off,
                    r.migrations as f64,
                    r.requests as f64,
                    r.max_w as f64,
                ]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(seed: u64, gap: usize, strategy: Strategy) -> Config {
        let mut c = base_cfg(seed);
        c.block = 128; // fast test scale
        c.dlb_enabled = true;
        c.wt_gap = gap;
        c.strategy = strategy;
        c
    }

    #[test]
    fn gap_reduces_migrations() {
        // the middle zone shrinks the busy set: fewer processes qualify to
        // export, so migrations fall (§3's overshoot-damping mechanism).
        let off = {
            let mut c = base_cfg(3);
            c.block = 128;
            c.dlb_enabled = false;
            c
        };
        let baseline = run_sim(&off).expect("off").makespan;
        let r0 = run_row("gap0".into(), &small(3, 0, Strategy::Basic), baseline).expect("gap0");
        let r5 = run_row("gap5".into(), &small(3, 5, Strategy::Basic), baseline).expect("gap5");
        assert!(
            r5.migrations <= r0.migrations,
            "gap must not increase migrations: {} vs {}",
            r5.migrations,
            r0.migrations
        );
    }

    #[test]
    fn all_strategies_complete_small_scale() {
        let off = {
            let mut c = base_cfg(1);
            c.block = 128;
            c.dlb_enabled = false;
            c
        };
        let baseline = run_sim(&off).expect("off").makespan;
        for s in [Strategy::Basic, Strategy::Equalizing, Strategy::Smart] {
            let r = run_row(format!("{s}"), &small(1, 0, s), baseline).expect("run");
            assert!(r.makespan > 0.0);
        }
    }
}
