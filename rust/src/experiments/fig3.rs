//! Figure 3: average (and max) time for finding a busy–idle process pair,
//! measured on the live protocol in the DES.
//!
//! Setup mirrors the paper's measurement: K of P processes hold deep queues
//! of long tasks (busy, w > W_T), the rest are idle; every process runs the
//! full randomized pairing protocol with 5 tries per round and δ back-off.
//! A trial's pairing time is the virtual time of the first confirmed
//! transaction; each (P, busy-fraction) cell aggregates many seeded trials.

use std::sync::Arc;

use crate::config::Config;
use crate::core::graph::GraphBuilder;
use crate::core::ids::ProcessId;
use crate::core::task::TaskKind;
use crate::sim::engine::SimEngine;
use crate::util::plot::{self, Series};
use crate::util::stats::Summary;

/// One measured cell.
#[derive(Debug, Clone)]
pub struct Cell {
    pub processes: usize,
    pub busy_fraction: f64,
    pub mean: f64,
    pub max: f64,
    pub summary: Summary,
}

#[derive(Debug, Clone)]
pub struct Fig3Result {
    pub delta: f64,
    pub cells: Vec<Cell>,
}

/// Run one pairing trial; returns the virtual time until a *designated*
/// process completes its first pairing.
///
/// Two design points, both matching the paper's measurement:
///
/// - Roles are **pinned** (`role_override`): the micro-benchmark measures
///   the protocol, not queue dynamics — without pinning, the first export
///   equalizes the queues and the busy/idle mix dissolves mid-measurement.
/// - We watch one designated idle process ("the waiting process" of the
///   paper's δ discussion).  Measuring "first pair anywhere" would shrink
///   with P by extreme-value statistics — not the per-process expectation
///   the paper plots.
pub fn pairing_time(p: usize, busy: usize, delta: f64, seed: u64) -> f64 {
    assert!(busy >= 1 && busy < p, "need at least one busy and one idle");
    let mut cfg = Config::default();
    cfg.processes = p;
    cfg.grid = None;
    cfg.dlb_enabled = true;
    cfg.wt = 2;
    cfg.delta = delta;
    cfg.seed = seed;
    cfg.validate().expect("valid fig3 config");

    // one never-finishing task per process keeps the run alive; roles come
    // from the override, not the queues
    let mut gb = GraphBuilder::new();
    for i in 0..p {
        let d = gb.data(ProcessId(i as u32), 64, 64);
        gb.task(TaskKind::Synthetic, vec![], d, u64::MAX / 1024, None);
    }
    let graph = gb.build();
    let mut eng = SimEngine::from_config(&cfg, Arc::clone(&graph));
    for (i, ps) in eng.processes.iter_mut().enumerate() {
        ps.role_override = Some(if i < busy { crate::net::Role::Busy } else { crate::net::Role::Idle });
    }
    let target = p - 1; // a (pinned) idle process
    eng.stop_when = Some(Box::new(move |procs| {
        procs[target].counters().transactions > 0
    }));
    eng.max_time = 3600.0;
    let r = eng.run().expect("fig3 trial");
    r.end_time
}

/// Full figure: sweep P and busy fraction, `trials` seeds per cell.
pub fn run(p_values: &[usize], fractions: &[f64], delta: f64, trials: usize, seed: u64) -> Fig3Result {
    let mut cells = Vec::new();
    for &p in p_values {
        for &f in fractions {
            let busy = ((p as f64 * f).round() as usize).clamp(1, p - 1);
            let times: Vec<f64> = (0..trials)
                .map(|t| pairing_time(p, busy, delta, seed ^ ((t as u64) << 20) ^ (p as u64)))
                .collect();
            let s = Summary::of(&times);
            cells.push(Cell {
                processes: p,
                busy_fraction: f,
                mean: s.mean,
                max: s.max,
                summary: s,
            });
        }
    }
    Fig3Result { delta, cells }
}

impl Fig3Result {
    /// ASCII: mean pairing time vs P, one series per busy fraction.
    pub fn render(&self) -> String {
        let mut fractions: Vec<f64> = self.cells.iter().map(|c| c.busy_fraction).collect();
        fractions.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
        fractions.dedup();
        let series: Vec<Series> = fractions
            .iter()
            .map(|&f| {
                Series::new(
                    format!("busy {:.0}%", f * 100.0),
                    self.cells
                        .iter()
                        .filter(|c| c.busy_fraction == f)
                        .map(|c| (c.processes as f64, c.mean * 1e3))
                        .collect(),
                )
            })
            .collect();
        plot::plot(
            &format!("Fig 3: mean time to find a pair [ms], δ = {} ms", self.delta * 1e3),
            &series,
            60,
            14,
        )
    }

    /// CSV rows: processes, busy_fraction, mean, max, p95.
    pub fn csv_rows(&self) -> Vec<Vec<f64>> {
        self.cells
            .iter()
            .map(|c| {
                vec![c.processes as f64, c.busy_fraction, c.mean, c.max, c.summary.p95]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_trial_is_fast_and_positive() {
        let t = pairing_time(10, 5, 0.010, 42);
        assert!(t > 0.0);
        // with 50% busy, success probability per round > 96% ⇒ expected time
        // well under a handful of δ (staggered start adds ≤ 1 δ)
        assert!(t < 0.2, "pairing took {t}s");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = pairing_time(12, 6, 0.01, 7);
        let b = pairing_time(12, 6, 0.01, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn harder_mix_is_not_faster_on_average() {
        // K = P/2 is the paper's hardest case; K near P should pair faster
        // for an idle searcher (easy to find a busy peer).
        let r = run(&[16], &[0.5, 0.9], 0.01, 12, 3);
        assert_eq!(r.cells.len(), 2);
        for c in &r.cells {
            assert!(c.mean > 0.0 && c.max >= c.mean);
        }
    }

    #[test]
    fn grows_slowly_with_p() {
        // paper: "the average time grows slowly with the number of
        // processes" — check it does not explode (×10) from P=8 to P=64.
        let r = run(&[8, 64], &[0.5], 0.01, 10, 9);
        let t8 = r.cells[0].mean;
        let t64 = r.cells[1].mean;
        assert!(t64 < t8 * 10.0, "t8={t8} t64={t64}");
    }
}
