//! `ductr bench --real` — the threaded-runtime fast-path baseline: a real
//! (wallclock, multi-thread) matrix over P × policy × cores on the
//! imbalanced synthetic bag, reporting makespan and round-latency
//! percentiles from the span recorder.
//!
//! Unlike the DES bench (`experiments::bench`), these numbers are wallclock
//! and therefore machine- and scheduler-dependent — there is no baseline
//! regression gate on timing.  What the run *does* gate on, hard, is
//! behavior: every cell must complete, and every DLB-on cell must actually
//! migrate work (a cell whose coordinator stopped answering the pairing
//! protocol fails the whole bench, which is exactly the regression the
//! async-outbox / event-driven-coordinator fast path exists to prevent).
//! That makes `--smoke` safe for CI on loaded shared runners.

use std::io::Write as _;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::anyhow;
use crate::config::{Config, PolicyKind};
use crate::core::graph::{GraphBuilder, TaskGraph};
use crate::core::ids::ProcessId;
use crate::core::task::TaskKind;
use crate::metrics::LatencyReport;
use crate::runtime::{run_threaded, InitialData};
use crate::util::error::Result;

/// One threaded cell: a policy (or DLB off) at a process/core count.
#[derive(Debug, Clone)]
pub struct RealBenchCase {
    pub name: String,
    pub processes: usize,
    pub cores: usize,
    /// `"off"` or the `PolicyKind` name.
    pub policy: String,
    pub tasks: usize,
    /// Wallclock seconds, start → last task completion.
    pub makespan: f64,
    pub tasks_exported: u64,
    pub rounds: u64,
    pub round_p50: f64,
    pub round_p95: f64,
    pub qwait_p95: f64,
    /// Whole-cell wall time (includes thread spawn/join overhead).
    pub wall_secs: f64,
}

#[derive(Debug, Clone)]
pub struct RealBenchReport {
    pub seed: u64,
    pub smoke: bool,
    pub cases: Vec<RealBenchCase>,
}

/// The imbalanced bag: `n` independent tasks, all homed on rank 0 — the
/// workload every DLB policy exists to spread.
fn bag(n: usize, flops: u64) -> Arc<TaskGraph> {
    let mut b = GraphBuilder::new();
    for _ in 0..n {
        let d = b.data(ProcessId(0), 16, 16);
        b.task(TaskKind::Synthetic, vec![], d, flops, None);
    }
    b.build()
}

fn cell_config(
    p: usize,
    cores: usize,
    policy: Option<PolicyKind>,
    seed: u64,
) -> Result<Config> {
    let mut cfg = Config::default();
    cfg.processes = p;
    cfg.cores_per_process = cores;
    cfg.seed = seed;
    cfg.dlb_enabled = policy.is_some();
    if let Some(pk) = policy {
        cfg.policy = pk;
    }
    cfg.wt = 2;
    cfg.delta = 0.001;
    cfg.flops_per_sec = 1e9;
    // shaped wire so every cell exercises the async outbox: 200 µs per hop
    // plus a finite bandwidth term, same cost model as the DES
    cfg.net_latency = 0.0002;
    cfg.doubles_per_sec = 5e7;
    cfg.trace_enabled = true;
    cfg.validate().map_err(|e| anyhow!("bench --real config: {e}"))?;
    Ok(cfg)
}

fn policy_label(policy: Option<PolicyKind>) -> String {
    match policy {
        None => "off".to_string(),
        Some(pk) => pk.to_string(),
    }
}

/// Run the matrix.  `smoke` shrinks it to a seconds-scale CI profile.
pub fn run(seed: u64, smoke: bool) -> Result<RealBenchReport> {
    let (p_list, cores_list): (&[usize], &[usize]) =
        if smoke { (&[2, 4], &[2]) } else { (&[2, 4, 8], &[1, 2]) };
    let policies: Vec<Option<PolicyKind>> = if smoke {
        vec![None, Some(PolicyKind::RandomPairing), Some(PolicyKind::WorkStealing)]
    } else {
        vec![
            None,
            Some(PolicyKind::RandomPairing),
            Some(PolicyKind::WorkStealing),
            Some(PolicyKind::Diffusion),
        ]
    };
    // 1 ms tasks keep the smoke matrix in CI seconds; 2 ms in the full one
    let (tasks_per_p, flops): (usize, u64) =
        if smoke { (8, 1_000_000) } else { (12, 2_000_000) };

    let mut cases = Vec::new();
    for &p in p_list {
        for &cores in cores_list {
            for &policy in &policies {
                let cfg = cell_config(p, cores, policy, seed)?;
                let n = tasks_per_p * p;
                let graph = bag(n, flops);
                let init: InitialData = vec![vec![]; p];
                let label = policy_label(policy);
                let name = format!("bag P={p} cores={cores} {label}");
                let t0 = Instant::now();
                let r = run_threaded(&cfg, graph, init, false)
                    .map_err(|e| anyhow!("{name}: {e}"))?;
                let wall_secs = t0.elapsed().as_secs_f64();
                // behavior gates (wallclock-independent): completion is
                // implied by Ok; a DLB cell that moved nothing means the
                // coordinator stopped answering the protocol
                if r.makespan <= 0.0 {
                    return Err(anyhow!("{name}: empty makespan"));
                }
                if policy.is_some() && r.counters.tasks_exported == 0 {
                    return Err(anyhow!("{name}: DLB on but no task migrated"));
                }
                if r.counters.tasks_exported != r.counters.tasks_received {
                    return Err(anyhow!(
                        "{name}: exported {} != received {}",
                        r.counters.tasks_exported,
                        r.counters.tasks_received
                    ));
                }
                let lat = LatencyReport::from_trace(&r.trace);
                cases.push(RealBenchCase {
                    name,
                    processes: p,
                    cores,
                    policy: label,
                    tasks: n,
                    makespan: r.makespan,
                    tasks_exported: r.counters.tasks_exported,
                    rounds: r.counters.rounds,
                    round_p50: lat.round.quantile(0.50),
                    round_p95: lat.round.quantile(0.95),
                    qwait_p95: lat.queue_wait.quantile(0.95),
                    wall_secs,
                });
            }
        }
    }
    Ok(RealBenchReport { seed, smoke, cases })
}

impl RealBenchReport {
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "ductr bench --real (seed {}, {}): threaded fast path\n",
            self.seed,
            if self.smoke { "smoke" } else { "full" }
        ));
        s.push_str(
            "case                              tasks   makespan   exported   rounds  round_p95  qwait_p95\n",
        );
        for c in &self.cases {
            s.push_str(&format!(
                "  {:<30} {:>6} {:>9.4}s {:>10} {:>8} {:>9.5}s {:>9.5}s\n",
                c.name, c.tasks, c.makespan, c.tasks_exported, c.rounds, c.round_p95, c.qwait_p95
            ));
        }
        s
    }

    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{{")?;
        writeln!(f, "  \"generated_by\": \"ductr bench --real\",")?;
        writeln!(f, "  \"placeholder\": false,")?;
        writeln!(f, "  \"seed\": {},", self.seed)?;
        writeln!(f, "  \"smoke\": {},", self.smoke)?;
        writeln!(f, "  \"cases\": [")?;
        for (i, c) in self.cases.iter().enumerate() {
            let comma = if i + 1 < self.cases.len() { "," } else { "" };
            writeln!(
                f,
                "    {{\"name\": \"{}\", \"processes\": {}, \"cores\": {}, \
                 \"policy\": \"{}\", \"tasks\": {}, \"makespan\": {}, \
                 \"tasks_exported\": {}, \"rounds\": {}, \
                 \"round_p50\": {}, \"round_p95\": {}, \"qwait_p95\": {}, \
                 \"wall_secs\": {}}}{comma}",
                c.name,
                c.processes,
                c.cores,
                c.policy,
                c.tasks,
                c.makespan,
                c.tasks_exported,
                c.rounds,
                c.round_p50,
                c.round_p95,
                c.qwait_p95,
                c.wall_secs
            )?;
        }
        writeln!(f, "  ]")?;
        writeln!(f, "}}")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One DLB-on smoke-profile cell end to end through the gates (the
    /// full matrix is exercised by the CI `bench --real --smoke` step).
    #[test]
    fn one_real_cell_passes_the_behavior_gates() {
        let cfg = cell_config(2, 2, Some(PolicyKind::RandomPairing), 1).expect("cfg");
        let graph = bag(16, 1_000_000);
        let r = run_threaded(&cfg, graph, vec![vec![]; 2], false).expect("run");
        assert!(r.makespan > 0.0);
        assert!(r.counters.tasks_exported > 0, "imbalanced bag must migrate");
        assert_eq!(r.counters.tasks_exported, r.counters.tasks_received);
    }

    #[test]
    fn report_renders_and_serializes() {
        let rep = RealBenchReport {
            seed: 1,
            smoke: true,
            cases: vec![RealBenchCase {
                name: "bag P=2 cores=2 pairing".into(),
                processes: 2,
                cores: 2,
                policy: "pairing".into(),
                tasks: 16,
                makespan: 0.01,
                tasks_exported: 5,
                rounds: 3,
                round_p50: 0.001,
                round_p95: 0.002,
                qwait_p95: 0.0005,
                wall_secs: 0.02,
            }],
        };
        assert!(rep.render().contains("bag P=2 cores=2 pairing"));
        let path = std::env::temp_dir().join("ductr_bench_real_test.json");
        rep.write_json(&path).expect("write");
        let body = std::fs::read_to_string(&path).expect("read");
        assert!(body.contains("\"generated_by\": \"ductr bench --real\""));
        assert!(body.contains("\"tasks_exported\": 5"));
        let _ = std::fs::remove_file(&path);
    }
}
