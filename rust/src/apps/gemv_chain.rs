//! GEMV-chain workload — the low computational-intensity case of §4.
//!
//! `chains_per_proc` independent chains are seeded on a *subset* of the
//! processes (the rest start idle), each chain being `chain_len` sequential
//! GEMV tasks `y_{k+1} = A_k · y_k`.  With Q ≈ 20 (paper §4), migrating one
//! GEMV costs as much as ~20 local ones: DLB should *not* pay off here
//! unless queues are much deeper than Q — the crossover the `sec4` bench
//! measures.

use std::sync::Arc;

use crate::core::graph::{GraphBuilder, TaskGraph};
use crate::core::ids::ProcessId;
use crate::core::task::TaskKind;

/// Build the workload.  Chains are placed round-robin over the first
/// `loaded_procs` processes; `block` is the GEMV matrix order.
pub fn build(
    processes: usize,
    loaded_procs: usize,
    chains_per_proc: usize,
    chain_len: usize,
    block: usize,
) -> Arc<TaskGraph> {
    assert!(loaded_procs >= 1 && loaded_procs <= processes);
    let mut gb = GraphBuilder::new();
    let total_chains = loaded_procs * chains_per_proc;
    for c in 0..total_chains {
        let home = ProcessId((c % loaded_procs) as u32);
        // matrix handle reused along the chain (v0 input) + vector handles
        let a = gb.data(home, block, block);
        let mut y = gb.data(home, block, 1);
        for _ in 0..chain_len {
            let y_next = gb.data(home, block, 1);
            gb.task(
                TaskKind::Gemv,
                vec![a, y],
                y_next,
                TaskKind::Gemv.flops_for_block(block as u64),
                None,
            );
            y = y_next;
        }
    }
    gb.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_count_and_kinds() {
        let g = build(8, 4, 3, 10, 64);
        assert_eq!(g.num_tasks(), 4 * 3 * 10);
        assert!(g.tasks.iter().all(|t| t.kind == TaskKind::Gemv));
    }

    #[test]
    fn chains_are_sequential() {
        let g = build(4, 1, 1, 5, 32);
        // single chain: tasks form a path
        for (i, t) in g.tasks.iter().enumerate() {
            if i == 0 {
                assert!(t.deps.is_empty());
            } else {
                assert_eq!(t.deps.len(), 1);
            }
        }
    }

    #[test]
    fn only_loaded_procs_have_tasks() {
        let g = build(8, 2, 2, 4, 32);
        let mut owners: Vec<u32> = g.tasks.iter().map(|t| t.placement.0).collect();
        owners.sort_unstable();
        owners.dedup();
        assert_eq!(owners, vec![0, 1]);
    }

    #[test]
    fn gemv_intensity_is_low() {
        let g = build(2, 1, 1, 1, 512);
        let t = &g.tasks[0];
        // F/D ≈ 2 ⇒ with S/R = 40, Q ≈ 20 (§4)
        let q = 40.0 / t.intensity();
        assert!((q - 20.0).abs() < 1.0, "Q = {q}");
    }
}
