//! Imbalanced bag-of-tasks: independent synthetic tasks with a skewed
//! placement — the canonical workload where DLB shines (no dependencies,
//! pure load redistribution).

use std::sync::Arc;

use crate::core::graph::{GraphBuilder, TaskGraph};
use crate::core::ids::ProcessId;
use crate::core::task::TaskKind;
use crate::util::rng::Rng;

/// Parameters for the bag generator.
#[derive(Debug, Clone, Copy)]
pub struct BagParams {
    pub tasks: usize,
    /// Flops of an average task.
    pub mean_flops: u64,
    /// Placement skew ≥ 0: 0 = uniform; larger concentrates tasks on the
    /// low-rank processes with a geometric-like profile.
    pub skew: f64,
    /// Spread of task sizes: each task's flops ~ mean · exp(±spread).
    pub size_spread: f64,
    /// Data block order (for migration-cost accounting).
    pub block: usize,
}

impl Default for BagParams {
    fn default() -> Self {
        BagParams { tasks: 256, mean_flops: 50_000_000, skew: 2.0, size_spread: 0.5, block: 128 }
    }
}

/// Build the bag over `processes` ranks.
pub fn build(processes: usize, params: BagParams, seed: u64) -> Arc<TaskGraph> {
    let mut rng = Rng::new(seed ^ 0xBA6);
    let mut gb = GraphBuilder::new();
    for _ in 0..params.tasks {
        // skewed placement: weight ∝ exp(−skew · rank / P)
        let home = if params.skew <= 0.0 {
            rng.range_usize(0, processes)
        } else {
            // inverse-CDF draw from the exponential profile
            let u = rng.next_f64();
            let lam = params.skew;
            let x = -(1.0 - u * (1.0 - (-lam).exp())).ln() / lam; // in [0,1)
            ((x * processes as f64) as usize).min(processes - 1)
        };
        let d = gb.data(ProcessId(home as u32), params.block, params.block);
        let factor = (params.size_spread * (2.0 * rng.next_f64() - 1.0)).exp();
        let flops = ((params.mean_flops as f64) * factor) as u64;
        gb.task(TaskKind::Synthetic, vec![], d, flops.max(1), None);
    }
    gb.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bag_is_independent() {
        let g = build(4, BagParams::default(), 1);
        assert_eq!(g.num_tasks(), 256);
        assert!(g.tasks.iter().all(|t| t.deps.is_empty()));
    }

    #[test]
    fn skew_concentrates_low_ranks() {
        let p = 8;
        let g = build(p, BagParams { skew: 3.0, ..Default::default() }, 2);
        let mut counts = vec![0usize; p];
        for t in &g.tasks {
            counts[t.placement.idx()] += 1;
        }
        assert!(
            counts[0] > counts[p - 1] * 2,
            "rank 0 should be much more loaded: {counts:?}"
        );
    }

    #[test]
    fn zero_skew_roughly_uniform() {
        let p = 4;
        let g = build(p, BagParams { skew: 0.0, tasks: 4000, ..Default::default() }, 3);
        let mut counts = vec![0usize; p];
        for t in &g.tasks {
            counts[t.placement.idx()] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 150.0, "{counts:?}");
        }
    }

    #[test]
    fn sizes_spread_around_mean() {
        let g = build(2, BagParams { size_spread: 1.0, ..Default::default() }, 4);
        let flops: Vec<u64> = g.tasks.iter().map(|t| t.flops).collect();
        let min = *flops.iter().min().expect("nonempty");
        let max = *flops.iter().max().expect("nonempty");
        assert!(max > min * 2, "spread expected: {min}..{max}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = build(4, BagParams::default(), 9);
        let b = build(4, BagParams::default(), 9);
        assert_eq!(a.tasks.len(), b.tasks.len());
        for (x, y) in a.tasks.iter().zip(b.tasks.iter()) {
            assert_eq!(x.flops, y.flops);
            assert_eq!(x.placement, y.placement);
        }
    }
}
