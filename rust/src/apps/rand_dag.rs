//! Random layered DAGs: stress and property-test workloads with
//! non-trivial dependency structure and tunable parallelism.

use std::sync::Arc;

use crate::core::graph::{GraphBuilder, TaskGraph};
use crate::core::ids::ProcessId;
use crate::core::task::TaskKind;
use crate::util::rng::Rng;

/// Parameters for the layered-DAG generator.
#[derive(Debug, Clone, Copy)]
pub struct DagParams {
    pub layers: usize,
    pub width: usize,
    /// Max dependencies drawn from the previous layer (≥ 1).
    pub max_deps: usize,
    pub mean_flops: u64,
    pub block: usize,
}

impl Default for DagParams {
    fn default() -> Self {
        DagParams { layers: 10, width: 16, max_deps: 3, mean_flops: 10_000_000, block: 64 }
    }
}

/// Build a random layered DAG over `processes` ranks with uniform random
/// placement.
pub fn build(processes: usize, params: DagParams, seed: u64) -> Arc<TaskGraph> {
    assert!(params.max_deps >= 1 && params.layers >= 1 && params.width >= 1);
    let mut rng = Rng::new(seed ^ 0xDA6);
    let mut gb = GraphBuilder::new();
    let mut prev_layer: Vec<crate::core::ids::DataId> = Vec::new();
    for layer in 0..params.layers {
        let mut this_layer = Vec::with_capacity(params.width);
        for _ in 0..params.width {
            let home = ProcessId(rng.range_usize(0, processes) as u32);
            let out = gb.data(home, params.block, params.block);
            let mut args = Vec::new();
            if layer > 0 {
                let ndeps = rng.range_usize(1, params.max_deps + 1).min(prev_layer.len());
                let picks = rng.sample_distinct(prev_layer.len(), ndeps, None);
                for p in picks {
                    args.push(prev_layer[p]);
                }
            }
            let jitter = 0.5 + rng.next_f64();
            let flops = ((params.mean_flops as f64) * jitter) as u64;
            gb.task(TaskKind::Synthetic, args, out, flops.max(1), None);
            this_layer.push(out);
        }
        prev_layer = this_layer;
    }
    gb.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure_matches_params() {
        let p = DagParams { layers: 5, width: 8, max_deps: 2, ..Default::default() };
        let g = build(4, p, 1);
        assert_eq!(g.num_tasks(), 40);
        g.topo_order().expect("acyclic");
        // layer 0 has no deps, later layers have 1..=2
        for (i, t) in g.tasks.iter().enumerate() {
            if i < 8 {
                assert!(t.deps.is_empty());
            } else {
                assert!((1..=2).contains(&t.deps.len()), "task {i}: {:?}", t.deps.len());
            }
        }
    }

    #[test]
    fn placements_cover_processes() {
        let g = build(4, DagParams { layers: 20, width: 20, ..Default::default() }, 2);
        let mut seen = [false; 4];
        for t in &g.tasks {
            seen[t.placement.idx()] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn deterministic() {
        let a = build(3, DagParams::default(), 5);
        let b = build(3, DagParams::default(), 5);
        for (x, y) in a.tasks.iter().zip(b.tasks.iter()) {
            assert_eq!(x.deps, y.deps);
            assert_eq!(x.flops, y.flops);
        }
    }
}
