//! Additional workload generators beyond the Cholesky benchmark:
//!
//! - `gemv_chain` — the §4 low-intensity counterexample (Q ≈ 20);
//! - `bag` — imbalanced bag-of-tasks (the cleanest DLB win);
//! - `rand_dag` — random layered DAGs for stress/property testing.

pub mod bag;
pub mod gemv_chain;
pub mod rand_dag;
