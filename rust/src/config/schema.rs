//! Typed configuration schema with defaults, file loading, dotted-key
//! overrides (`--set dlb.strategy=smart`) and validation.
//!
//! The defaults encode the paper's §6 experimental setup: S/R = 40
//! (Rackham's machine balance), W_T = 5, δ = 10 ms, 5 tries per round.

use std::fmt;
use std::path::Path;

use super::parser::{self, Table};

/// Execution mode: discrete-event simulation vs real threads + PJRT.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Discrete-event simulation: virtual clock, cost-model durations.
    Sim,
    /// Threaded real mode: OS threads, wallclock, PJRT kernel execution.
    Real,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "sim" => Ok(Mode::Sim),
            "real" => Ok(Mode::Real),
            other => Err(ConfigError::new(format!("unknown mode: {other} (sim|real)"))),
        }
    }
}

impl fmt::Display for Mode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Mode::Sim => "sim",
            Mode::Real => "real",
        })
    }
}

/// Which workload drives the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// Right-looking block Cholesky (paper §5).
    Cholesky,
    /// Chains of GEMV tasks — §4's low-intensity counterexample.
    GemvChain,
    /// Imbalanced bag of independent synthetic tasks.
    Bag,
    /// Random layered DAG of synthetic tasks.
    RandomDag,
}

impl Workload {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "cholesky" => Ok(Workload::Cholesky),
            "gemv_chain" | "gemv" => Ok(Workload::GemvChain),
            "bag" => Ok(Workload::Bag),
            "random_dag" | "rand_dag" => Ok(Workload::RandomDag),
            other => Err(ConfigError::new(format!(
                "unknown workload: {other} (cholesky|gemv_chain|bag|random_dag)"
            ))),
        }
    }
}

impl fmt::Display for Workload {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Workload::Cholesky => "cholesky",
            Workload::GemvChain => "gemv_chain",
            Workload::Bag => "bag",
            Workload::RandomDag => "random_dag",
        })
    }
}

/// Task-export strategy (paper §3's three alternatives).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Send the excess above W_T; no extra information exchanged.
    Basic,
    /// Equalize: send w_busy − (w_busy + w_idle)/2 using the load piggybacked
    /// on the request.
    Equalizing,
    /// Export only tasks predicted to finish earlier remotely, using the
    /// performance recorder's estimates.
    Smart,
}

impl Strategy {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "basic" => Ok(Strategy::Basic),
            "equalizing" | "equal" => Ok(Strategy::Equalizing),
            "smart" => Ok(Strategy::Smart),
            other => Err(ConfigError::new(format!(
                "unknown strategy: {other} (basic|equalizing|smart)"
            ))),
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Strategy::Basic => "basic",
            Strategy::Equalizing => "equalizing",
            Strategy::Smart => "smart",
        })
    }
}

/// Horizon protocol of the sharded DES coordinator (`sim::parallel`).
/// Both modes are bit-identical to the single-threaded oracle; they differ
/// only in how many barrier windows (and worker wakeups) a run costs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowMode {
    /// Distance-aware per-shard horizons from the S×S inter-shard delay
    /// matrix, with sparse barriers (shards already at their horizon with
    /// an empty inbox are not commanded).  The default.
    Matrix,
    /// The original protocol: one global `t_next + min cross-shard delay`
    /// horizon, every shard commanded every window.  Kept as the A/B
    /// baseline for the window-count win.
    Scalar,
}

impl WindowMode {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "matrix" | "distance" => Ok(WindowMode::Matrix),
            "scalar" | "global" => Ok(WindowMode::Scalar),
            other => Err(ConfigError::new(format!(
                "unknown sim window mode: {other} (matrix|scalar)"
            ))),
        }
    }
}

impl fmt::Display for WindowMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            WindowMode::Matrix => "matrix",
            WindowMode::Scalar => "scalar",
        })
    }
}

/// Which distributed balancer drives migration (the policy subsystem —
/// `dlb::policy`).  The paper's protocol is `RandomPairing`; the other two
/// are the strongest competitors from the literature, runnable in the same
/// simulator and threaded runtime for head-to-head comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// The paper's randomized idle–busy pairing (§3).
    RandomPairing,
    /// Receiver-initiated work stealing: idle processes steal from random
    /// victims with bounded retries (John et al. 2022).
    WorkStealing,
    /// Locality-aware stealing: steal inside the cluster node (the nearest
    /// topology tier) first, escalate to distance-weighted remote victims
    /// only after `dlb.local_tries` consecutive local failures.
    Hierarchical,
    /// First-order neighborhood diffusion over the network topology
    /// (Demirel & Sbalzarini 2013).
    Diffusion,
    /// Second-order (SOS) diffusion: adds a momentum term carrying the
    /// previous round's flows, with β derived from the topology's spectral
    /// radius (Demirel & Sbalzarini 2013, §second-order schemes).
    /// Converges in strictly fewer rounds than first-order diffusion on
    /// poorly-conditioned shapes (rings, large tori).
    SosDiffusion,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "pairing" | "random_pairing" => Ok(PolicyKind::RandomPairing),
            "stealing" | "work_stealing" => Ok(PolicyKind::WorkStealing),
            "hierarchical" | "hier" => Ok(PolicyKind::Hierarchical),
            "diffusion" => Ok(PolicyKind::Diffusion),
            "sos-diffusion" | "sos_diffusion" | "sos" => Ok(PolicyKind::SosDiffusion),
            other => Err(ConfigError::new(format!(
                "unknown policy: {other} (pairing|stealing|hierarchical|diffusion|sos-diffusion)"
            ))),
        }
    }

    pub const ALL: [PolicyKind; 5] = [
        PolicyKind::RandomPairing,
        PolicyKind::WorkStealing,
        PolicyKind::Hierarchical,
        PolicyKind::Diffusion,
        PolicyKind::SosDiffusion,
    ];
}

impl fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PolicyKind::RandomPairing => "pairing",
            PolicyKind::WorkStealing => "stealing",
            PolicyKind::Hierarchical => "hierarchical",
            PolicyKind::Diffusion => "diffusion",
            PolicyKind::SosDiffusion => "sos-diffusion",
        })
    }
}

/// Interconnect shape selector; realized into `net::Topology` by
/// [`Config::build_topology`].
///
/// The first four shapes answer distances in closed form at any scale; the
/// graph-backed shapes (`dragonfly:a,p,h`, `fattree:k`, `randreg:d`,
/// `graph`) materialize a `net::GraphTopo` with a precomputed all-pairs
/// distance table, built once per run.  `Graph` itself carries no payload —
/// the edge source lives in `Config::graph_edges` / `Config::graph_file`,
/// so this selector stays `Copy` (experiment grids iterate arrays of it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    /// Uniform single-hop (the paper's implicit model).
    Flat,
    /// Bidirectional ring over all processes.
    Ring,
    /// 2D torus shaped by the effective process grid.
    Torus,
    /// Two-level cluster: `network.cluster_nodes` groups with a per-hop
    /// inter-node penalty.
    Cluster,
    /// Dragonfly: `a·h + 1` groups of `a` routers (intra-group cliques,
    /// one global link per group pair), `p` ranks per router.
    Dragonfly { a: usize, p: usize, h: usize },
    /// Two-level fat tree with `k` leaf switches and `k/2` ranks each —
    /// any two ranks are at most two hops apart.
    FatTree { k: usize },
    /// Random `d`-regular graph over all processes, seeded by `run.seed`.
    RandReg { d: usize },
    /// Explicit edge list from `network.graph_edges` (inline) or
    /// `network.graph_file` (path) — `--topology graph:FILE` sets both.
    Graph,
}

impl TopologyKind {
    /// Parse a topology selector.  Graph-backed shapes take inline
    /// parameters (`dragonfly:2,4,1`, `fattree:4`, `randreg:3`); the bare
    /// `graph` form expects its edges from the config fields, which
    /// `Config::set_topology_str` fills for the `graph:FILE` spelling.
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        let bad_num =
            |spec: &str| ConfigError::new(format!("bad topology parameter in: {spec}"));
        if let Some(args) = s.strip_prefix("dragonfly:") {
            let parts: Vec<&str> = args.split(',').collect();
            if parts.len() != 3 {
                return Err(ConfigError::new(format!(
                    "dragonfly needs three parameters a,p,h — got: {s}"
                )));
            }
            let a = parts[0].trim().parse().map_err(|_| bad_num(s))?;
            let p = parts[1].trim().parse().map_err(|_| bad_num(s))?;
            let h = parts[2].trim().parse().map_err(|_| bad_num(s))?;
            return Ok(TopologyKind::Dragonfly { a, p, h });
        }
        if let Some(arg) = s.strip_prefix("fattree:") {
            let k = arg.trim().parse().map_err(|_| bad_num(s))?;
            return Ok(TopologyKind::FatTree { k });
        }
        if let Some(arg) = s.strip_prefix("randreg:") {
            let d = arg.trim().parse().map_err(|_| bad_num(s))?;
            return Ok(TopologyKind::RandReg { d });
        }
        match s {
            "flat" => Ok(TopologyKind::Flat),
            "ring" => Ok(TopologyKind::Ring),
            "torus" => Ok(TopologyKind::Torus),
            "cluster" => Ok(TopologyKind::Cluster),
            "graph" => Ok(TopologyKind::Graph),
            other => Err(ConfigError::new(format!(
                "unknown topology: {other} \
                 (flat|ring|torus|cluster|dragonfly:a,p,h|fattree:k|randreg:d|graph[:FILE])"
            ))),
        }
    }
}

impl fmt::Display for TopologyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TopologyKind::Flat => f.write_str("flat"),
            TopologyKind::Ring => f.write_str("ring"),
            TopologyKind::Torus => f.write_str("torus"),
            TopologyKind::Cluster => f.write_str("cluster"),
            TopologyKind::Dragonfly { a, p, h } => write!(f, "dragonfly:{a},{p},{h}"),
            TopologyKind::FatTree { k } => write!(f, "fattree:{k}"),
            TopologyKind::RandReg { d } => write!(f, "randreg:{d}"),
            TopologyKind::Graph => f.write_str("graph"),
        }
    }
}

/// Process grid (pr × pc) for the block-cyclic distribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Grid {
    pub rows: usize,
    pub cols: usize,
}

impl Grid {
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(rows > 0 && cols > 0);
        Grid { rows, cols }
    }

    pub fn size(&self) -> usize {
        self.rows * self.cols
    }

    /// Parse `"2x5"` / `"11x1"`.
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        let (r, c) = s
            .split_once(['x', 'X'])
            .ok_or_else(|| ConfigError::new(format!("grid must look like 2x5, got {s}")))?;
        let rows: usize = r.trim().parse().map_err(|_| ConfigError::new(format!("bad grid rows: {r}")))?;
        let cols: usize = c.trim().parse().map_err(|_| ConfigError::new(format!("bad grid cols: {c}")))?;
        if rows == 0 || cols == 0 {
            return Err(ConfigError::new("grid dims must be positive"));
        }
        Ok(Grid { rows, cols })
    }

    /// The most-square factorization of `p` (used when no grid is given;
    /// for prime p this degenerates to 1×p — the paper's imbalanced case).
    pub fn squarest(p: usize) -> Grid {
        assert!(p > 0);
        let mut best = (1, p);
        let mut r = 1;
        while r * r <= p {
            if p % r == 0 {
                best = (r, p / r);
            }
            r += 1;
        }
        Grid { rows: best.0, cols: best.1 }
    }
}

impl fmt::Display for Grid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.rows, self.cols)
    }
}

#[derive(Debug)]
pub struct ConfigError {
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.msg)
    }
}

impl std::error::Error for ConfigError {}

impl ConfigError {
    pub fn new(msg: impl Into<String>) -> Self {
        ConfigError { msg: msg.into() }
    }
}

impl From<parser::ParseError> for ConfigError {
    fn from(e: parser::ParseError) -> Self {
        ConfigError::new(e.to_string())
    }
}

/// Full run configuration.  See `Config::default()` for the paper-aligned
/// defaults and `docs` in README for per-field meaning.
#[derive(Debug, Clone)]
pub struct Config {
    // [run]
    pub mode: Mode,
    pub workload: Workload,
    pub seed: u64,
    pub processes: usize,
    pub grid: Option<Grid>,
    pub cores_per_process: usize,

    // [cholesky]
    pub nb: usize,
    pub block: usize,

    // [gemv] / synthetic workloads
    pub chain_len: usize,
    pub chains_per_proc: usize,
    pub bag_tasks: usize,
    pub bag_skew: f64,

    // [dlb]
    pub dlb_enabled: bool,
    /// Which balancer runs (pairing | stealing | diffusion).
    pub policy: PolicyKind,
    pub strategy: Strategy,
    /// Work stealing: steal half the victim's excess (true) or one task.
    pub steal_half: bool,
    pub wt: usize,
    /// Hysteresis gap (paper §3's suggested alternative): processes with
    /// W_T < w ≤ W_T + gap are in a middle zone — neither busy nor idle —
    /// and do not participate in pairing. 0 = the paper's base model.
    pub wt_gap: usize,
    pub delta: f64,
    pub tries: usize,
    pub confirm_timeout: f64,
    /// Hierarchical stealing: consecutive failed intra-node attempts before
    /// a hunt escalates to remote nodes.
    pub local_tries: usize,
    /// Reactive δ (AIMD): shrink δ multiplicatively on successful transfers,
    /// grow it additively on failed rounds, bounded by [delta_min, delta_max].
    pub adaptive_delta: bool,
    pub delta_min: f64,
    pub delta_max: f64,

    // [sim]
    /// DES transport coalescing: pack every `Effect::Send` of one process
    /// step that shares (destination, computed delay) into a single
    /// delivery event.  Arrival times are unchanged (the delay already
    /// includes the size term, so only same-size messages merge) but the
    /// event count — and therefore scheduler work — drops sharply once
    /// control traffic fans out at scale.  Off by default so determinism
    /// fingerprints match historical runs; flip on for the A/B columns of
    /// `ductr bench`.
    pub coalesce: bool,
    /// Shard the DES across this many worker threads (conservative
    /// time-windowed synchronization, `sim::parallel`).  1 = the
    /// single-threaded oracle engine.  Results are bit-identical either
    /// way; N > 1 buys wall-clock speed at large P.  Requires
    /// `exec_jitter = 0` (jitter draws from one engine-global RNG stream
    /// in dispatch order) and `net_latency > 0` (the lookahead window is
    /// derived from the cross-shard latency floor).
    pub sim_threads: usize,
    /// Coordinator horizon protocol under `sim_threads > 1`: distance-aware
    /// per-shard horizons with sparse barriers (`matrix`, the default) or
    /// the original global scalar-lookahead barrier (`scalar`).  Bit-wise
    /// irrelevant to results; only window counts differ.
    pub sim_window: WindowMode,

    // [cost]  (paper §4: S flops/s, R doubles/s; Rackham S/R ≈ 40)
    pub flops_per_sec: f64,
    pub doubles_per_sec: f64,
    pub exec_jitter: f64,
    pub task_overhead: f64,

    // [network]
    pub net_latency: f64,
    pub control_doubles: u64,
    /// Interconnect shape (flat reproduces the paper's uniform network).
    pub topology: TopologyKind,
    /// Cluster topology: number of nodes (0 = derive from the squarest
    /// factorization of `processes`).
    pub cluster_nodes: usize,
    /// Cluster topology: hops charged for an inter-node message.
    pub inter_node_hops: u64,
    /// Inline undirected edge list for `topology = graph`: whitespace- or
    /// comma-separated `u-v` tokens (e.g. `"0-1 1-2 2-0"`).  Takes
    /// precedence over `graph_file` when both are set.
    pub graph_edges: String,
    /// Path to an edge-list file for `topology = graph` (same token
    /// format); `--topology graph:FILE` sets this.
    pub graph_file: String,

    // [artifacts]
    pub artifacts_dir: String,

    // [trace]
    /// Arm the flight recorder: typed span/instant events per process
    /// (round lifecycle, task lifecycle, message flights) feeding the
    /// latency histograms and the Chrome/Perfetto exporter.  Off by
    /// default — the recorder is provably fingerprint-neutral, but off
    /// keeps the hot paths free of event appends.
    pub trace_enabled: bool,
    /// Chrome trace-event JSON output path ("" = don't write a file).
    /// Setting it via `--trace-out` implies `trace_enabled`.
    pub trace_out: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            mode: Mode::Sim,
            workload: Workload::Cholesky,
            seed: 1,
            processes: 10,
            grid: None,
            cores_per_process: 1,
            nb: 12,
            block: 64,
            chain_len: 32,
            chains_per_proc: 4,
            bag_tasks: 256,
            bag_skew: 2.0,
            dlb_enabled: true,
            policy: PolicyKind::RandomPairing,
            strategy: Strategy::Basic,
            steal_half: true,
            wt: 5,
            wt_gap: 0,
            delta: 0.010,
            tries: 5,
            confirm_timeout: 0.050,
            local_tries: 3,
            adaptive_delta: false,
            delta_min: 0.001,
            delta_max: 0.050,
            coalesce: false,
            sim_threads: 1,
            sim_window: WindowMode::Matrix,
            flops_per_sec: 8.8e9,
            doubles_per_sec: 2.2e8, // S/R = 40, the paper's machine balance
            exec_jitter: 0.0,
            task_overhead: 5.0e-6,
            net_latency: 2.0e-6,
            control_doubles: 8,
            topology: TopologyKind::Flat,
            cluster_nodes: 0,
            inter_node_hops: 4,
            graph_edges: String::new(),
            graph_file: String::new(),
            artifacts_dir: "artifacts".to_string(),
            trace_enabled: false,
            trace_out: String::new(),
        }
    }
}

fn get_usize(t: &Table, sec: &str, key: &str, into: &mut usize) -> Result<(), ConfigError> {
    if let Some(v) = t.get(sec).and_then(|s| s.get(key)) {
        let i = v
            .as_i64()
            .ok_or_else(|| ConfigError::new(format!("[{sec}] {key}: expected integer, got {v}")))?;
        if i < 0 {
            return Err(ConfigError::new(format!("[{sec}] {key}: must be ≥ 0")));
        }
        *into = i as usize;
    }
    Ok(())
}

fn get_u64(t: &Table, sec: &str, key: &str, into: &mut u64) -> Result<(), ConfigError> {
    if let Some(v) = t.get(sec).and_then(|s| s.get(key)) {
        let i = v
            .as_i64()
            .ok_or_else(|| ConfigError::new(format!("[{sec}] {key}: expected integer, got {v}")))?;
        *into = i as u64;
    }
    Ok(())
}

fn get_f64(t: &Table, sec: &str, key: &str, into: &mut f64) -> Result<(), ConfigError> {
    if let Some(v) = t.get(sec).and_then(|s| s.get(key)) {
        *into = v
            .as_f64()
            .ok_or_else(|| ConfigError::new(format!("[{sec}] {key}: expected number, got {v}")))?;
    }
    Ok(())
}

fn get_bool(t: &Table, sec: &str, key: &str, into: &mut bool) -> Result<(), ConfigError> {
    if let Some(v) = t.get(sec).and_then(|s| s.get(key)) {
        *into = v
            .as_bool()
            .ok_or_else(|| ConfigError::new(format!("[{sec}] {key}: expected bool, got {v}")))?;
    }
    Ok(())
}

fn get_string(t: &Table, sec: &str, key: &str, into: &mut String) -> Result<(), ConfigError> {
    if let Some(v) = t.get(sec).and_then(|s| s.get(key)) {
        *into = v
            .as_str()
            .ok_or_else(|| ConfigError::new(format!("[{sec}] {key}: expected string, got {v}")))?
            .to_string();
    }
    Ok(())
}

impl Config {
    /// Load from a TOML-subset file over the defaults.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Config, ConfigError> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            ConfigError::new(format!("cannot read {}: {e}", path.as_ref().display()))
        })?;
        Self::from_str_toml(&text)
    }

    /// Parse from a config document string over the defaults.
    pub fn from_str_toml(text: &str) -> Result<Config, ConfigError> {
        let t = parser::parse(text)?;
        let mut c = Config::default();
        c.apply_table(&t)?;
        c.validate()?;
        Ok(c)
    }

    fn apply_table(&mut self, t: &Table) -> Result<(), ConfigError> {
        let mut mode_s = self.mode.to_string();
        let mut workload_s = self.workload.to_string();
        let mut strategy_s = self.strategy.to_string();
        let mut policy_s = self.policy.to_string();
        let mut topology_s = self.topology.to_string();
        let mut window_s = self.sim_window.to_string();
        let mut grid_s = String::new();

        get_string(t, "run", "mode", &mut mode_s)?;
        get_string(t, "run", "workload", &mut workload_s)?;
        get_u64(t, "run", "seed", &mut self.seed)?;
        get_usize(t, "run", "processes", &mut self.processes)?;
        get_string(t, "run", "grid", &mut grid_s)?;
        get_usize(t, "run", "cores_per_process", &mut self.cores_per_process)?;

        get_usize(t, "cholesky", "nb", &mut self.nb)?;
        get_usize(t, "cholesky", "block", &mut self.block)?;

        get_usize(t, "gemv", "chain_len", &mut self.chain_len)?;
        get_usize(t, "gemv", "chains_per_proc", &mut self.chains_per_proc)?;
        get_usize(t, "bag", "tasks", &mut self.bag_tasks)?;
        get_f64(t, "bag", "skew", &mut self.bag_skew)?;

        get_bool(t, "dlb", "enabled", &mut self.dlb_enabled)?;
        get_string(t, "dlb", "policy", &mut policy_s)?;
        get_string(t, "dlb", "strategy", &mut strategy_s)?;
        get_bool(t, "dlb", "steal_half", &mut self.steal_half)?;
        get_usize(t, "dlb", "wt", &mut self.wt)?;
        get_usize(t, "dlb", "gap", &mut self.wt_gap)?;
        get_f64(t, "dlb", "delta", &mut self.delta)?;
        get_usize(t, "dlb", "tries", &mut self.tries)?;
        get_f64(t, "dlb", "confirm_timeout", &mut self.confirm_timeout)?;
        get_usize(t, "dlb", "local_tries", &mut self.local_tries)?;
        get_bool(t, "dlb", "adaptive_delta", &mut self.adaptive_delta)?;
        get_f64(t, "dlb", "delta_min", &mut self.delta_min)?;
        get_f64(t, "dlb", "delta_max", &mut self.delta_max)?;

        get_bool(t, "sim", "coalesce", &mut self.coalesce)?;
        get_usize(t, "sim", "threads", &mut self.sim_threads)?;
        get_string(t, "sim", "window", &mut window_s)?;

        get_f64(t, "cost", "flops_per_sec", &mut self.flops_per_sec)?;
        get_f64(t, "cost", "doubles_per_sec", &mut self.doubles_per_sec)?;
        get_f64(t, "cost", "exec_jitter", &mut self.exec_jitter)?;
        get_f64(t, "cost", "task_overhead", &mut self.task_overhead)?;

        get_f64(t, "network", "latency", &mut self.net_latency)?;
        get_u64(t, "network", "control_doubles", &mut self.control_doubles)?;
        get_string(t, "network", "topology", &mut topology_s)?;
        get_usize(t, "network", "cluster_nodes", &mut self.cluster_nodes)?;
        get_u64(t, "network", "inter_hops", &mut self.inter_node_hops)?;
        get_string(t, "network", "graph_edges", &mut self.graph_edges)?;
        get_string(t, "network", "graph_file", &mut self.graph_file)?;

        get_string(t, "artifacts", "dir", &mut self.artifacts_dir)?;
        get_bool(t, "trace", "enabled", &mut self.trace_enabled)?;
        get_string(t, "trace", "out", &mut self.trace_out)?;

        self.mode = Mode::parse(&mode_s)?;
        self.workload = Workload::parse(&workload_s)?;
        self.strategy = Strategy::parse(&strategy_s)?;
        self.policy = PolicyKind::parse(&policy_s)?;
        self.sim_window = WindowMode::parse(&window_s)?;
        self.set_topology_str(&topology_s)?;
        if !grid_s.is_empty() {
            self.grid = Some(Grid::parse(&grid_s)?);
        }
        Ok(())
    }

    /// Apply `section.key=value` override strings (CLI `--set`).
    pub fn apply_overrides<'a>(
        &mut self,
        overrides: impl IntoIterator<Item = &'a str>,
    ) -> Result<(), ConfigError> {
        let mut doc = String::new();
        for ov in overrides {
            let (path, val) = ov
                .split_once('=')
                .ok_or_else(|| ConfigError::new(format!("override must be sec.key=value: {ov}")))?;
            let (sec, key) = path
                .split_once('.')
                .ok_or_else(|| ConfigError::new(format!("override key must be sec.key: {path}")))?;
            doc.push_str(&format!("[{sec}]\n{key} = {val}\n"));
        }
        let t = parser::parse(&doc)?;
        self.apply_table(&t)?;
        self.validate()
    }

    /// Effective process grid: explicit, or the most-square factorization.
    pub fn effective_grid(&self) -> Grid {
        self.grid.unwrap_or_else(|| Grid::squarest(self.processes))
    }

    /// Matrix order implied by the Cholesky settings.
    pub fn matrix_n(&self) -> usize {
        self.nb * self.block
    }

    /// Interpret a topology selector string, routing the `graph:FILE`
    /// spelling into `graph_file` (the bare kinds go straight to
    /// `TopologyKind::parse`).  Shared by the config table, `--set`
    /// overrides, and `--topology` on the CLI.
    pub fn set_topology_str(&mut self, s: &str) -> Result<(), ConfigError> {
        if let Some(path) = s.strip_prefix("graph:") {
            if path.is_empty() {
                return Err(ConfigError::new("graph:FILE needs a file path"));
            }
            self.topology = TopologyKind::Graph;
            self.graph_file = path.to_string();
            return Ok(());
        }
        self.topology = TopologyKind::parse(s)?;
        Ok(())
    }

    /// Realize the configured interconnect shape over `processes` ranks.
    ///
    /// - `torus` uses the effective process grid as its dimensions;
    /// - `cluster` groups ranks into `cluster_nodes` nodes (squarest
    ///   factorization rows when 0/auto) with `inter_node_hops` per
    ///   inter-node message;
    /// - the graph-backed shapes construct a `GraphTopo` (CSR adjacency +
    ///   all-pairs distance table) — construction can fail, which is why
    ///   `Config::validate` runs this fallible path: a malformed graph is
    ///   a config error at startup, never a panic mid-run.
    pub fn try_build_topology(&self) -> Result<crate::net::topology::Topology, ConfigError> {
        use crate::net::graph;
        use crate::net::topology::Topology;
        use std::sync::Arc;
        let p = self.processes;
        let graph_err = |e: String| ConfigError::new(format!("network.topology: {e}"));
        Ok(match self.topology {
            TopologyKind::Flat => Topology::Flat,
            TopologyKind::Ring => Topology::Ring { len: p.max(1) },
            TopologyKind::Torus => {
                let g = self.effective_grid();
                Topology::Torus { rows: g.rows, cols: g.cols }
            }
            TopologyKind::Cluster => {
                let nodes = if self.cluster_nodes > 0 {
                    self.cluster_nodes
                } else {
                    Grid::squarest(p).rows
                };
                let nodes = nodes.clamp(1, p.max(1));
                Topology::Cluster {
                    nodes,
                    per_node: (p / nodes).max(1),
                    inter_hops: self.inter_node_hops.max(1) as u32,
                }
            }
            TopologyKind::Dragonfly { a, p: rp, h } => {
                Topology::Graph(Arc::new(graph::dragonfly(a, rp, h).map_err(graph_err)?))
            }
            TopologyKind::FatTree { k } => {
                Topology::Graph(Arc::new(graph::fat_tree(k).map_err(graph_err)?))
            }
            TopologyKind::RandReg { d } => Topology::Graph(Arc::new(
                graph::random_regular(p, d, self.seed).map_err(graph_err)?,
            )),
            TopologyKind::Graph => {
                let (text, origin);
                if !self.graph_edges.is_empty() {
                    text = self.graph_edges.clone();
                    origin = "network.graph_edges".to_string();
                } else if !self.graph_file.is_empty() {
                    text = std::fs::read_to_string(&self.graph_file).map_err(|e| {
                        ConfigError::new(format!(
                            "network.graph_file: cannot read {}: {e}",
                            self.graph_file
                        ))
                    })?;
                    origin = self.graph_file.clone();
                } else {
                    return Err(ConfigError::new(
                        "topology = graph needs network.graph_edges or network.graph_file",
                    ));
                }
                let (n, edges) = graph::parse_edge_list(&text).map_err(graph_err)?;
                let label = format!("graph[{origin}]");
                Topology::Graph(Arc::new(
                    graph::GraphTopo::from_edges(n, &edges, label).map_err(graph_err)?,
                ))
            }
        })
    }

    /// Infallible shorthand for callers past validation — a `Config` that
    /// passed `validate()` cannot fail here.
    pub fn build_topology(&self) -> crate::net::topology::Topology {
        self.try_build_topology().expect("validated config builds its topology")
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.processes == 0 {
            return Err(ConfigError::new("run.processes must be ≥ 1"));
        }
        if let Some(g) = self.grid {
            if g.size() != self.processes {
                return Err(ConfigError::new(format!(
                    "grid {} has {} slots but run.processes = {}",
                    g,
                    g.size(),
                    self.processes
                )));
            }
        }
        if self.cores_per_process == 0 {
            return Err(ConfigError::new("run.cores_per_process must be ≥ 1"));
        }
        if self.nb == 0 || self.block == 0 {
            return Err(ConfigError::new("cholesky.nb and cholesky.block must be ≥ 1"));
        }
        if self.tries == 0 {
            return Err(ConfigError::new("dlb.tries must be ≥ 1"));
        }
        if self.delta < 0.0 || self.confirm_timeout <= 0.0 {
            return Err(ConfigError::new("dlb.delta must be ≥ 0, confirm_timeout > 0"));
        }
        if self.flops_per_sec <= 0.0 || self.doubles_per_sec <= 0.0 {
            return Err(ConfigError::new("cost rates must be positive"));
        }
        if !(0.0..=1.0).contains(&self.exec_jitter) {
            return Err(ConfigError::new("cost.exec_jitter must be in [0, 1]"));
        }
        if self.net_latency < 0.0 {
            return Err(ConfigError::new("network.latency must be ≥ 0"));
        }
        if self.topology == TopologyKind::Cluster
            && self.cluster_nodes > 0
            && self.processes % self.cluster_nodes != 0
        {
            return Err(ConfigError::new(format!(
                "network.cluster_nodes = {} does not divide run.processes = {}",
                self.cluster_nodes, self.processes
            )));
        }
        if self.inter_node_hops == 0 {
            return Err(ConfigError::new("network.inter_hops must be ≥ 1"));
        }
        if self.local_tries == 0 {
            return Err(ConfigError::new("dlb.local_tries must be ≥ 1"));
        }
        if self.delta_min <= 0.0 || self.delta_max < self.delta_min {
            return Err(ConfigError::new("dlb.delta_min must be > 0 and ≤ dlb.delta_max"));
        }
        if self.sim_threads == 0 {
            return Err(ConfigError::new("sim.threads must be ≥ 1"));
        }
        if self.sim_threads > self.processes {
            return Err(ConfigError::new(format!(
                "sim.threads = {} exceeds run.processes = {} — a shard needs at least one rank",
                self.sim_threads, self.processes
            )));
        }
        if self.sim_threads > 1 {
            // The sharded engine's preconditions: jitter draws from one
            // engine-global RNG stream in dispatch order (unshardable), and
            // a zero latency floor would make the conservative lookahead
            // window zero-width.
            if self.exec_jitter > 0.0 {
                return Err(ConfigError::new(
                    "sim.threads > 1 requires cost.exec_jitter = 0 (jitter is \
                     drawn from a global engine RNG in dispatch order)",
                ));
            }
            if self.net_latency <= 0.0 {
                return Err(ConfigError::new(
                    "sim.threads > 1 requires network.latency > 0 (the lookahead \
                     window is the cross-shard latency floor)",
                ));
            }
        }
        // Topology-distance contract: the realized shape must give every
        // rank its own slot; `hops` stays total regardless, but an
        // under-sized shape would strand the excess ranks (empty neighbor
        // sets — their load could never leave under diffusion).  Graph
        // shapes additionally reject here when the node count differs from
        // run.processes in either direction — there is no silent modulo
        // folding of out-of-shape ranks onto graph nodes.
        let topo = self.try_build_topology()?;
        if !topo.covers(self.processes) {
            return Err(ConfigError::new(format!(
                "topology {} does not cover run.processes = {}",
                topo.label(),
                self.processes
            )));
        }
        Ok(())
    }

    /// The machine-balance ratio S/R the §4 analysis is parameterized by.
    pub fn s_over_r(&self) -> f64 {
        self.flops_per_sec / self.doubles_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_setup() {
        let c = Config::default();
        assert_eq!(c.wt, 5);
        assert!((c.delta - 0.010).abs() < 1e-12);
        assert_eq!(c.tries, 5);
        assert!((c.s_over_r() - 40.0).abs() < 1e-9);
        assert_eq!(c.nb, 12);
        c.validate().expect("defaults valid");
    }

    #[test]
    fn file_roundtrip() {
        let doc = r#"
            [run]
            mode = "real"
            workload = "gemv_chain"
            processes = 15
            grid = "3x5"
            seed = 7
            [dlb]
            strategy = "smart"
            wt = 9
            delta = 0.002
            [cost]
            flops_per_sec = 1.0e9
            doubles_per_sec = 2.5e7
        "#;
        let c = Config::from_str_toml(doc).expect("parse");
        assert_eq!(c.mode, Mode::Real);
        assert_eq!(c.workload, Workload::GemvChain);
        assert_eq!(c.processes, 15);
        assert_eq!(c.grid, Some(Grid::new(3, 5)));
        assert_eq!(c.strategy, Strategy::Smart);
        assert_eq!(c.wt, 9);
        assert!((c.s_over_r() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn overrides_apply() {
        let mut c = Config::default();
        c.apply_overrides(["dlb.strategy=\"equalizing\"", "run.processes=11", "run.grid=\"11x1\""])
            .expect("overrides");
        assert_eq!(c.strategy, Strategy::Equalizing);
        assert_eq!(c.processes, 11);
        assert_eq!(c.grid, Some(Grid::new(11, 1)));
    }

    #[test]
    fn bad_override_reports() {
        let mut c = Config::default();
        assert!(c.apply_overrides(["noequals"]).is_err());
        assert!(c.apply_overrides(["nodot=3"]).is_err());
        assert!(c.apply_overrides(["run.mode=\"warp\""]).is_err());
    }

    #[test]
    fn grid_parse_and_squarest() {
        assert_eq!(Grid::parse("2x5").expect("ok"), Grid::new(2, 5));
        assert_eq!(Grid::parse("11X1").expect("ok"), Grid::new(11, 1));
        assert!(Grid::parse("5").is_err());
        assert!(Grid::parse("0x5").is_err());
        assert_eq!(Grid::squarest(12), Grid::new(3, 4));
        assert_eq!(Grid::squarest(11), Grid::new(1, 11)); // prime → paper's worst case
        assert_eq!(Grid::squarest(16), Grid::new(4, 4));
    }

    #[test]
    fn grid_size_mismatch_rejected() {
        let doc = "[run]\nprocesses = 10\ngrid = \"3x5\"";
        assert!(Config::from_str_toml(doc).is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = Config::default();
        c.processes = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.exec_jitter = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.doubles_per_sec = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn matrix_n_derived() {
        let c = Config::default();
        assert_eq!(c.matrix_n(), 12 * 64);
    }

    #[test]
    fn policy_and_topology_parse_and_default() {
        let c = Config::default();
        assert_eq!(c.policy, PolicyKind::RandomPairing);
        assert_eq!(c.topology, TopologyKind::Flat);
        let doc = r#"
            [dlb]
            policy = "stealing"
            steal_half = false
            [network]
            topology = "torus"
        "#;
        let c = Config::from_str_toml(doc).expect("parse");
        assert_eq!(c.policy, PolicyKind::WorkStealing);
        assert!(!c.steal_half);
        assert_eq!(c.topology, TopologyKind::Torus);
        assert!(PolicyKind::parse("nope").is_err());
        assert!(TopologyKind::parse("mesh").is_err());
    }

    #[test]
    fn build_topology_shapes() {
        use crate::net::topology::Topology;
        let mut c = Config::default();
        c.processes = 12;
        c.grid = Some(Grid::new(3, 4));
        c.topology = TopologyKind::Torus;
        assert_eq!(c.build_topology(), Topology::Torus { rows: 3, cols: 4 });
        c.topology = TopologyKind::Ring;
        assert_eq!(c.build_topology(), Topology::Ring { len: 12 });
        c.topology = TopologyKind::Cluster;
        c.cluster_nodes = 3;
        assert_eq!(
            c.build_topology(),
            Topology::Cluster { nodes: 3, per_node: 4, inter_hops: 4 }
        );
        // auto node count: squarest(12) = 3x4 → 3 nodes
        c.cluster_nodes = 0;
        assert_eq!(
            c.build_topology(),
            Topology::Cluster { nodes: 3, per_node: 4, inter_hops: 4 }
        );
    }

    #[test]
    fn locality_knobs_parse_and_validate() {
        let c = Config::default();
        assert_eq!(c.local_tries, 3);
        assert!(!c.adaptive_delta);
        assert!(c.delta_min > 0.0 && c.delta_min <= c.delta_max);

        let doc = r#"
            [dlb]
            policy = "hierarchical"
            local_tries = 2
            adaptive_delta = true
            delta_min = 0.0005
            delta_max = 0.02
        "#;
        let c = Config::from_str_toml(doc).expect("parse");
        assert_eq!(c.policy, PolicyKind::Hierarchical);
        assert_eq!(c.local_tries, 2);
        assert!(c.adaptive_delta);
        assert!((c.delta_min - 0.0005).abs() < 1e-12);
        assert!((c.delta_max - 0.02).abs() < 1e-12);
        assert_eq!(PolicyKind::parse("hier").expect("alias"), PolicyKind::Hierarchical);

        let mut c = Config::default();
        c.local_tries = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.delta_min = 0.04;
        c.delta_max = 0.01;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.delta_min = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn all_policies_listed_once() {
        assert_eq!(PolicyKind::ALL.len(), 5);
        for p in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(&p.to_string()).expect("roundtrip"), p);
        }
        assert_eq!(PolicyKind::parse("sos").expect("alias"), PolicyKind::SosDiffusion);
        assert_eq!(
            PolicyKind::parse("sos_diffusion").expect("alias"),
            PolicyKind::SosDiffusion
        );
    }

    #[test]
    fn graph_topology_kinds_parse_and_roundtrip() {
        let kinds = [
            TopologyKind::Dragonfly { a: 2, p: 4, h: 1 },
            TopologyKind::FatTree { k: 4 },
            TopologyKind::RandReg { d: 3 },
            TopologyKind::Graph,
        ];
        for k in kinds {
            // Display → parse must round-trip: `apply_table` re-parses the
            // Display string when a file does not override it.
            assert_eq!(TopologyKind::parse(&k.to_string()).expect("roundtrip"), k);
        }
        assert!(TopologyKind::parse("dragonfly:2,4").is_err(), "needs 3 params");
        assert!(TopologyKind::parse("fattree:four").is_err());
        assert!(TopologyKind::parse("randreg:").is_err());
    }

    #[test]
    fn graph_file_spelling_sets_path() {
        let mut c = Config::default();
        c.set_topology_str("graph:/tmp/edges.txt").expect("parse");
        assert_eq!(c.topology, TopologyKind::Graph);
        assert_eq!(c.graph_file, "/tmp/edges.txt");
        assert!(c.set_topology_str("graph:").is_err(), "empty path rejected");
        assert!(c.set_topology_str("mesh").is_err());
    }

    #[test]
    fn inline_graph_edges_build_and_validate() {
        let doc = r#"
            [run]
            processes = 4
            [network]
            topology = "graph"
            graph_edges = "0-1 1-2 2-3 3-0"
        "#;
        let c = Config::from_str_toml(doc).expect("4-cycle parses");
        assert_eq!(c.topology, TopologyKind::Graph);
        let t = c.build_topology();
        use crate::core::ids::ProcessId;
        assert_eq!(t.hops(ProcessId(0), ProcessId(2)), 2);
        assert!(t.covers(4));
    }

    #[test]
    fn bad_graphs_fail_in_validate_not_mid_run() {
        // disconnected
        let doc = "[run]\nprocesses = 4\n[network]\ntopology = \"graph\"\ngraph_edges = \"0-1 2-3\"";
        assert!(Config::from_str_toml(doc).is_err());
        // node count != processes: no silent modulo (satellite regression)
        let doc = "[run]\nprocesses = 5\n[network]\ntopology = \"graph\"\ngraph_edges = \"0-1 1-2 2-3 3-0\"";
        assert!(Config::from_str_toml(doc).is_err());
        // graph topology with no edge source
        let doc = "[run]\nprocesses = 4\n[network]\ntopology = \"graph\"";
        assert!(Config::from_str_toml(doc).is_err());
        // missing file surfaces as a config error
        let mut c = Config::default();
        c.processes = 4;
        c.topology = TopologyKind::Graph;
        c.graph_file = "/nonexistent/edges.txt".to_string();
        assert!(c.validate().is_err());
        // generator whose node count misses run.processes is caught too
        let mut c = Config::default();
        c.processes = 10;
        c.topology = TopologyKind::FatTree { k: 4 }; // 8 ranks ≠ 10
        assert!(c.validate().is_err());
        // randreg needs n·d even
        let mut c = Config::default();
        c.processes = 5;
        c.topology = TopologyKind::RandReg { d: 3 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn generator_topologies_cover_their_exact_rank_count() {
        let mut c = Config::default();
        c.processes = 12; // dragonfly a=2,p=2,h=1 → (2·1+1)·2·2 = 12
        c.set_topology_str("dragonfly:2,2,1").expect("parse");
        c.validate().expect("exact cover");
        assert!(c.build_topology().covers(12));

        let mut c = Config::default();
        c.processes = 8; // fattree k=4 → k²/2 = 8
        c.set_topology_str("fattree:4").expect("parse");
        c.validate().expect("exact cover");

        let mut c = Config::default();
        c.processes = 10;
        c.set_topology_str("randreg:3").expect("parse");
        c.validate().expect("10·3 even, connected w.h.p. with retries");
        // same seed → same graph: build twice and compare
        assert_eq!(c.build_topology(), c.build_topology());
    }

    #[test]
    fn coalesce_parses_and_defaults_off() {
        let c = Config::default();
        assert!(!c.coalesce, "historical fingerprints require coalesce off by default");
        let c = Config::from_str_toml("[sim]\ncoalesce = true").expect("parse");
        assert!(c.coalesce);
        let mut c = Config::default();
        c.apply_overrides(["sim.coalesce=true"]).expect("override");
        assert!(c.coalesce);
    }

    #[test]
    fn sim_threads_parses_and_defaults_to_one() {
        let c = Config::default();
        assert_eq!(c.sim_threads, 1, "single-threaded oracle by default");
        let c = Config::from_str_toml("[sim]\nthreads = 4").expect("parse");
        assert_eq!(c.sim_threads, 4);
        let mut c = Config::default();
        c.apply_overrides(["sim.threads=2"]).expect("override");
        assert_eq!(c.sim_threads, 2);
        // non-numeric values die in the parser, not silently
        assert!(Config::from_str_toml("[sim]\nthreads = \"two\"").is_err());
    }

    #[test]
    fn sim_threads_validation_guards() {
        let mut c = Config::default();
        c.sim_threads = 0;
        assert!(c.validate().is_err(), "0 threads is a typo, not a request");
        let mut c = Config::default();
        c.processes = 4;
        c.sim_threads = 5;
        assert!(c.validate().is_err(), "more shards than ranks");
        let mut c = Config::default();
        c.sim_threads = 2;
        c.exec_jitter = 0.1;
        assert!(c.validate().is_err(), "jitter is unshardable");
        let mut c = Config::default();
        c.sim_threads = 2;
        c.net_latency = 0.0;
        assert!(c.validate().is_err(), "zero latency → zero lookahead");
        let mut c = Config::default();
        c.sim_threads = 2;
        c.validate().expect("2 threads over 10 ranks is fine");
    }

    #[test]
    fn sim_window_parses_and_defaults_to_matrix() {
        let c = Config::default();
        assert_eq!(c.sim_window, WindowMode::Matrix, "distance-aware horizons by default");
        let c = Config::from_str_toml("[sim]\nwindow = \"scalar\"").expect("parse");
        assert_eq!(c.sim_window, WindowMode::Scalar);
        let c = Config::from_str_toml("[sim]\nwindow = \"distance\"").expect("alias");
        assert_eq!(c.sim_window, WindowMode::Matrix);
        let mut c = Config::default();
        c.apply_overrides(["sim.window=\"global\""]).expect("override alias");
        assert_eq!(c.sim_window, WindowMode::Scalar);
        assert!(Config::from_str_toml("[sim]\nwindow = \"sideways\"").is_err());
    }

    #[test]
    fn cluster_nodes_must_divide_processes() {
        let mut c = Config::default();
        c.processes = 10;
        c.topology = TopologyKind::Cluster;
        c.cluster_nodes = 3;
        assert!(c.validate().is_err());
        c.cluster_nodes = 5;
        c.validate().expect("5 divides 10");
    }
}
