//! TOML-subset parser (serde/toml are unavailable offline).
//!
//! Supported grammar — the subset the ductr config schema needs:
//!
//! ```text
//! # comment
//! [section]
//! key = 42            # integer
//! key = 4.2e-3        # float
//! key = true          # bool
//! key = "text"        # string (no escapes beyond \" \\ \n \t)
//! key = [1, 2, 3]     # homogeneous scalar array
//! ```
//!
//! Keys before any `[section]` land in the `""` root section.  Duplicate
//! keys: last one wins (documented, tested).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// `section → key → value`.
pub type Table = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse error with 1-based line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError { line, msg: msg.into() }
}

/// Strip a trailing comment that is not inside a string literal.
fn strip_comment(s: &str) -> &str {
    let mut in_str = false;
    let mut prev_escape = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' if !prev_escape => in_str = !in_str,
            '#' if !in_str => return &s[..i],
            _ => {}
        }
        prev_escape = c == '\\' && !prev_escape;
    }
    s
}

fn parse_scalar(tok: &str, line: usize) -> Result<Value, ParseError> {
    let t = tok.trim();
    if t.is_empty() {
        return Err(err(line, "empty value"));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(stripped) = t.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| err(line, format!("unterminated string: {t}")))?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(err(line, format!("bad escape \\{other:?}"))),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    // ints first (no '.', 'e'); allow underscores
    let cleaned = t.replace('_', "");
    if !cleaned.contains('.') && !cleaned.contains(['e', 'E']) {
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(line, format!("cannot parse value: {t}")))
}

fn parse_value(tok: &str, line: usize) -> Result<Value, ParseError> {
    let t = tok.trim();
    if let Some(inner) = t.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(line, "unterminated array"))?;
        let mut items = Vec::new();
        // split on commas outside strings
        let mut depth_str = false;
        let mut start = 0usize;
        let bytes = inner.as_bytes();
        for i in 0..bytes.len() {
            match bytes[i] {
                b'"' => depth_str = !depth_str,
                b',' if !depth_str => {
                    let piece = &inner[start..i];
                    if !piece.trim().is_empty() {
                        items.push(parse_scalar(piece, line)?);
                    }
                    start = i + 1;
                }
                _ => {}
            }
        }
        let last = &inner[start..];
        if !last.trim().is_empty() {
            items.push(parse_scalar(last, line)?);
        }
        return Ok(Value::Array(items));
    }
    parse_scalar(t, line)
}

/// Parse a full document.
pub fn parse(text: &str) -> Result<Table, ParseError> {
    let mut table: Table = BTreeMap::new();
    let mut section = String::new();
    table.entry(section.clone()).or_default();
    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            section = name.to_string();
            table.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| err(lineno, format!("expected key = value, got: {line}")))?;
        let key = k.trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(v, lineno)?;
        table
            .get_mut(&section)
            .expect("section inserted above")
            .insert(key.to_string(), value);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_kinds() {
        let t = parse(
            r#"
            # top comment
            root_key = 1
            [run]
            mode = "sim"       # trailing comment
            seed = 42
            frac = 0.5
            rate = 2.2e8
            on = true
            off = false
            sizes = [32, 64, 128]
            names = ["a", "b"]
            big = 1_000_000
            "#,
        )
        .expect("parse ok");
        assert_eq!(t[""]["root_key"], Value::Int(1));
        assert_eq!(t["run"]["mode"], Value::Str("sim".into()));
        assert_eq!(t["run"]["seed"], Value::Int(42));
        assert_eq!(t["run"]["frac"], Value::Float(0.5));
        assert_eq!(t["run"]["rate"], Value::Float(2.2e8));
        assert_eq!(t["run"]["on"], Value::Bool(true));
        assert_eq!(t["run"]["off"], Value::Bool(false));
        assert_eq!(
            t["run"]["sizes"],
            Value::Array(vec![Value::Int(32), Value::Int(64), Value::Int(128)])
        );
        assert_eq!(t["run"]["big"], Value::Int(1_000_000));
    }

    #[test]
    fn string_with_hash_and_escapes() {
        let t = parse("s = \"a # not comment\"\ne = \"tab\\tend\\\"q\\\"\"").expect("ok");
        assert_eq!(t[""]["s"], Value::Str("a # not comment".into()));
        assert_eq!(t[""]["e"], Value::Str("tab\tend\"q\"".into()));
    }

    #[test]
    fn duplicate_key_last_wins() {
        let t = parse("k = 1\nk = 2").expect("ok");
        assert_eq!(t[""]["k"], Value::Int(2));
    }

    #[test]
    fn error_reports_line() {
        let e = parse("good = 1\nbad line without equals").expect_err("should fail");
        assert_eq!(e.line, 2);
    }

    #[test]
    fn unterminated_things_fail() {
        assert!(parse("[sec").is_err());
        assert!(parse("k = \"open").is_err());
        assert!(parse("k = [1, 2").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse(" = 3").is_err());
    }

    #[test]
    fn value_accessors() {
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::Float(0.5).as_i64(), None);
        assert_eq!(Value::Str("x".into()).as_str(), Some("x"));
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
    }

    #[test]
    fn display_roundtrip_ints() {
        let v = Value::Array(vec![Value::Int(1), Value::Str("s".into())]);
        assert_eq!(v.to_string(), "[1, \"s\"]");
    }
}
