//! Configuration subsystem: a TOML-subset parser (`parser`) and the typed
//! run configuration (`schema`) with paper-aligned defaults.

pub mod parser;
pub mod schema;

pub use parser::{parse, Table, Value};
pub use schema::{
    Config, ConfigError, Grid, Mode, PolicyKind, Strategy, TopologyKind, WindowMode, Workload,
};
