//! The discrete-event simulator: virtual clock + calendar-queue scheduler
//! driving the same `ProcessState` machines the threaded runtime uses.
//!
//! Determinism: events are ordered by (time, key) where the key is the
//! parallel-stable `emit × P + rank` of the emitting process — unique, and
//! a function of the emitter's own step sequence rather than of global
//! dispatch interleaving, so the sharded engine (`sim::parallel`) can
//! reproduce the exact same total order shard-locally.  All randomness
//! flows from the run seed through per-process RNG streams plus one engine
//! stream for execution-time jitter.  Two runs with the same seed are
//! bit-identical — which is how Fig 5's "lucky vs unlucky" pair of runs is
//! reproduced honestly (two *named* seeds).
//!
//! Scale: the scheduler is a two-level calendar queue (`sim::calendar`)
//! with O(1) amortized push/pop instead of a `BinaryHeap`'s O(log n), and
//! the transport optionally coalesces same-(destination, delay) control
//! messages of one step into single delivery events (`[sim] coalesce`) —
//! the two changes that keep per-event cost flat as P grows to 4096.

use std::sync::Arc;

use crate::config::Config;
use crate::core::data::Payload;
use crate::core::graph::TaskGraph;
use crate::core::ids::ProcessId;
use crate::core::process::{Effect, ProcessParams, ProcessState};
use crate::metrics::counters::DlbCounters;
use crate::metrics::recorder::RunTrace;
use crate::metrics::trace::RunTraces;
use crate::net::message::{Envelope, Flight};
use crate::sched::queue::ReadyTask;
use crate::util::rng::Rng;

use super::calendar::{CalendarQueue, Entry};
use super::network::NetworkModel;

/// Event payloads are kept small and flat: flights (envelope + coalesced
/// tail) live in a slab on the engine (indexed by `slot`) rather than in
/// per-event `Box`es, so pushing an event never allocates once the slab and
/// queue have warmed up.
#[derive(Debug)]
pub(crate) enum EventKind {
    Deliver { slot: u32 },
    ExecDone { proc: ProcessId, rt: ReadyTask, duration: f64 },
    /// `gen` is the process's tick generation at arm time: a popped tick
    /// dispatches only while it is still the latest armed one.
    Tick { proc: ProcessId, gen: u64 },
}

/// Outcome of a simulated run.
#[derive(Debug)]
pub struct SimResult {
    /// Time of the last task completion anywhere.
    pub makespan: f64,
    /// Virtual time at which the simulation fully drained (termination
    /// protocol included).
    pub end_time: f64,
    pub traces: RunTraces,
    /// Flight-recorder event streams (empty unless `[trace] enabled`).
    pub trace: RunTrace,
    pub counters: DlbCounters,
    pub per_process_counters: Vec<DlbCounters>,
    /// Events dispatched to a process state machine — every delivered
    /// message counts, including the coalesced tail of a flight, so the
    /// number is comparable across `coalesce` on/off (suppressed stale
    /// ticks are not counted — they do no work).
    pub events_processed: u64,
    /// Largest number of simultaneously pending events (memory high-water
    /// mark of the run — recorded for the perf trajectory in `ductr bench`).
    pub peak_pending_events: usize,
    /// Aggregate compute utilization: Σ flops / (P · S · makespan).
    pub utilization: f64,
    /// Coordinator window statistics (all-zero for the single-threaded
    /// engine, which has no windows).  Excluded from the bit-identity
    /// contract with the sharded engine — it describes the execution
    /// strategy, not the simulated system.
    pub window: WindowStats,
}

/// Barrier-protocol statistics of a sharded run (`sim::parallel`): how many
/// coordinator windows the run took and how sparse the barriers were.  The
/// measurable half of the distance-aware lookahead protocol — fewer windows
/// and more skipped commands at identical event counts is the win.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WindowStats {
    /// Coordinator barrier iterations.
    pub windows: u64,
    /// `WindowCmd`s actually dispatched to shard workers.
    pub cmds_sent: u64,
    /// Shard-window slots skipped by the sparse-barrier rule (shard already
    /// at/past its horizon with an empty inbox — cached report reused).
    pub cmds_skipped: u64,
}

impl WindowStats {
    /// Mean events dispatched per coordinator window.
    pub fn events_per_window(&self, events: u64) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            events as f64 / self.windows as f64
        }
    }
}

/// Errors a simulation can hit (budget guards — a correct run never does).
#[derive(Debug)]
pub enum SimError {
    EventBudget(u64),
    TimeBudget(f64),
    Deadlock { live: usize },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::EventBudget(n) => {
                write!(f, "event budget exceeded ({n} events) — livelock?")
            }
            SimError::TimeBudget(t) => write!(f, "virtual-time budget exceeded (t = {t})"),
            SimError::Deadlock { live } => {
                write!(f, "deadlock: {live} processes not halted but no events pending")
            }
        }
    }
}

impl std::error::Error for SimError {}

/// The simulator.
pub struct SimEngine {
    pub processes: Vec<ProcessState>,
    network: NetworkModel,
    queue: CalendarQueue<EventKind>,
    /// Flight storage for in-flight `Deliver` events (slot-indexed slab;
    /// freed slots are recycled via `env_free`).
    env_slab: Vec<Option<Flight>>,
    env_free: Vec<u32>,
    /// Pack same-(destination, delay) sends of one step into one flight.
    coalesce: bool,
    /// Per-step scratch for coalescing: (destination, delay bits, slot) of
    /// every flight opened by the step currently being applied.
    step_flights: Vec<(ProcessId, u64, u32)>,
    now: f64,
    /// Per-process emission counters backing the parallel-stable event
    /// keys: the k-th event emitted by rank r gets key `k·P + r`.  Unique
    /// across processes, and advanced only by the emitter's own steps, so
    /// the sharded engine reproduces identical keys without coordination.
    emit_seq: Vec<u64>,
    jitter: f64,
    rng: Rng,
    /// Per-process time of the next scheduled tick (push-side dedup).
    tick_at: Vec<f64>,
    /// Per-process tick generation: bumped on every arm, stamped into the
    /// `Tick` event.  A popped tick whose generation is no longer current
    /// was superseded and dies at the pop instead of firing `on_tick`
    /// spuriously — exact even when a re-arm lands on the same timestamp.
    tick_gen: Vec<u64>,
    /// Processes that have not halted — O(1) termination check per event.
    live: usize,
    /// Pending-event high-water mark.
    peak_pending: usize,
    pub max_events: u64,
    pub max_time: f64,
    /// Optional early-stop predicate (e.g. Fig 3 time-to-first-pair).
    pub stop_when: Option<Box<dyn Fn(&[ProcessState]) -> bool>>,
}

impl SimEngine {
    /// Build from a config and a task graph (uses the config's cost model,
    /// network, DLB and seed settings).
    pub fn from_config(cfg: &Config, graph: Arc<TaskGraph>) -> Self {
        let params = ProcessParams::from_config(cfg);
        let p = cfg.processes;
        let processes: Vec<ProcessState> = (0..p)
            .map(|i| {
                ProcessState::new(ProcessId(i as u32), p, Arc::clone(&graph), params.clone(), cfg.seed)
            })
            .collect();
        SimEngine {
            processes,
            network: NetworkModel::with_topology(
                cfg.net_latency,
                cfg.doubles_per_sec,
                cfg.build_topology(),
            ),
            queue: CalendarQueue::new(),
            env_slab: Vec::new(),
            env_free: Vec::new(),
            coalesce: cfg.coalesce,
            step_flights: Vec::new(),
            now: 0.0,
            emit_seq: vec![0; p],
            jitter: cfg.exec_jitter,
            rng: Rng::new(cfg.seed ^ 0xE46E_17E5_u64),
            tick_at: vec![f64::NEG_INFINITY; p],
            tick_gen: vec![0; p],
            live: p,
            peak_pending: 0,
            max_events: 500_000_000,
            max_time: f64::INFINITY,
            stop_when: None,
        }
    }

    /// Queue an event emitted by `src`.  The tiebreak key is `emit·P + rank`
    /// of the emitter — at equal timestamps, events dispatch by (emission
    /// index, source rank) rather than by global push order, which is what
    /// lets `sim::parallel` reproduce this engine's order bit for bit.
    fn push(&mut self, src: ProcessId, t: f64, kind: EventKind) {
        debug_assert!(t >= self.now, "event in the past: {t} < {}", self.now);
        let p = self.processes.len() as u64;
        let key = self.emit_seq[src.idx()] * p + src.idx() as u64;
        self.emit_seq[src.idx()] += 1;
        self.queue.push(t, key, kind);
        self.peak_pending = self.peak_pending.max(self.queue.len());
    }

    fn stash_flight(&mut self, fl: Flight) -> u32 {
        match self.env_free.pop() {
            Some(slot) => {
                debug_assert!(self.env_slab[slot as usize].is_none());
                self.env_slab[slot as usize] = Some(fl);
                slot
            }
            None => {
                self.env_slab.push(Some(fl));
                (self.env_slab.len() - 1) as u32
            }
        }
    }

    fn unstash_flight(&mut self, slot: u32) -> Flight {
        let fl = self.env_slab[slot as usize].take().expect("live flight slot");
        self.env_free.push(slot);
        fl
    }

    /// Free the slab slot of a popped-but-undispatched event (the budget
    /// error paths) so occupied slots always equal pending deliveries.
    fn discard_event(&mut self, kind: &EventKind) {
        if let EventKind::Deliver { slot } = *kind {
            let _ = self.unstash_flight(slot);
        }
    }

    /// Drain `effects` into the event queue.  The buffer is the caller's
    /// scratch space — emptied here, reused for the next step.
    ///
    /// With `coalesce` on, sends of this one step that share (destination,
    /// computed delay) are appended to the flight the first of them opened
    /// instead of getting their own `Deliver` event; the coalesced count is
    /// credited to the stepping process's counters.
    fn apply_effects(&mut self, proc: ProcessId, effects: &mut Vec<Effect>) {
        self.step_flights.clear();
        let mut coalesced: u64 = 0;
        for e in effects.drain(..) {
            match e {
                Effect::Send(env) => {
                    let delay = self.network.delay_between(env.from, env.to, env.wire_doubles);
                    if self.coalesce {
                        let key = (env.to, delay.to_bits());
                        if let Some(&(_, _, slot)) = self
                            .step_flights
                            .iter()
                            .find(|&&(to, bits, _)| to == key.0 && bits == key.1)
                        {
                            let fl = self.env_slab[slot as usize]
                                .as_mut()
                                .expect("open flight slot");
                            fl.tail.push(env.msg);
                            coalesced += 1;
                            continue;
                        }
                        let fl = Flight::sent(env, self.now);
                        let slot = self.stash_flight(fl);
                        self.step_flights.push((key.0, key.1, slot));
                        self.push(proc, self.now + delay, EventKind::Deliver { slot });
                    } else {
                        let fl = Flight::sent(env, self.now);
                        let slot = self.stash_flight(fl);
                        self.push(proc, self.now + delay, EventKind::Deliver { slot });
                    }
                }
                Effect::StartExec { task } => {
                    let node = self.processes[proc.idx()].graph.task(task.task);
                    let base = self.processes[proc.idx()].params.cost.local_time(node.flops);
                    let factor = if self.jitter > 0.0 {
                        1.0 + self.jitter * (2.0 * self.rng.next_f64() - 1.0)
                    } else {
                        1.0
                    };
                    let duration = (base * factor).max(1e-12);
                    let done = EventKind::ExecDone { proc, rt: task, duration };
                    self.push(proc, self.now + duration, done);
                }
                Effect::ScheduleTick { at } => {
                    let at = at.max(self.now);
                    // Dedup: skip if an earlier-or-equal future tick exists.
                    if self.tick_at[proc.idx()] > self.now
                        && self.tick_at[proc.idx()] <= at + 1e-12
                    {
                        continue;
                    }
                    self.tick_at[proc.idx()] = at;
                    self.tick_gen[proc.idx()] += 1;
                    let gen = self.tick_gen[proc.idx()];
                    self.push(proc, at, EventKind::Tick { proc, gen });
                }
                Effect::Halt => {
                    debug_assert!(self.live > 0, "halt underflow");
                    self.live = self.live.saturating_sub(1);
                }
            }
        }
        if coalesced > 0 {
            self.processes[proc.idx()].policy.counters_mut().messages_coalesced += coalesced;
        }
    }

    /// Run to completion; returns the aggregated result.
    pub fn run(&mut self) -> Result<SimResult, SimError> {
        // One scratch buffer for every ProcessState step of the run: the
        // state machine appends effects here, `apply_effects` drains it.
        let mut effects: Vec<Effect> = Vec::with_capacity(64);

        // boot every process at t = 0
        for i in 0..self.processes.len() {
            self.processes[i].start(0.0, &mut effects);
            self.apply_effects(ProcessId(i as u32), &mut effects);
        }

        let mut events: u64 = 0;
        // Drain to empty rather than stopping at the last Halt: events left
        // behind the final halt (in-flight deliveries, armed ticks) are
        // no-ops on halted state machines, so the observable outcome is
        // unchanged — but the exit condition no longer depends on global
        // pop order, which is the property the sharded engine
        // (`sim::parallel`) needs to reproduce this run bit for bit.
        loop {
            let Some(Entry { t, item: kind, .. }) = self.queue.pop() else { break };
            // Superseded tick: a newer arm replaced this one.  Drop it at
            // the pop — before it counts as a dispatched event — instead
            // of firing `on_tick` spuriously; this is both the perf win
            // and the bug fix (dedup used to skip only pushes, never pops).
            if let EventKind::Tick { proc, gen } = kind {
                if gen != self.tick_gen[proc.idx()] {
                    continue;
                }
            }
            self.now = t;
            if self.now > self.max_time {
                self.discard_event(&kind);
                return Err(SimError::TimeBudget(self.now));
            }
            events += 1;
            // Every coalesced message counts as a dispatched event — and
            // toward the budget — so event totals and budget enforcement
            // stay comparable across coalesce on/off.
            if let EventKind::Deliver { slot } = kind {
                let tail = self.env_slab[slot as usize]
                    .as_ref()
                    .map_or(0, |fl| fl.tail.len() as u64);
                events += tail;
            }
            if events > self.max_events {
                self.discard_event(&kind);
                return Err(SimError::EventBudget(events));
            }
            match kind {
                EventKind::Deliver { slot } => {
                    let fl = self.unstash_flight(slot);
                    let (from, to) = (fl.head.from, fl.head.to);
                    let sent_at = fl.sent_at;
                    // flight span lands on the receiver's recorder (no-op
                    // when tracing is off); tail members share the send
                    // instant and arrival by construction
                    self.processes[to.idx()].recorder.msg_flight(
                        fl.head.msg.kind_name(),
                        from,
                        sent_at,
                        self.now,
                    );
                    self.processes[to.idx()].on_message(fl.head, self.now, &mut effects);
                    self.apply_effects(to, &mut effects);
                    for msg in fl.tail {
                        self.processes[to.idx()].recorder.msg_flight(
                            msg.kind_name(),
                            from,
                            sent_at,
                            self.now,
                        );
                        let env = Envelope {
                            from,
                            to,
                            msg,
                            // the wire charge was paid when the member's
                            // delay was computed; the receiver ignores it
                            wire_doubles: 0,
                        };
                        self.processes[to.idx()].on_message(env, self.now, &mut effects);
                        self.apply_effects(to, &mut effects);
                    }
                }
                EventKind::ExecDone { proc, rt, duration } => {
                    self.processes[proc.idx()].on_exec_complete(
                        rt,
                        Payload::Sim,
                        duration,
                        self.now,
                        &mut effects,
                    );
                    self.apply_effects(proc, &mut effects);
                }
                EventKind::Tick { proc, .. } => {
                    self.processes[proc.idx()].on_tick(self.now, &mut effects);
                    self.apply_effects(proc, &mut effects);
                }
            }
            // Only dispatched (state-changing) events can satisfy the
            // predicate; suppressed ticks skip the check via `continue`.
            if let Some(stop) = &self.stop_when {
                if stop(&self.processes) {
                    break;
                }
            }
        }

        // The queue is empty here unless `stop_when` broke out early.
        if self.live > 0 && self.stop_when.is_none() {
            return Err(SimError::Deadlock { live: self.live });
        }

        Ok(self.collect(events))
    }

    fn collect(&self, events: u64) -> SimResult {
        let p = self.processes.len();
        let mut traces = RunTraces::new(p);
        let mut counters = DlbCounters::default();
        let mut per = Vec::with_capacity(p);
        let mut makespan: f64 = 0.0;
        for ps in &self.processes {
            makespan = makespan.max(ps.last_completion);
            counters.merge(ps.counters());
            per.push(*ps.counters());
        }
        let mut trace = RunTrace::new(p);
        for (i, ps) in self.processes.iter().enumerate() {
            traces.per_process[i] = ps.trace.clone();
            if ps.recorder.is_on() {
                trace.per_process[i] = ps.recorder.events().to_vec();
            }
        }
        traces.makespan = makespan;
        let total_flops: u64 = self.processes[0].graph.total_flops();
        let s = self.processes[0].params.cost.flops_per_sec;
        let utilization = if makespan > 0.0 {
            total_flops as f64 / (p as f64 * s * makespan)
        } else {
            0.0
        };
        SimResult {
            makespan,
            end_time: self.now,
            traces,
            trace,
            counters,
            per_process_counters: per,
            events_processed: events,
            peak_pending_events: self.peak_pending,
            utilization,
            window: WindowStats::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::graph::GraphBuilder;
    use crate::core::task::TaskKind;

    /// A chain of n synthetic tasks all on p0 — pure sequential baseline.
    fn chain_cfg(n: usize, p: usize, dlb: bool) -> (Config, Arc<TaskGraph>) {
        let mut cfg = Config::default();
        cfg.processes = p;
        cfg.grid = None;
        cfg.dlb_enabled = dlb;
        cfg.wt = 2;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        let mut prev = None;
        for _ in 0..n {
            let d = b.data(ProcessId(0), 64, 64);
            let args = match prev {
                Some(pd) => vec![pd],
                None => vec![],
            };
            let t = b.task(TaskKind::Synthetic, args, d, 1_000_000, None);
            let _ = t;
            prev = Some(d);
        }
        (cfg, b.build())
    }

    #[test]
    fn sequential_chain_runs_to_completion() {
        let (cfg, g) = chain_cfg(20, 1, false);
        let mut eng = SimEngine::from_config(&cfg, g);
        let r = eng.run().expect("run");
        // 20 tasks × (1e6 flops / 8.8e9 + 5µs overhead)
        let expect = 20.0 * (1_000_000.0 / 8.8e9 + 5.0e-6);
        assert!((r.makespan - expect).abs() < expect * 0.01, "{} vs {expect}", r.makespan);
        assert_eq!(r.counters.transactions, 0);
    }

    #[test]
    fn multi_process_chain_terminates_with_dlb_on() {
        // chain is inherently sequential: DLB must not break correctness,
        // idle processes will search but find nothing persistent to steal.
        let (cfg, g) = chain_cfg(10, 4, true);
        let mut eng = SimEngine::from_config(&cfg, g);
        let r = eng.run().expect("run");
        assert!(r.makespan > 0.0);
    }

    /// Independent tasks all initially at p0: DLB should spread them and cut
    /// the makespan versus DLB-off.
    fn bag_cfg(n: usize, p: usize, dlb: bool, seed: u64) -> (Config, Arc<TaskGraph>) {
        let mut cfg = Config::default();
        cfg.processes = p;
        cfg.dlb_enabled = dlb;
        cfg.wt = 3;
        cfg.delta = 0.0005;
        cfg.seed = seed;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            let d = b.data(ProcessId(0), 256, 256);
            // 50 ms tasks: long enough that migration (≪ 1 ms) is negligible
            b.task(TaskKind::Synthetic, vec![], d, 440_000_000, None);
        }
        (cfg, b.build())
    }

    #[test]
    fn dlb_balances_imbalanced_bag() {
        let (cfg_off, g_off) = bag_cfg(32, 4, false, 7);
        let off = SimEngine::from_config(&cfg_off, g_off).run().expect("off");
        let (cfg_on, g_on) = bag_cfg(32, 4, true, 7);
        let on = SimEngine::from_config(&cfg_on, g_on).run().expect("on");
        assert!(on.counters.tasks_exported > 0, "work must migrate");
        assert!(
            on.makespan < 0.55 * off.makespan,
            "DLB should roughly 4x a pure-p0 bag: on={} off={}",
            on.makespan,
            off.makespan
        );
    }

    #[test]
    fn deterministic_under_seed() {
        let (cfg, g) = bag_cfg(16, 4, true, 11);
        let a = SimEngine::from_config(&cfg, Arc::clone(&g)).run().expect("a");
        let b = SimEngine::from_config(&cfg, g).run().expect("b");
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn different_seeds_differ() {
        let (cfg_a, g_a) = bag_cfg(16, 4, true, 1);
        let (cfg_b, g_b) = bag_cfg(16, 4, true, 2);
        let a = SimEngine::from_config(&cfg_a, g_a).run().expect("a");
        let b = SimEngine::from_config(&cfg_b, g_b).run().expect("b");
        // almost surely different event orders
        assert!(a.events_processed != b.events_processed || a.makespan != b.makespan);
    }

    #[test]
    fn utilization_bounded() {
        let (cfg, g) = bag_cfg(32, 4, true, 3);
        let r = SimEngine::from_config(&cfg, g).run().expect("run");
        assert!(r.utilization > 0.0 && r.utilization <= 1.0 + 1e-9, "{}", r.utilization);
    }

    #[test]
    fn traces_are_recorded() {
        let (cfg, g) = bag_cfg(16, 4, true, 5);
        let r = SimEngine::from_config(&cfg, g).run().expect("run");
        assert!(r.traces.per_process[0].max_workload() > 0);
        assert!(r.traces.makespan > 0.0);
    }

    #[test]
    fn tracing_is_fingerprint_neutral_and_captures_events() {
        use crate::metrics::recorder::TraceEvent;
        let (cfg_off, g) = bag_cfg(16, 4, true, 5);
        let off = SimEngine::from_config(&cfg_off, Arc::clone(&g)).run().expect("off");
        let mut cfg_on = cfg_off.clone();
        cfg_on.trace_enabled = true;
        let on = SimEngine::from_config(&cfg_on, g).run().expect("on");
        // the recorder is write-only: identical run, bit for bit
        assert_eq!(on.makespan.to_bits(), off.makespan.to_bits());
        assert_eq!(on.counters, off.counters);
        assert_eq!(on.events_processed, off.events_processed);
        // off (the default) records nothing; on records the full taxonomy
        assert!(off.trace.is_empty());
        assert!(on.trace.total_events() > 0);
        let all: Vec<&TraceEvent> = on.trace.per_process.iter().flatten().collect();
        assert!(all.iter().any(|e| matches!(e, TraceEvent::RoundEnd { .. })));
        assert!(all.iter().any(|e| matches!(e, TraceEvent::ExecEnd { .. })));
        assert!(all.iter().any(|e| matches!(e, TraceEvent::MigratedIn { .. })));
        // every DES flight span is causal: sent stamped at Send-apply time
        for e in &all {
            if let TraceEvent::MsgFlight { sent, t, .. } = e {
                assert!(sent <= t, "flight arrived before it left: {sent} > {t}");
                assert!(*sent > 0.0 || *t >= 0.0);
            }
        }
        assert!(
            all.iter().any(|e| matches!(e, TraceEvent::MsgFlight { .. })),
            "DES runs must record message flights"
        );
    }

    #[test]
    fn peak_pending_events_recorded() {
        let (cfg, g) = bag_cfg(16, 4, true, 5);
        let r = SimEngine::from_config(&cfg, g).run().expect("run");
        assert!(r.peak_pending_events > 0);
    }

    #[test]
    fn stale_ticks_are_suppressed_at_pop() {
        let (cfg, g) = chain_cfg(1, 1, true);
        let mut eng = SimEngine::from_config(&cfg, g);
        // Hand-schedule a tick at t=2, then a replacement at t=1 (allowed:
        // dedup only skips pushes at-or-after the live tick).
        let mut fx = vec![Effect::ScheduleTick { at: 2.0 }];
        eng.apply_effects(ProcessId(0), &mut fx);
        let mut fx = vec![Effect::ScheduleTick { at: 1.0 }];
        eng.apply_effects(ProcessId(0), &mut fx);
        assert_eq!(eng.tick_at[0], 1.0, "latest schedule wins");
        // Earliest pop (t=1) is the live generation; the t=2 pop carries a
        // superseded generation and must not reach on_tick.
        let e1 = eng.queue.pop().expect("tick at 1");
        assert_eq!(e1.t, 1.0);
        let EventKind::Tick { gen: g1, .. } = e1.item else { panic!("expected tick") };
        assert_eq!(g1, eng.tick_gen[0], "t=1 would dispatch");
        let e2 = eng.queue.pop().expect("tick at 2");
        assert_eq!(e2.t, 2.0);
        let EventKind::Tick { gen: g2, .. } = e2.item else { panic!("expected tick") };
        assert_ne!(g2, eng.tick_gen[0], "t=2 is stale and must be dropped");
    }

    #[test]
    fn run_loop_drops_stale_ticks() {
        // Hand-arm a tick at t=2µs, then replace it with t=1µs; the chain
        // task runs ~118µs, so both pop mid-run.  Exactly one extra event
        // (the live tick) may be dispatched versus an unarmed run — the
        // superseded tick must die at the pop, not fire on_tick.
        let (cfg, g) = chain_cfg(1, 1, false);
        let mut eng = SimEngine::from_config(&cfg, g);
        let mut fx = vec![Effect::ScheduleTick { at: 2e-6 }];
        eng.apply_effects(ProcessId(0), &mut fx);
        let mut fx = vec![Effect::ScheduleTick { at: 1e-6 }];
        eng.apply_effects(ProcessId(0), &mut fx);
        let r = eng.run().expect("run");

        let (cfg2, g2) = chain_cfg(1, 1, false);
        let base = SimEngine::from_config(&cfg2, g2).run().expect("base");
        assert_eq!(
            r.events_processed,
            base.events_processed + 1,
            "one live tick dispatched, one stale tick suppressed"
        );
    }

    #[test]
    fn flight_slab_recycles_slots() {
        let (cfg, g) = bag_cfg(32, 4, true, 7);
        let mut eng = SimEngine::from_config(&cfg, g);
        let r = eng.run().expect("run");
        // far more messages flowed than the slab ever held live at once
        assert!(
            r.events_processed > eng.env_slab.len() as u64,
            "slab must recycle slots: {} slots for {} events",
            eng.env_slab.len(),
            r.events_processed
        );
        // occupied slots are exactly the deliveries still pending at exit
        // (both zero after a full drain — the invariant matters on the
        // `stop_when` early-break path, where flights can still be in the
        // air)
        let pending =
            eng.queue.iter().filter(|e| matches!(e.item, EventKind::Deliver { .. })).count();
        let live_slots = eng.env_slab.iter().filter(|s| s.is_some()).count();
        assert_eq!(live_slots, pending);
    }

    #[test]
    fn event_budget_guard() {
        let (cfg, g) = bag_cfg(16, 4, true, 5);
        let mut eng = SimEngine::from_config(&cfg, g);
        eng.max_events = 10;
        assert!(matches!(eng.run(), Err(SimError::EventBudget(_))));
    }

    /// A fan-out graph whose boot step sends several same-size v0 blocks to
    /// the same remote consumer — the canonical coalescing opportunity.
    fn v0_fanout_cfg(blocks: usize, coalesce: bool) -> (Config, Arc<TaskGraph>) {
        let mut cfg = Config::default();
        cfg.processes = 2;
        cfg.grid = None;
        cfg.dlb_enabled = false;
        cfg.coalesce = coalesce;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        // v0 data homed on p0, consumed by tasks on p1 → p0's start step
        // emits `blocks` DataSends to p1, all the same size/delay.
        let args: Vec<_> = (0..blocks).map(|_| b.data(ProcessId(0), 32, 32)).collect();
        let out = b.data(ProcessId(1), 32, 32);
        b.task(TaskKind::Synthetic, args, out, 1_000_000, None);
        (cfg, b.build())
    }

    #[test]
    fn coalescing_packs_v0_fanout_into_one_delivery() {
        let (cfg_off, g_off) = v0_fanout_cfg(6, false);
        let off = SimEngine::from_config(&cfg_off, g_off).run().expect("off");
        let (cfg_on, g_on) = v0_fanout_cfg(6, true);
        let on = SimEngine::from_config(&cfg_on, g_on).run().expect("on");
        // identical logical message count and identical timing …
        assert_eq!(on.events_processed, off.events_processed);
        assert_eq!(on.makespan.to_bits(), off.makespan.to_bits());
        // … but 5 of the 6 boot-time DataSends rode an existing flight
        assert_eq!(off.counters.messages_coalesced, 0);
        assert_eq!(on.counters.messages_coalesced, 5);
        // which shrinks the pending-event high-water mark
        assert!(
            on.peak_pending_events < off.peak_pending_events,
            "coalescing must shrink pending events: on={} off={}",
            on.peak_pending_events,
            off.peak_pending_events
        );
    }

    #[test]
    fn coalescing_off_is_bit_identical_to_default() {
        // `coalesce = false` is the default: constructing it explicitly
        // must not disturb anything (guards the config plumbing).
        let (cfg_a, g_a) = bag_cfg(16, 4, true, 11);
        let mut cfg_b = cfg_a.clone();
        cfg_b.coalesce = false;
        let a = SimEngine::from_config(&cfg_a, g_a).run().expect("a");
        let b = SimEngine::from_config(&cfg_b, bag_cfg(16, 4, true, 11).1).run().expect("b");
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn coalesced_bag_run_still_balances_and_conserves_tasks() {
        let (mut cfg, g) = bag_cfg(32, 4, true, 7);
        cfg.coalesce = true;
        let r = SimEngine::from_config(&cfg, g).run().expect("run");
        assert!(r.counters.tasks_exported > 0);
        assert_eq!(r.counters.tasks_exported, r.counters.tasks_received);
    }
}
