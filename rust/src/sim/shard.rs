//! One shard of the parallel DES: a contiguous block of ranks, their
//! `ProcessState`s, and a private calendar queue.
//!
//! A shard is the single-threaded engine's hot path minus global state:
//! shard-local sends go straight into the local calendar (no
//! synchronization whatsoever), cross-shard sends are appended to
//! per-destination outboxes that the coordinator (`sim::parallel`) routes
//! at the window barrier.  Event keys are the same parallel-stable
//! `emit × P + rank` values `SimEngine` uses, so replaying a shard's slice
//! of the run reproduces the single-threaded dispatch order bit for bit.

use std::sync::Arc;

use crate::core::data::Payload;
use crate::core::ids::ProcessId;
use crate::core::process::{Effect, ProcessState};
use crate::net::message::{Envelope, Flight};
use crate::sim::calendar::{CalendarQueue, Entry};
use crate::sim::engine::EventKind;
use crate::sim::network::NetworkModel;

/// A flight crossing a shard boundary: arrival time and event key travel
/// with it so the destination shard can enqueue it exactly as the
/// single-threaded engine would have.
#[derive(Debug)]
pub(crate) struct OutFlight {
    /// Arrival time (`send_time + delay_between`, computed sender-side with
    /// the exact same float expression as the local path).
    pub(crate) t: f64,
    /// The sender's parallel-stable event key (`emit × P + rank`).
    pub(crate) key: u64,
    pub(crate) flight: Flight,
}

/// Where a step's open flight lives — the coalescing scratch must be able
/// to append tail messages to local slab flights and outbox flights alike.
#[derive(Debug, Clone, Copy)]
enum FlightRef {
    Local(u32),
    Out { shard: usize, idx: usize },
}

/// Per-window report a worker hands the coordinator at the barrier.
/// Everything here is cumulative or a snapshot of quiescent state, so the
/// coordinator may reuse a cached report across windows in which the shard
/// was not commanded (the sparse-barrier rule): an uncommanded shard
/// cannot change any of these fields.
#[derive(Debug)]
pub(crate) struct ShardReport {
    /// Earliest pending local event, `None` when this shard is drained.
    pub(crate) next_time: Option<f64>,
    /// Drained cross-shard outboxes: (destination shard, flights).
    pub(crate) outboxes: Vec<(usize, Vec<OutFlight>)>,
    /// Cumulative dispatched events (coalesced tails included), matching
    /// the single-threaded engine's counting rules.
    pub(crate) events: u64,
    /// Owned processes that have not halted.
    pub(crate) live: usize,
}

pub(crate) struct Shard {
    pub(crate) id: u32,
    /// First owned global rank — ownership is a contiguous interval, so
    /// `global - lo` indexes `procs`.
    pub(crate) lo: usize,
    pub(crate) procs: Vec<ProcessState>,
    queue: CalendarQueue<EventKind>,
    env_slab: Vec<Option<Flight>>,
    env_free: Vec<u32>,
    coalesce: bool,
    step_flights: Vec<(ProcessId, u64, FlightRef)>,
    /// Time of the last event this shard dispatched.
    pub(crate) now: f64,
    /// Per-owned-rank emission counters (see `SimEngine::push`).
    emit_seq: Vec<u64>,
    tick_at: Vec<f64>,
    tick_gen: Vec<u64>,
    pub(crate) live: usize,
    /// Local pending-event high-water mark; the coordinator reports the
    /// sum over shards (an upper bound on the true global peak).
    pub(crate) peak_pending: usize,
    events: u64,
    p_total: u64,
    network: NetworkModel,
    shard_of: Arc<Vec<u32>>,
    /// Per-destination-shard outboxes, drained into `take_report`.
    outboxes: Vec<Vec<OutFlight>>,
}

impl Shard {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        id: u32,
        lo: usize,
        procs: Vec<ProcessState>,
        p_total: usize,
        network: NetworkModel,
        shard_of: Arc<Vec<u32>>,
        coalesce: bool,
        n_shards: usize,
    ) -> Self {
        let owned = procs.len();
        Shard {
            id,
            lo,
            procs,
            queue: CalendarQueue::new(),
            env_slab: Vec::new(),
            env_free: Vec::new(),
            coalesce,
            step_flights: Vec::new(),
            now: 0.0,
            emit_seq: vec![0; owned],
            tick_at: vec![f64::NEG_INFINITY; owned],
            tick_gen: vec![0; owned],
            live: owned,
            peak_pending: 0,
            events: 0,
            p_total: p_total as u64,
            network,
            shard_of,
            outboxes: (0..n_shards).map(|_| Vec::new()).collect(),
        }
    }

    /// Local index of an owned global rank.
    fn li(&self, g: ProcessId) -> usize {
        debug_assert!(self.owns(g), "rank {g:?} not owned by shard {}", self.id);
        g.idx() - self.lo
    }

    fn owns(&self, g: ProcessId) -> bool {
        self.shard_of[g.idx()] == self.id
    }

    /// Consume the emitter's next parallel-stable event key.
    fn next_key(&mut self, src: ProcessId) -> u64 {
        let li = src.idx() - self.lo;
        let key = self.emit_seq[li] * self.p_total + src.idx() as u64;
        self.emit_seq[li] += 1;
        key
    }

    fn push(&mut self, src: ProcessId, t: f64, kind: EventKind) {
        debug_assert!(t >= self.now, "event in the past: {t} < {}", self.now);
        let key = self.next_key(src);
        self.queue.push(t, key, kind);
        self.peak_pending = self.peak_pending.max(self.queue.len());
    }

    fn stash_flight(&mut self, fl: Flight) -> u32 {
        match self.env_free.pop() {
            Some(slot) => {
                debug_assert!(self.env_slab[slot as usize].is_none());
                self.env_slab[slot as usize] = Some(fl);
                slot
            }
            None => {
                self.env_slab.push(Some(fl));
                (self.env_slab.len() - 1) as u32
            }
        }
    }

    fn unstash_flight(&mut self, slot: u32) -> Flight {
        let fl = self.env_slab[slot as usize].take().expect("live flight slot");
        self.env_free.push(slot);
        fl
    }

    /// `SimEngine::apply_effects`, split along the shard boundary: local
    /// sends keep the engine's zero-allocation slab path, cross-shard sends
    /// consume their event key immediately (key order is per-emitter, so
    /// this matches the single-threaded engine exactly) and park in the
    /// destination's outbox until the barrier.  Coalescing stays per-step
    /// and works on both kinds of open flight via `FlightRef`.
    fn apply_effects(&mut self, proc: ProcessId, effects: &mut Vec<Effect>) {
        self.step_flights.clear();
        let mut coalesced: u64 = 0;
        for e in effects.drain(..) {
            match e {
                Effect::Send(env) => {
                    let delay = self.network.delay_between(env.from, env.to, env.wire_doubles);
                    let to = env.to;
                    if self.coalesce {
                        let bits = delay.to_bits();
                        if let Some(&(_, _, fref)) = self
                            .step_flights
                            .iter()
                            .find(|&&(t, b, _)| t == to && b == bits)
                        {
                            match fref {
                                FlightRef::Local(slot) => {
                                    let fl = self.env_slab[slot as usize]
                                        .as_mut()
                                        .expect("open flight slot");
                                    fl.tail.push(env.msg);
                                }
                                FlightRef::Out { shard, idx } => {
                                    self.outboxes[shard][idx].flight.tail.push(env.msg);
                                }
                            }
                            coalesced += 1;
                            continue;
                        }
                    }
                    let fl = Flight::sent(env, self.now);
                    let bits = delay.to_bits();
                    if self.owns(to) {
                        let slot = self.stash_flight(fl);
                        if self.coalesce {
                            self.step_flights.push((to, bits, FlightRef::Local(slot)));
                        }
                        self.push(proc, self.now + delay, EventKind::Deliver { slot });
                    } else {
                        let dst = self.shard_of[to.idx()] as usize;
                        let key = self.next_key(proc);
                        let idx = self.outboxes[dst].len();
                        self.outboxes[dst].push(OutFlight { t: self.now + delay, key, flight: fl });
                        if self.coalesce {
                            self.step_flights.push((to, bits, FlightRef::Out { shard: dst, idx }));
                        }
                    }
                }
                Effect::StartExec { task } => {
                    let li = self.li(proc);
                    let node = self.procs[li].graph.task(task.task);
                    let base = self.procs[li].params.cost.local_time(node.flops);
                    // No jitter term: `Config::validate` rejects
                    // exec_jitter > 0 under sim.threads > 1, because jitter
                    // draws from one engine-global RNG stream in dispatch
                    // order — inherently unshardable.
                    let duration = base.max(1e-12);
                    let done = EventKind::ExecDone { proc, rt: task, duration };
                    self.push(proc, self.now + duration, done);
                }
                Effect::ScheduleTick { at } => {
                    let li = self.li(proc);
                    let at = at.max(self.now);
                    if self.tick_at[li] > self.now && self.tick_at[li] <= at + 1e-12 {
                        continue;
                    }
                    self.tick_at[li] = at;
                    self.tick_gen[li] += 1;
                    let gen = self.tick_gen[li];
                    self.push(proc, at, EventKind::Tick { proc, gen });
                }
                Effect::Halt => {
                    debug_assert!(self.live > 0, "halt underflow");
                    self.live = self.live.saturating_sub(1);
                }
            }
        }
        if coalesced > 0 {
            let li = self.li(proc);
            self.procs[li].policy.counters_mut().messages_coalesced += coalesced;
        }
    }

    /// Boot every owned process at t = 0 (rank order, as the
    /// single-threaded engine does).
    pub(crate) fn boot(&mut self, effects: &mut Vec<Effect>) {
        for k in 0..self.procs.len() {
            let g = ProcessId((self.lo + k) as u32);
            self.procs[k].start(0.0, effects);
            self.apply_effects(g, effects);
        }
    }

    /// Enqueue the window's cross-shard arrivals and dispatch every local
    /// event strictly before `horizon`.  Conservative safety: the
    /// coordinator picked `horizon` so that anything another shard j can
    /// still send this shard arrives at
    /// `≥ next_eff_j + L[j][me] ≥ horizon` (per-pair matrix lookahead; the
    /// scalar protocol is the same bound collapsed to the global minimum) —
    /// those are exactly the events held back by the strict `<`.  The
    /// shard never needs to know which protocol produced the number.
    pub(crate) fn run_window(
        &mut self,
        horizon: f64,
        inbox: Vec<OutFlight>,
        effects: &mut Vec<Effect>,
    ) {
        for of in inbox {
            // Inbox flights enter the calendar directly (their event key
            // was minted sender-side), bypassing `push()` — so restate its
            // invariant here: a horizon-protocol bug otherwise dispatches
            // time-travelling events and regresses `self.now` silently.
            debug_assert!(
                of.t >= self.now,
                "cross-shard arrival in the past: {} < {}",
                of.t,
                self.now
            );
            let slot = self.stash_flight(of.flight);
            self.queue.push(of.t, of.key, EventKind::Deliver { slot });
        }
        self.peak_pending = self.peak_pending.max(self.queue.len());
        while let Some(Entry { t, item: kind, .. }) = self.queue.pop_before(horizon) {
            if let EventKind::Tick { proc, gen } = kind {
                if gen != self.tick_gen[self.li(proc)] {
                    continue;
                }
            }
            self.now = t;
            self.events += 1;
            if let EventKind::Deliver { slot } = kind {
                let tail = self.env_slab[slot as usize]
                    .as_ref()
                    .map_or(0, |fl| fl.tail.len() as u64);
                self.events += tail;
            }
            match kind {
                EventKind::Deliver { slot } => {
                    let fl = self.unstash_flight(slot);
                    let (from, to) = (fl.head.from, fl.head.to);
                    let sent_at = fl.sent_at;
                    let li = self.li(to);
                    self.procs[li].recorder.msg_flight(
                        fl.head.msg.kind_name(),
                        from,
                        sent_at,
                        self.now,
                    );
                    self.procs[li].on_message(fl.head, self.now, effects);
                    self.apply_effects(to, effects);
                    for msg in fl.tail {
                        let li = self.li(to);
                        let p = &mut self.procs[li];
                        p.recorder.msg_flight(msg.kind_name(), from, sent_at, self.now);
                        let env = Envelope { from, to, msg, wire_doubles: 0 };
                        self.procs[li].on_message(env, self.now, effects);
                        self.apply_effects(to, effects);
                    }
                }
                EventKind::ExecDone { proc, rt, duration } => {
                    let li = self.li(proc);
                    self.procs[li].on_exec_complete(rt, Payload::Sim, duration, self.now, effects);
                    self.apply_effects(proc, effects);
                }
                EventKind::Tick { proc, .. } => {
                    let li = self.li(proc);
                    self.procs[li].on_tick(self.now, effects);
                    self.apply_effects(proc, effects);
                }
            }
        }
    }

    /// Barrier hand-off: drained outboxes, earliest pending local event,
    /// cumulative event count, and remaining live processes.
    pub(crate) fn take_report(&mut self) -> ShardReport {
        let mut out = Vec::new();
        for (dst, v) in self.outboxes.iter_mut().enumerate() {
            if !v.is_empty() {
                out.push((dst, std::mem::take(v)));
            }
        }
        ShardReport {
            next_time: self.queue.next_time(),
            outboxes: out,
            events: self.events,
            live: self.live,
        }
    }

    #[cfg(test)]
    pub(crate) fn events(&self) -> u64 {
        self.events
    }

    #[cfg(test)]
    pub(crate) fn pending(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::core::graph::GraphBuilder;
    use crate::core::process::ProcessParams;
    use crate::core::task::TaskKind;
    use crate::net::message::Msg;
    use crate::net::topology::Topology;

    /// A 2-rank world split into 2 shards; returns shard 1 (owning rank 1).
    fn lone_shard() -> Shard {
        let mut cfg = Config::default();
        cfg.processes = 2;
        cfg.dlb_enabled = false;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        let d = b.data(ProcessId(0), 8, 8);
        b.task(TaskKind::Synthetic, vec![], d, 1_000, None);
        let graph = b.build();
        let params = ProcessParams::from_config(&cfg);
        let shard_of = Arc::new(vec![0u32, 1u32]);
        let net = NetworkModel::with_topology(cfg.net_latency, cfg.doubles_per_sec, Topology::Flat);
        let procs = vec![ProcessState::new(ProcessId(1), 2, graph, params, cfg.seed)];
        Shard::new(1, 1, procs, 2, net, shard_of, false, 2)
    }

    #[test]
    fn arrival_exactly_at_the_horizon_is_not_processed_early() {
        // The conservative contract at its boundary: a cross-shard message
        // whose arrival lands exactly ON the horizon must wait for the next
        // window — another shard may still emit an event at that instant.
        let mut shard = lone_shard();
        let mut effects = Vec::new();
        let horizon = 5e-6;
        let inbox = vec![OutFlight {
            t: horizon,
            key: 0,
            flight: Flight::sent(
                Envelope {
                    from: ProcessId(0),
                    to: ProcessId(1),
                    msg: Msg::Shutdown,
                    wire_doubles: 0,
                },
                0.0,
            ),
        }];
        shard.run_window(horizon, inbox, &mut effects);
        assert_eq!(shard.events(), 0, "boundary arrival dispatched early");
        assert_eq!(shard.pending(), 1, "arrival must stay queued");
        assert_eq!(shard.live, 1);
        // next window opens past the arrival: now it dispatches
        shard.run_window(2.0 * horizon, Vec::new(), &mut effects);
        assert_eq!(shard.events(), 1);
        assert_eq!(shard.pending(), 0);
        assert_eq!(shard.live, 0, "Shutdown halts the rank");
        assert_eq!(shard.now, horizon);
    }

    /// The protocol invariant the coordinator's horizons exist to uphold:
    /// no flight may be delivered behind a shard's dispatch frontier.  A
    /// horizon bug that breaks it must fail fast in debug builds, not
    /// silently regress `now`.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "cross-shard arrival in the past")]
    fn inbox_flight_behind_now_panics_in_debug() {
        let mut shard = lone_shard();
        let mut effects = Vec::new();
        let mk = |t: f64, key: u64| OutFlight {
            t,
            key,
            flight: Flight::sent(
                Envelope {
                    from: ProcessId(0),
                    to: ProcessId(1),
                    msg: Msg::Shutdown,
                    wire_doubles: 0,
                },
                0.0,
            ),
        };
        // Advance the frontier to 4 µs…
        shard.run_window(1e-5, vec![mk(4e-6, 0)], &mut effects);
        assert_eq!(shard.now, 4e-6);
        // …then a later window delivers a flight dated before it.
        shard.run_window(1e-4, vec![mk(2e-6, 2)], &mut effects);
    }

    #[test]
    fn strictly_earlier_arrival_is_processed_in_window() {
        let mut shard = lone_shard();
        let mut effects = Vec::new();
        let horizon = 5e-6;
        let inbox = vec![OutFlight {
            t: horizon / 2.0,
            key: 0,
            flight: Flight::sent(
                Envelope {
                    from: ProcessId(0),
                    to: ProcessId(1),
                    msg: Msg::Shutdown,
                    wire_doubles: 0,
                },
                0.0,
            ),
        }];
        shard.run_window(horizon, inbox, &mut effects);
        assert_eq!(shard.events(), 1);
        assert_eq!(shard.live, 0);
    }
}
