//! The two-level calendar queue behind the DES event scheduler.
//!
//! A `BinaryHeap` pays O(log n) per push and per pop; with P = 4096
//! processes the pending-event set reaches tens of thousands of entries and
//! those log factors dominate the simulator's inner loop.  The calendar
//! queue replaces them with O(1) amortized operations:
//!
//! - a **near-horizon wheel** of `nb` buckets, each `width` seconds wide,
//!   covering `[t0, t0 + nb·width)`: a push lands in its bucket by one
//!   division, a pop touches only the (small) current bucket's heap;
//! - an **overflow far-list** for events at or beyond the horizon, held
//!   unsorted until the wheel drains and the window is rebuilt over them.
//!
//! The window is recalibrated at every rebuild from the pending set itself:
//! bucket count tracks the population (`next_power_of_two`, so ~1 entry per
//! bucket) and bucket width tracks a deterministic sample of the event-time
//! spread.  A rebuild is O(pending) and happens once per exhausted window —
//! amortized O(1) per event as long as a window serves O(nb) events, which
//! the population-tracking bucket count guarantees.
//!
//! **Ordering contract:** pops come out in exactly the total order
//! `(time, seq)` — identical to the `BinaryHeap` the DES used before, so
//! run fingerprints are bit-for-bit reproducible across the swap.  Bucket
//! boundaries partition time, so no event in a later bucket or in the
//! far-list can precede the current bucket's minimum; *within* a bucket,
//! entries sit in a small min-ordered heap, so even a system-wide
//! same-timestamp cohort (a boot storm delivering thousands of equal-size
//! messages at one instant — ties no bucket width can split) costs
//! O(log cohort) per operation rather than a linear rescan per pop.
//! `tests/properties.rs` property-checks the order equivalence against a
//! `BinaryHeap` oracle over random streams (ties, far-future outliers,
//! interleaved pops).

use std::collections::BinaryHeap;

/// One scheduled entry: fire time, tie-breaking sequence number, payload.
#[derive(Debug)]
pub struct Entry<T> {
    pub t: f64,
    pub seq: u64,
    pub item: T,
}

/// Bucket storage wrapper: orders a max-`BinaryHeap` by *reversed*
/// `(t, seq)` so `pop` yields the earliest entry first — the exact
/// comparator the DES's old global event heap used.
#[derive(Debug)]
struct Slot<T>(Entry<T>);

impl<T> PartialEq for Slot<T> {
    fn eq(&self, other: &Self) -> bool {
        self.0.t == other.0.t && self.0.seq == other.0.seq
    }
}
impl<T> Eq for Slot<T> {}
impl<T> PartialOrd for Slot<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Slot<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .0
            .t
            .partial_cmp(&self.0.t)
            .expect("no NaN times")
            .then_with(|| other.0.seq.cmp(&self.0.seq))
    }
}

const MIN_BUCKETS: usize = 16;
const MAX_BUCKETS: usize = 1 << 16;
/// Floor on the bucket width — also the fallback when every sampled event
/// shares one timestamp (width cannot separate ties anyway).
const MIN_WIDTH: f64 = 1e-9;
/// Deterministic sample size for the width estimate at rebuild.
const SAMPLE: usize = 64;
/// Rebuild mid-window when average occupancy exceeds this many entries per
/// bucket (the pending set outgrew the wheel).
const REBUILD_FACTOR: usize = 8;

/// A two-level calendar/ladder priority queue over `(t, seq)`.
pub struct CalendarQueue<T> {
    /// The near-horizon wheel; all entries with `t < horizon` live here.
    /// Each bucket is a small min-ordered heap (see [`Slot`]).
    buckets: Vec<BinaryHeap<Slot<T>>>,
    /// Seconds of virtual time per bucket.
    width: f64,
    /// Start time of bucket 0 of the current window.
    t0: f64,
    /// `t0 + buckets.len() × width`; entries at or beyond go to `far`.
    /// Starts at −∞ so every push before the first pop lands in `far` and
    /// the first window self-calibrates over the full boot population.
    horizon: f64,
    /// Current bucket: every near entry lives at an index ≥ `cursor`.
    cursor: usize,
    near_len: usize,
    far: Vec<Entry<T>>,
    len: usize,
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> CalendarQueue<T> {
    pub fn new() -> Self {
        CalendarQueue {
            buckets: Vec::new(),
            width: MIN_WIDTH,
            t0: 0.0,
            horizon: f64::NEG_INFINITY,
            cursor: 0,
            near_len: 0,
            far: Vec::new(),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// All pending entries, in no particular order.
    pub fn iter(&self) -> impl Iterator<Item = &Entry<T>> {
        self.buckets.iter().flat_map(|b| b.iter().map(|s| &s.0)).chain(self.far.iter())
    }

    /// Bucket index for a near entry.  The `as usize` cast saturates
    /// negative values to 0 and the clamp keeps float-boundary stragglers
    /// in the current bucket, whose heap still orders them exactly.
    #[inline]
    fn bucket_index(&self, t: f64) -> usize {
        let raw = ((t - self.t0) / self.width) as usize;
        raw.clamp(self.cursor, self.buckets.len() - 1)
    }

    pub fn push(&mut self, t: f64, seq: u64, item: T) {
        debug_assert!(!t.is_nan(), "NaN event time");
        let e = Entry { t, seq, item };
        // Count the entry before any rebuild below: rebuild re-gathers
        // everything pending and checks its census against `len`.
        self.len += 1;
        if t < self.horizon {
            let idx = self.bucket_index(t);
            self.buckets[idx].push(Slot(e));
            self.near_len += 1;
            // The pending set outgrew the wheel: re-center on the current
            // bucket's start so occupancy drops back to ~1.  Once the wheel
            // is at MAX_BUCKETS a rebuild cannot widen it further — skip it
            // (per-op cost degrades to O(log occupancy) instead of a rebuild
            // storm on every push).
            if self.near_len > REBUILD_FACTOR * self.buckets.len()
                && self.buckets.len() < MAX_BUCKETS
            {
                let start = self.t0 + self.cursor as f64 * self.width;
                self.rebuild(start);
            }
        } else {
            self.far.push(e);
        }
    }

    pub fn pop(&mut self) -> Option<Entry<T>> {
        if self.len == 0 {
            return None;
        }
        self.settle();
        let Slot(e) = self.buckets[self.cursor].pop().expect("non-empty bucket");
        self.near_len -= 1;
        self.len -= 1;
        Some(e)
    }

    /// Fire time of the earliest pending entry without removing it (`None`
    /// when empty).  Takes `&mut self` because locating the minimum may
    /// trigger the same far-list rebuild a `pop` would.
    pub fn next_time(&mut self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        self.settle();
        Some(self.buckets[self.cursor].peek().expect("settled cursor bucket").0.t)
    }

    /// Pop the earliest entry strictly before `before`; `None` when the
    /// queue is empty or its minimum is at or past `before`.
    ///
    /// This is the window primitive of the sharded DES (`sim::parallel`): a
    /// worker drains its calendar up to the conservative horizon and not
    /// one event further — an entry exactly **at** the horizon stays queued
    /// for the next window, because a cross-shard message may still arrive
    /// at that instant.
    pub fn pop_before(&mut self, before: f64) -> Option<Entry<T>> {
        if self.len == 0 {
            return None;
        }
        self.settle();
        if self.buckets[self.cursor].peek().expect("settled cursor bucket").0.t >= before {
            return None;
        }
        let Slot(e) = self.buckets[self.cursor].pop().expect("settled cursor bucket");
        self.near_len -= 1;
        self.len -= 1;
        Some(e)
    }

    /// Position `cursor` on the bucket holding the global minimum entry.
    /// Requires `len > 0`.  When the near wheel is drained (or before the
    /// first pop), rebuilds over the far-list anchored at its earliest
    /// entry — exactly the lazy recalibration `pop` has always done.
    fn settle(&mut self) {
        debug_assert!(self.len > 0);
        if self.near_len == 0 {
            let start = self.far.iter().map(|e| e.t).fold(f64::INFINITY, f64::min);
            self.rebuild(start);
        }
        while self.buckets[self.cursor].is_empty() {
            self.cursor += 1;
            debug_assert!(self.cursor < self.buckets.len(), "near_len > 0 but wheel empty");
        }
    }

    /// Recalibrate the wheel over everything pending and re-partition.
    /// `start` becomes bucket 0's start time; entries earlier than it (only
    /// possible through float-boundary clamping) stay ordered because they
    /// land in bucket 0, whose heap orders them exactly.
    fn rebuild(&mut self, start: f64) {
        debug_assert!(self.len > 0 && start.is_finite());
        let mut all: Vec<Entry<T>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            all.extend(b.drain().map(|s| s.0));
        }
        all.append(&mut self.far);
        debug_assert_eq!(all.len(), self.len);

        // Bucket count tracks the population; width tracks the *median*
        // inter-event gap of a deterministic sample — robust against the
        // bimodal mix the DES actually produces (µs-apart control messages
        // alongside exec completions many ms out), where a mean would
        // inflate the width and pile the near-term events into one bucket.
        // Wide tails simply stay in `far` and get their own windows later.
        let n = all.len();
        let nb = n.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        let s = n.min(SAMPLE);
        // Stratified sample: every (n/s)-th entry, so the estimate spans
        // the whole pending set — a contiguous prefix could be one
        // same-timestamp cohort and collapse the width to MIN_WIDTH even
        // when the set spans seconds, forcing an O(n) rebuild per cohort.
        let step = (n / s).max(1);
        let mut sample: Vec<f64> =
            all.iter().step_by(step).take(s).map(|e| e.t).collect();
        sample.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
        let mut gaps: Vec<f64> =
            sample.windows(2).map(|w| w[1] - w[0]).filter(|&g| g > 0.0).collect();
        gaps.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
        let gap = if !gaps.is_empty() {
            gaps[gaps.len() / 2]
        } else {
            // every sampled time equal: fall back to the sampled span per
            // bucket (0 for a genuinely single-instant set, where no width
            // can separate ties and one bucket-heap window is correct)
            (sample[sample.len() - 1] - sample[0]) / nb as f64
        };
        self.width = (4.0 * gap).clamp(MIN_WIDTH, 1.0);
        self.t0 = start;
        self.horizon = start + nb as f64 * self.width;
        self.cursor = 0;
        self.near_len = 0;
        if self.buckets.len() != nb {
            self.buckets.resize_with(nb, BinaryHeap::new);
        }
        for e in all {
            if e.t < self.horizon {
                let idx = self.bucket_index(e.t);
                self.buckets[idx].push(Slot(e));
                self.near_len += 1;
            } else {
                self.far.push(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut CalendarQueue<u32>) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push((e.t, e.seq));
        }
        out
    }

    #[test]
    fn empty_queue_pops_none() {
        let mut q: CalendarQueue<u32> = CalendarQueue::new();
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = CalendarQueue::new();
        for (i, t) in [5.0, 1.0, 3.0, 2.0, 4.0].into_iter().enumerate() {
            q.push(t, i as u64, 0u32);
        }
        let order: Vec<f64> = drain(&mut q).into_iter().map(|(t, _)| t).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn ties_resolve_by_seq() {
        let mut q = CalendarQueue::new();
        for seq in [3u64, 1, 4, 0, 2] {
            q.push(7.5, seq, 0u32);
        }
        let seqs: Vec<u64> = drain(&mut q).into_iter().map(|(_, s)| s).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn next_time_peeks_without_removing() {
        let mut q = CalendarQueue::new();
        assert_eq!(q.next_time(), None);
        q.push(3.0, 1, 0u32);
        q.push(1.0, 2, 0u32);
        assert_eq!(q.next_time(), Some(1.0));
        assert_eq!(q.len(), 2, "next_time must not consume");
        assert_eq!(q.pop().map(|e| e.t), Some(1.0));
        assert_eq!(q.next_time(), Some(3.0));
    }

    #[test]
    fn pop_before_is_strict_at_the_horizon() {
        let mut q = CalendarQueue::new();
        q.push(1.0, 1, 0u32);
        q.push(2.0, 2, 0u32);
        q.push(3.0, 3, 0u32);
        assert_eq!(q.pop_before(2.0).map(|e| e.t), Some(1.0));
        // Strict `<`: an entry exactly AT the horizon is not eligible — a
        // cross-shard message may still arrive at that very instant.
        assert!(q.pop_before(2.0).is_none());
        assert_eq!(q.len(), 2);
        assert_eq!(q.next_time(), Some(2.0));
        assert_eq!(q.pop_before(f64::INFINITY).map(|e| e.t), Some(2.0));
        assert_eq!(q.pop_before(3.5).map(|e| e.t), Some(3.0));
        assert!(q.pop_before(f64::INFINITY).is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn pop_before_rebuilds_over_the_far_list() {
        // A lone far-future entry forces the same lazy recalibration pop
        // performs; pop_before must see it land in the near wheel.
        let mut q = CalendarQueue::new();
        q.push(5_000.0, 1, 0u32);
        assert!(q.pop_before(5_000.0).is_none());
        assert_eq!(q.pop_before(5_001.0).map(|e| e.t), Some(5_000.0));
        assert!(q.is_empty());
    }

    #[test]
    fn far_future_overflow_comes_out_last_and_ordered() {
        let mut q = CalendarQueue::new();
        q.push(1e-6, 1, 0u32);
        q.push(2e-6, 2, 0u32);
        // way beyond any near window
        q.push(5_000.0, 3, 0u32);
        q.push(4_999.0, 4, 0u32);
        assert_eq!(
            drain(&mut q),
            vec![(1e-6, 1), (2e-6, 2), (4_999.0, 4), (5_000.0, 3)]
        );
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = CalendarQueue::new();
        let mut seq = 0u64;
        let mut popped = Vec::new();
        for round in 0..50u64 {
            for k in 0..4u64 {
                seq += 1;
                q.push(round as f64 * 1e-3 + k as f64 * 1e-5, seq, 0u32);
            }
            let e = q.pop().expect("pending");
            popped.push((e.t, e.seq));
        }
        popped.extend(drain(&mut q));
        let mut sorted = popped.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
        assert_eq!(popped, sorted, "pop order must be the (t, seq) total order");
        assert_eq!(popped.len(), 200);
    }

    #[test]
    fn wheel_rebuilds_under_growth() {
        // Prime a window over a small spread batch, then flood far more
        // entries than the wheel holds *inside* that window, so the
        // push-side growth rebuild runs; the drain must stay ordered.
        let mut q = CalendarQueue::new();
        let mut seq = 0u64;
        for i in 0..200u64 {
            seq += 1;
            q.push(i as f64 * 1e-6, seq, 0u32);
        }
        let first = q.pop().expect("primed"); // window now calibrated
        assert_eq!(first.seq, 1);
        for i in 0..20_000u64 {
            seq += 1;
            q.push(1e-6 + (i % 97) as f64 * 1e-6, seq, 0u32);
        }
        assert_eq!(q.len(), 20_199);
        let out = drain(&mut q);
        assert_eq!(out.len(), 20_199);
        let mut sorted = out.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
        assert_eq!(out, sorted);
    }

    #[test]
    fn iter_sees_all_pending() {
        let mut q = CalendarQueue::new();
        for i in 0..10u64 {
            q.push(i as f64, i, i as u32);
        }
        let _ = q.pop();
        let mut items: Vec<u32> = q.iter().map(|e| e.item).collect();
        items.sort_unstable();
        assert_eq!(items, (1..10).collect::<Vec<u32>>());
        assert_eq!(q.len(), 9);
    }
}
