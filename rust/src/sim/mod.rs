//! Discrete-event simulation mode: virtual clock + modeled network driving
//! the identical coordinator state machines as the threaded runtime.

pub mod calendar;
pub mod engine;
pub mod network;

pub use calendar::CalendarQueue;
pub use engine::{SimEngine, SimError, SimResult};
pub use network::NetworkModel;
