//! Discrete-event simulation mode: virtual clock + modeled network driving
//! the identical coordinator state machines as the threaded runtime.
//!
//! Two interchangeable engines: the single-threaded `SimEngine` (the
//! determinism oracle) and the sharded, conservatively-synchronized
//! `ParallelSimEngine` (`[sim] threads > 1`).  They produce bit-identical
//! results; `run_config` dispatches between them.

use std::sync::Arc;

use crate::config::Config;
use crate::core::graph::TaskGraph;

pub mod calendar;
pub mod engine;
pub mod network;
pub mod parallel;
mod shard;

pub use calendar::CalendarQueue;
pub use engine::{SimEngine, SimError, SimResult, WindowStats};
pub use network::NetworkModel;
pub use parallel::ParallelSimEngine;

/// Run a simulation with the engine the config asks for: the sharded
/// parallel engine when `[sim] threads > 1`, the single-threaded oracle
/// otherwise.  Callers needing engine extras (`stop_when`, custom budgets)
/// construct their engine directly.
pub fn run_config(cfg: &Config, graph: Arc<TaskGraph>) -> Result<SimResult, SimError> {
    if cfg.sim_threads > 1 {
        ParallelSimEngine::from_config(cfg, graph).run()
    } else {
        SimEngine::from_config(cfg, graph).run()
    }
}
