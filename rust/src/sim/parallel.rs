//! The sharded DES coordinator: conservative time-windowed parallel
//! execution over `Shard` workers.
//!
//! Synchronization protocol (classic conservative / lookahead-based PDES):
//!
//! 1. partition the P ranks into S contiguous shards
//!    (`Topology::shard_partition` — node-aligned on clusters);
//! 2. derive the lookahead L = `NetworkModel::min_cross_shard_delay`, a
//!    lower bound on the delay of *any* message crossing a shard boundary;
//! 3. repeat: find the earliest pending event time `t_next` anywhere, run
//!    every shard concurrently up to the horizon `t_next + L` (strict `<`),
//!    then exchange the cross-shard flights produced during the window and
//!    advance.
//!
//! Safety: a cross-shard message sent inside the window (at `t ≥ t_next`)
//! arrives at `t + delay ≥ t_next + L` — at or past the horizon — so no
//! shard can dispatch an event that a message it has not yet seen could
//! precede.  Combined with the engine's parallel-stable event keys
//! (`emit × P + rank`), every shard dispatches exactly the subsequence of
//! the single-threaded (time, key) order it owns, and the run is
//! bit-identical to `SimEngine`: same makespan, same counters, same
//! fingerprints.  The only intentional deviations: `peak_pending_events`
//! is the sum of per-shard peaks (an upper bound on the true global
//! high-water mark), budget errors are window-granular, and `stop_when`
//! is unsupported (callers needing early-stop predicates use `SimEngine`).

use std::sync::mpsc;
use std::sync::Arc;

use crate::config::Config;
use crate::core::graph::TaskGraph;
use crate::core::ids::ProcessId;
use crate::core::process::{Effect, ProcessParams, ProcessState};
use crate::metrics::counters::DlbCounters;
use crate::metrics::recorder::RunTrace;
use crate::metrics::trace::RunTraces;
use crate::sim::engine::{SimError, SimResult};
use crate::sim::network::NetworkModel;
use crate::sim::shard::{OutFlight, Shard, ShardReport};

/// One barrier-to-barrier work order for a shard worker.
struct WindowCmd {
    horizon: f64,
    inbox: Vec<OutFlight>,
}

/// The parallel simulator.  Same construction surface as `SimEngine`;
/// dispatch between the two lives in `sim::run_config`.
pub struct ParallelSimEngine {
    shards: Vec<Shard>,
    /// Conservative window length (∞ when only one shard is populated —
    /// then the whole run is a single window and the worker just drains).
    lookahead: f64,
    p: usize,
    graph: Arc<TaskGraph>,
    flops_per_sec: f64,
    pub max_events: u64,
    pub max_time: f64,
}

impl ParallelSimEngine {
    pub fn from_config(cfg: &Config, graph: Arc<TaskGraph>) -> Self {
        let params = ProcessParams::from_config(cfg);
        let p = cfg.processes;
        let threads = cfg.sim_threads.clamp(1, p.max(1));
        let topo = cfg.build_topology();
        let shard_of = Arc::new(topo.shard_partition(p, threads));
        let network =
            NetworkModel::with_topology(cfg.net_latency, cfg.doubles_per_sec, topo);
        let lookahead = network.min_cross_shard_delay(&shard_of).unwrap_or(f64::INFINITY);
        debug_assert!(
            cfg.exec_jitter == 0.0,
            "Config::validate rejects exec_jitter > 0 under sim.threads > 1"
        );
        // Shard ids from the partition are contiguous and all populated.
        let n = shard_of.last().map_or(0, |&s| s as usize + 1).max(1);
        let flops_per_sec = params.cost.flops_per_sec;
        let mut shards = Vec::with_capacity(n);
        let mut lo = 0usize;
        for sid in 0..n {
            let hi = shard_of.iter().filter(|&&s| s <= sid as u32).count();
            let procs: Vec<ProcessState> = (lo..hi)
                .map(|r| {
                    ProcessState::new(
                        ProcessId(r as u32),
                        p,
                        Arc::clone(&graph),
                        params.clone(),
                        cfg.seed,
                    )
                })
                .collect();
            shards.push(Shard::new(
                sid as u32,
                lo,
                procs,
                p,
                network.clone(),
                Arc::clone(&shard_of),
                cfg.coalesce,
                n,
            ));
            lo = hi;
        }
        ParallelSimEngine {
            shards,
            lookahead,
            p,
            graph,
            flops_per_sec,
            max_events: 500_000_000,
            max_time: f64::INFINITY,
        }
    }

    /// Run to completion; bit-identical results to `SimEngine::run` (see
    /// module docs for the two intentional deviations).
    pub fn run(&mut self) -> Result<SimResult, SimError> {
        let n = self.shards.len();
        let shards_in = std::mem::take(&mut self.shards);
        let lookahead = self.lookahead;
        let max_time = self.max_time;
        let max_events = self.max_events;

        let outcome: Result<(Vec<Shard>, u64), SimError> = std::thread::scope(|scope| {
            let mut cmd_txs: Vec<mpsc::Sender<WindowCmd>> = Vec::with_capacity(n);
            let mut rep_rxs: Vec<mpsc::Receiver<ShardReport>> = Vec::with_capacity(n);
            let (shard_tx, shard_rx) = mpsc::channel::<Shard>();
            for mut shard in shards_in {
                let (cmd_tx, cmd_rx) = mpsc::channel::<WindowCmd>();
                let (rep_tx, rep_rx) = mpsc::channel::<ShardReport>();
                cmd_txs.push(cmd_tx);
                rep_rxs.push(rep_rx);
                let shard_tx = shard_tx.clone();
                scope.spawn(move || {
                    // One effects scratch buffer per worker for the whole
                    // run, mirroring the single-threaded engine.
                    let mut effects: Vec<Effect> = Vec::with_capacity(64);
                    shard.boot(&mut effects);
                    let mut alive = rep_tx.send(shard.take_report()).is_ok();
                    while alive {
                        // Sender dropped = coordinator is done (or bailed
                        // on a budget error): hand the shard back.
                        let Ok(cmd) = cmd_rx.recv() else { break };
                        shard.run_window(cmd.horizon, cmd.inbox, &mut effects);
                        alive = rep_tx.send(shard.take_report()).is_ok();
                    }
                    let _ = shard_tx.send(shard);
                });
            }
            drop(shard_tx);

            let mut pending: Vec<Vec<OutFlight>> = (0..n).map(|_| Vec::new()).collect();
            let mut nexts: Vec<Option<f64>> = vec![None; n];
            let mut shard_events = vec![0u64; n];
            let mut shard_live = vec![0usize; n];
            // Post-boot and per-barrier: collect in shard order so routing
            // is deterministic (keys make pop order independent of it, but
            // determinism in the transport layer costs nothing).
            for i in 0..n {
                let r = rep_rxs[i].recv().expect("shard worker alive");
                for (dst, v) in r.outboxes {
                    pending[dst].extend(v);
                }
                nexts[i] = r.next_time;
                shard_events[i] = r.events;
                shard_live[i] = r.live;
            }
            loop {
                let mut t_next = f64::INFINITY;
                for nt in nexts.iter().flatten() {
                    t_next = t_next.min(*nt);
                }
                for inbox in &pending {
                    for of in inbox {
                        t_next = t_next.min(of.t);
                    }
                }
                if !t_next.is_finite() {
                    break;
                }
                if t_next > max_time {
                    drop(cmd_txs);
                    return Err(SimError::TimeBudget(t_next));
                }
                let horizon = t_next + lookahead;
                for (i, tx) in cmd_txs.iter().enumerate() {
                    let inbox = std::mem::take(&mut pending[i]);
                    tx.send(WindowCmd { horizon, inbox }).expect("shard worker alive");
                }
                for i in 0..n {
                    let r = rep_rxs[i].recv().expect("shard worker alive");
                    for (dst, v) in r.outboxes {
                        pending[dst].extend(v);
                    }
                    nexts[i] = r.next_time;
                    shard_events[i] = r.events;
                    shard_live[i] = r.live;
                }
                let events: u64 = shard_events.iter().sum();
                if events > max_events {
                    drop(cmd_txs);
                    return Err(SimError::EventBudget(events));
                }
            }
            drop(cmd_txs);
            let mut out: Vec<Shard> = shard_rx.iter().collect();
            out.sort_by_key(|s| s.id);
            let live: usize = shard_live.iter().sum();
            if live > 0 {
                return Err(SimError::Deadlock { live });
            }
            Ok((out, shard_events.iter().sum()))
        });

        let (shards, events) = outcome?;
        let result = Self::collect(self.p, &self.graph, self.flops_per_sec, &shards, events);
        self.shards = shards;
        Ok(result)
    }

    /// `SimEngine::collect`, reassembled from the shards in rank order.
    fn collect(
        p: usize,
        graph: &TaskGraph,
        flops_per_sec: f64,
        shards: &[Shard],
        events: u64,
    ) -> SimResult {
        let mut traces = RunTraces::new(p);
        let mut counters = DlbCounters::default();
        let mut per = Vec::with_capacity(p);
        let mut trace = RunTrace::new(p);
        let mut makespan: f64 = 0.0;
        let mut end_time: f64 = 0.0;
        let mut peak = 0usize;
        for s in shards {
            end_time = end_time.max(s.now);
            peak += s.peak_pending;
            for (k, ps) in s.procs.iter().enumerate() {
                let i = s.lo + k;
                makespan = makespan.max(ps.last_completion);
                counters.merge(ps.counters());
                per.push(*ps.counters());
                traces.per_process[i] = ps.trace.clone();
                if ps.recorder.is_on() {
                    trace.per_process[i] = ps.recorder.events().to_vec();
                }
            }
        }
        traces.makespan = makespan;
        let total_flops: u64 = graph.total_flops();
        let utilization = if makespan > 0.0 {
            total_flops as f64 / (p as f64 * flops_per_sec * makespan)
        } else {
            0.0
        };
        SimResult {
            makespan,
            end_time,
            traces,
            trace,
            counters,
            per_process_counters: per,
            events_processed: events,
            peak_pending_events: peak,
            utilization,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::graph::GraphBuilder;
    use crate::core::task::TaskKind;
    use crate::sim::engine::SimEngine;

    /// Independent tasks all homed on p0 — heavy migration traffic, the
    /// worst case for cross-shard determinism.
    fn bag_cfg(n: usize, p: usize, seed: u64, threads: usize) -> (Config, Arc<TaskGraph>) {
        let mut cfg = Config::default();
        cfg.processes = p;
        cfg.dlb_enabled = true;
        cfg.wt = 3;
        cfg.delta = 0.0005;
        cfg.seed = seed;
        cfg.sim_threads = threads;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            let d = b.data(ProcessId(0), 256, 256);
            b.task(TaskKind::Synthetic, vec![], d, 440_000_000, None);
        }
        (cfg, b.build())
    }

    fn assert_bit_identical(par: &SimResult, single: &SimResult) {
        assert_eq!(par.makespan.to_bits(), single.makespan.to_bits(), "makespan drifted");
        assert_eq!(par.end_time.to_bits(), single.end_time.to_bits(), "end_time drifted");
        assert_eq!(par.events_processed, single.events_processed, "event count drifted");
        assert_eq!(par.counters, single.counters, "aggregate counters drifted");
        assert_eq!(par.per_process_counters, single.per_process_counters, "per-rank drift");
    }

    #[test]
    fn sharded_bag_is_bit_identical_to_single_thread() {
        for threads in [2, 3] {
            let (cfg, g) = bag_cfg(32, 4, 7, threads);
            let single = {
                let mut c1 = cfg.clone();
                c1.sim_threads = 1;
                SimEngine::from_config(&c1, Arc::clone(&g)).run().expect("single")
            };
            let par = ParallelSimEngine::from_config(&cfg, g).run().expect("parallel");
            assert_bit_identical(&par, &single);
            assert!(par.counters.tasks_exported > 0, "work must migrate across shards");
        }
    }

    #[test]
    fn sharded_run_with_coalescing_is_bit_identical() {
        let (mut cfg, g) = bag_cfg(32, 4, 11, 2);
        cfg.coalesce = true;
        let single = {
            let mut c1 = cfg.clone();
            c1.sim_threads = 1;
            SimEngine::from_config(&c1, Arc::clone(&g)).run().expect("single")
        };
        let par = ParallelSimEngine::from_config(&cfg, g).run().expect("parallel");
        assert_bit_identical(&par, &single);
        assert!(par.counters.messages_coalesced > 0);
    }

    #[test]
    fn single_shard_degenerates_to_one_window() {
        // threads = 1 through the parallel path: lookahead is ∞, the whole
        // run is one window, results still match the oracle.
        let (cfg, g) = bag_cfg(16, 4, 5, 1);
        let single = SimEngine::from_config(&cfg, Arc::clone(&g)).run().expect("single");
        let par = ParallelSimEngine::from_config(&cfg, g).run().expect("parallel");
        assert_bit_identical(&par, &single);
    }

    #[test]
    fn parallel_event_budget_guard() {
        let (cfg, g) = bag_cfg(16, 4, 5, 2);
        let mut eng = ParallelSimEngine::from_config(&cfg, g);
        eng.max_events = 10;
        assert!(matches!(eng.run(), Err(SimError::EventBudget(_))));
    }

    #[test]
    fn chain_across_shards_terminates() {
        // A dependency chain alternating between ranks in different shards:
        // every hand-off crosses the barrier.
        let mut cfg = Config::default();
        cfg.processes = 2;
        cfg.dlb_enabled = false;
        cfg.sim_threads = 2;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        let mut prev = None;
        for i in 0..10 {
            let home = ProcessId((i % 2) as u32);
            let d = b.data(home, 64, 64);
            let args = match prev {
                Some(pd) => vec![pd],
                None => vec![],
            };
            b.task(TaskKind::Synthetic, args, d, 1_000_000, None);
            prev = Some(d);
        }
        let g = b.build();
        let single = {
            let mut c1 = cfg.clone();
            c1.sim_threads = 1;
            SimEngine::from_config(&c1, Arc::clone(&g)).run().expect("single")
        };
        let par = ParallelSimEngine::from_config(&cfg, g).run().expect("parallel");
        assert_bit_identical(&par, &single);
        assert!(par.makespan > 0.0);
    }
}
