//! The sharded DES coordinator: conservative time-windowed parallel
//! execution over `Shard` workers.
//!
//! Synchronization protocol (classic conservative / lookahead-based PDES,
//! distance-aware since PR 10):
//!
//! 1. partition the P ranks into S contiguous shards
//!    (`Topology::shard_partition` — node-aligned on clusters,
//!    edge-cut-aware on graphs);
//! 2. precompute the S×S minimum inter-shard delay matrix
//!    `D = NetworkModel::cross_shard_delay_matrix`: the block-min hop
//!    distances **metric-closed over shard paths** (Floyd–Warshall on
//!    exact integer hops) × latency, size term at its zero bound — so
//!    `D[j][i]` lower-bounds the total delay of any message *chain*
//!    originating in shard j that reaches shard i, relays through gateway
//!    shards included, not just direct sends;
//! 3. repeat: snapshot each shard's earliest pending work
//!    `next_eff[j] = min(local queue next, earliest undelivered inbound
//!    flight)`, give shard i its own horizon
//!    `h_i = min( min_{j≠i} (next_eff[j] + D[j][i]),
//!                next_eff[i] + min_{j≠i} (D[i][j] + D[j][i]) )`,
//!    run the commanded shards concurrently up to their horizons
//!    (strict `<`), then exchange the cross-shard flights produced during
//!    the window and advance.
//!
//! Safety, per chain: any event that can still arrive at shard i starts
//! from some shard s's pending work at `t ≥ next_eff[s]` and traverses
//! shards `s → k₁ → … → i`, each leg paying at least its pairwise bound,
//! so by the closure's triangle inequality it arrives at
//! `≥ next_eff[s] + D[s][i]` when `s ≠ i`.  A chain shard i *itself*
//! provokes (s = i) must leave and come back, paying at least the closed
//! round trip through some peer — the echo term, which is why the horizon
//! carries it: without the cap, a shard whose peers are all drained
//! (`next_eff[j] = ∞`, e.g. DLB off with every remote task awaiting
//! cross-shard data) would get an unbounded horizon and simulate past the
//! replies its own outbox is about to provoke.  Correctly-rounded f64
//! `+`/`×` are weakly monotone, so the bounds survive rounding
//! bit-exactly in the engine's operating regime, and a strict `< h_i` pop
//! never dispatches an event a message shard i has not yet seen could
//! precede.  Combined with the engine's parallel-stable event keys
//! (`emit × P + rank`), every shard dispatches exactly the subsequence of
//! the single-threaded (time, key) order it owns, and the run is
//! bit-identical to `SimEngine`: same makespan, same counters, same
//! fingerprints.  The old global protocol (one `t_next + min L` horizon
//! for everyone) is the special case where every `D[j][i]` is collapsed
//! to the matrix minimum and every `next_eff[j]` to the global minimum —
//! kept selectable as `[sim] window = "scalar"` for A/B window counts.
//!
//! **Sparse barriers.**  A shard that cannot act this window — its inbox
//! is empty and its next local event is at or past its horizon — is not
//! sent a `WindowCmd` at all: its worker stays parked on the channel and
//! its cached report (next event time, cumulative events, live count)
//! remains valid because nothing on that shard can have changed.  On
//! topologies where the hot set is far from the rest, this removes the
//! per-window wakeup/report round-trip for every idle shard; with the
//! scalar protocol every shard is commanded every window.  Progress: the
//! shard holding the globally-earliest work always has
//! `h_i ≥ t_next + min L > t_next`, so at least one shard is commanded
//! each window (the degenerate `t + L == t` rounding case at extreme
//! magnitudes is answered with `SimError::Deadlock` instead of a
//! livelock).
//!
//! The only intentional deviations from the oracle: `peak_pending_events`
//! is the sum of per-shard peaks (an upper bound on the true global
//! high-water mark), budget errors are window-granular, `stop_when` is
//! unsupported (callers needing early-stop predicates use `SimEngine`),
//! and `SimResult::window` carries the barrier statistics (all-zero from
//! the single-threaded engine, and excluded from the bit-identity
//! contract — it describes the execution strategy, not the simulated
//! system).

use std::sync::mpsc;
use std::sync::Arc;

use crate::config::{Config, WindowMode};
use crate::core::graph::TaskGraph;
use crate::core::ids::ProcessId;
use crate::core::process::{Effect, ProcessParams, ProcessState};
use crate::metrics::counters::DlbCounters;
use crate::metrics::recorder::RunTrace;
use crate::metrics::trace::RunTraces;
use crate::sim::engine::{SimError, SimResult, WindowStats};
use crate::sim::network::{NetworkModel, ShardDelays};
use crate::sim::shard::{OutFlight, Shard, ShardReport};

/// One barrier-to-barrier work order for a shard worker.
struct WindowCmd {
    horizon: f64,
    inbox: Vec<OutFlight>,
}

/// The parallel simulator.  Same construction surface as `SimEngine`;
/// dispatch between the two lives in `sim::run_config`.
pub struct ParallelSimEngine {
    shards: Vec<Shard>,
    /// Scalar window length — the delay-matrix minimum (∞ when only one
    /// shard is populated: the whole run is then a single window and the
    /// worker just drains).  The `scalar` protocol's only lookahead; the
    /// `matrix` protocol's progress floor.
    lookahead: f64,
    /// Per-pair minimum inter-shard delays; `None` iff a single shard is
    /// populated.
    delays: Option<ShardDelays>,
    window_mode: WindowMode,
    p: usize,
    graph: Arc<TaskGraph>,
    flops_per_sec: f64,
    pub max_events: u64,
    pub max_time: f64,
    /// `WindowCmd`s dispatched to each shard by the last `run()` —
    /// observability for the sparse-barrier rule (an idle far shard should
    /// sit near zero while the hot shards track the window count).
    pub cmds_per_shard: Vec<u64>,
}

impl ParallelSimEngine {
    pub fn from_config(cfg: &Config, graph: Arc<TaskGraph>) -> Self {
        let params = ProcessParams::from_config(cfg);
        let p = cfg.processes;
        let threads = cfg.sim_threads.clamp(1, p.max(1));
        let topo = cfg.build_topology();
        let shard_of = Arc::new(topo.shard_partition(p, threads));
        let network =
            NetworkModel::with_topology(cfg.net_latency, cfg.doubles_per_sec, topo);
        let delays = network.cross_shard_delay_matrix(&shard_of);
        // The matrix minimum is bit-identical to the historical
        // `min_cross_shard_delay` scalar (same min hops, same expression).
        let lookahead = delays.as_ref().map_or(f64::INFINITY, ShardDelays::min_delay);
        debug_assert!(
            cfg.exec_jitter == 0.0,
            "Config::validate rejects exec_jitter > 0 under sim.threads > 1"
        );
        // Shard ids from the partition are contiguous and all populated.
        let n = shard_of.last().map_or(0, |&s| s as usize + 1).max(1);
        let flops_per_sec = params.cost.flops_per_sec;
        // Single pass over the partition for the block bounds (the ids are
        // non-decreasing, so each shard owns one contiguous rank interval).
        let mut counts = vec![0usize; n];
        for &s in shard_of.iter() {
            counts[s as usize] += 1;
        }
        let mut shards = Vec::with_capacity(n);
        let mut lo = 0usize;
        for (sid, &count) in counts.iter().enumerate() {
            let hi = lo + count;
            let procs: Vec<ProcessState> = (lo..hi)
                .map(|r| {
                    ProcessState::new(
                        ProcessId(r as u32),
                        p,
                        Arc::clone(&graph),
                        params.clone(),
                        cfg.seed,
                    )
                })
                .collect();
            shards.push(Shard::new(
                sid as u32,
                lo,
                procs,
                p,
                network.clone(),
                Arc::clone(&shard_of),
                cfg.coalesce,
                n,
            ));
            lo = hi;
        }
        ParallelSimEngine {
            shards,
            lookahead,
            delays,
            window_mode: cfg.sim_window,
            p,
            graph,
            flops_per_sec,
            max_events: 500_000_000,
            max_time: f64::INFINITY,
            cmds_per_shard: vec![0; n],
        }
    }

    /// Run to completion; bit-identical results to `SimEngine::run` (see
    /// module docs for the intentional deviations).
    pub fn run(&mut self) -> Result<SimResult, SimError> {
        let n = self.shards.len();
        let shards_in = std::mem::take(&mut self.shards);
        let lookahead = self.lookahead;
        let delays = self.delays.clone();
        let mode = self.window_mode;
        let max_time = self.max_time;
        let max_events = self.max_events;
        let mut stats = WindowStats::default();
        let mut cmds_per_shard = vec![0u64; n];

        let outcome: Result<(Vec<Shard>, u64), SimError> = std::thread::scope(|scope| {
            let mut cmd_txs: Vec<mpsc::Sender<WindowCmd>> = Vec::with_capacity(n);
            let mut rep_rxs: Vec<mpsc::Receiver<ShardReport>> = Vec::with_capacity(n);
            let (shard_tx, shard_rx) = mpsc::channel::<Shard>();
            for mut shard in shards_in {
                let (cmd_tx, cmd_rx) = mpsc::channel::<WindowCmd>();
                let (rep_tx, rep_rx) = mpsc::channel::<ShardReport>();
                cmd_txs.push(cmd_tx);
                rep_rxs.push(rep_rx);
                let shard_tx = shard_tx.clone();
                scope.spawn(move || {
                    // One effects scratch buffer per worker for the whole
                    // run, mirroring the single-threaded engine.
                    let mut effects: Vec<Effect> = Vec::with_capacity(64);
                    shard.boot(&mut effects);
                    let mut alive = rep_tx.send(shard.take_report()).is_ok();
                    while alive {
                        // Sender dropped = coordinator is done (or bailed
                        // on a budget error): hand the shard back.
                        let Ok(cmd) = cmd_rx.recv() else { break };
                        shard.run_window(cmd.horizon, cmd.inbox, &mut effects);
                        alive = rep_tx.send(shard.take_report()).is_ok();
                    }
                    let _ = shard_tx.send(shard);
                });
            }
            drop(shard_tx);

            // Undelivered cross-shard flights per destination, plus the
            // earliest arrival among them — maintained incrementally as
            // outboxes are routed (never re-scanned per window).
            let mut pending: Vec<Vec<OutFlight>> = (0..n).map(|_| Vec::new()).collect();
            let mut pending_min = vec![f64::INFINITY; n];
            let mut nexts: Vec<Option<f64>> = vec![None; n];
            let mut shard_events = vec![0u64; n];
            let mut shard_live = vec![0usize; n];
            let mut route = |r: ShardReport,
                             i: usize,
                             pending: &mut Vec<Vec<OutFlight>>,
                             pending_min: &mut Vec<f64>,
                             nexts: &mut Vec<Option<f64>>,
                             shard_events: &mut Vec<u64>,
                             shard_live: &mut Vec<usize>| {
                for (dst, v) in r.outboxes {
                    for of in &v {
                        if of.t < pending_min[dst] {
                            pending_min[dst] = of.t;
                        }
                    }
                    pending[dst].extend(v);
                }
                nexts[i] = r.next_time;
                shard_events[i] = r.events;
                shard_live[i] = r.live;
            };
            // Post-boot and per-barrier: collect in shard order so routing
            // is deterministic (keys make pop order independent of it, but
            // determinism in the transport layer costs nothing).
            for (i, rx) in rep_rxs.iter().enumerate() {
                let r = rx.recv().expect("shard worker alive");
                route(
                    r,
                    i,
                    &mut pending,
                    &mut pending_min,
                    &mut nexts,
                    &mut shard_events,
                    &mut shard_live,
                );
            }
            let mut horizons = vec![f64::INFINITY; n];
            let mut commanded = vec![false; n];
            loop {
                // Earliest pending work anywhere: a shard's local queue or
                // an undelivered flight parked at the coordinator.
                let mut t_next = f64::INFINITY;
                for i in 0..n {
                    let eff = nexts[i].unwrap_or(f64::INFINITY).min(pending_min[i]);
                    if eff < t_next {
                        t_next = eff;
                    }
                }
                if !t_next.is_finite() {
                    break;
                }
                if t_next > max_time {
                    drop(cmd_txs);
                    return Err(SimError::TimeBudget(t_next));
                }
                stats.windows += 1;
                match (mode, &delays) {
                    (WindowMode::Matrix, Some(d)) => {
                        // h_i = min over the other shards of the earliest
                        // time their next send could reach i, capped by the
                        // self-echo bound: a chain *provoked by shard i's
                        // own sends* leaves at ≥ eff_i and pays at least the
                        // closed round trip before a reply can return, a
                        // constraint the j≠i terms cannot see when the
                        // other shards are drained (eff_j = ∞).
                        for i in 0..n {
                            let eff_i =
                                nexts[i].unwrap_or(f64::INFINITY).min(pending_min[i]);
                            let mut h = eff_i + d.echo(i);
                            for j in 0..n {
                                if j == i {
                                    continue;
                                }
                                let eff =
                                    nexts[j].unwrap_or(f64::INFINITY).min(pending_min[j]);
                                if eff.is_finite() {
                                    let bound = eff + d.delay(j, i);
                                    if bound < h {
                                        h = bound;
                                    }
                                }
                            }
                            horizons[i] = h;
                        }
                    }
                    // Scalar protocol, and the single-populated-shard case
                    // (lookahead ∞): one global horizon for everyone.
                    _ => {
                        let h = t_next + lookahead;
                        if !(h > t_next) {
                            // t_next + L rounded back onto t_next: no event
                            // can ever clear the strict `<` pop — report it
                            // instead of spinning.
                            drop(cmd_txs);
                            return Err(SimError::Deadlock {
                                live: shard_live.iter().sum(),
                            });
                        }
                        horizons.iter_mut().for_each(|hi| *hi = h);
                    }
                }
                let mut sent_any = false;
                for i in 0..n {
                    // Sparse barrier: nothing to deliver and nothing the
                    // shard could pop below its horizon — the cached report
                    // is still exact, skip the round-trip.  (Matrix mode
                    // only: the scalar protocol is kept faithful to the
                    // original all-shards barrier for A/B comparison.)
                    let skip = mode == WindowMode::Matrix
                        && pending[i].is_empty()
                        && nexts[i].map_or(true, |t| t >= horizons[i]);
                    commanded[i] = !skip;
                    if skip {
                        stats.cmds_skipped += 1;
                        continue;
                    }
                    let inbox = std::mem::take(&mut pending[i]);
                    pending_min[i] = f64::INFINITY;
                    cmd_txs[i]
                        .send(WindowCmd { horizon: horizons[i], inbox })
                        .expect("shard worker alive");
                    stats.cmds_sent += 1;
                    cmds_per_shard[i] += 1;
                    sent_any = true;
                }
                if !sent_any {
                    // Every horizon rounded onto its shard's next event
                    // (possible only at extreme time magnitudes): no
                    // command can make progress.
                    drop(cmd_txs);
                    return Err(SimError::Deadlock { live: shard_live.iter().sum() });
                }
                for i in 0..n {
                    if !commanded[i] {
                        continue;
                    }
                    let r = rep_rxs[i].recv().expect("shard worker alive");
                    route(
                        r,
                        i,
                        &mut pending,
                        &mut pending_min,
                        &mut nexts,
                        &mut shard_events,
                        &mut shard_live,
                    );
                }
                let events: u64 = shard_events.iter().sum();
                if events > max_events {
                    drop(cmd_txs);
                    return Err(SimError::EventBudget(events));
                }
            }
            drop(cmd_txs);
            let mut out: Vec<Shard> = shard_rx.iter().collect();
            out.sort_by_key(|s| s.id);
            let live: usize = shard_live.iter().sum();
            if live > 0 {
                return Err(SimError::Deadlock { live });
            }
            Ok((out, shard_events.iter().sum()))
        });

        let (shards, events) = outcome?;
        let result =
            Self::collect(self.p, &self.graph, self.flops_per_sec, &shards, events, stats);
        self.shards = shards;
        self.cmds_per_shard = cmds_per_shard;
        Ok(result)
    }

    /// `SimEngine::collect`, reassembled from the shards in rank order.
    fn collect(
        p: usize,
        graph: &TaskGraph,
        flops_per_sec: f64,
        shards: &[Shard],
        events: u64,
        window: WindowStats,
    ) -> SimResult {
        let mut traces = RunTraces::new(p);
        let mut counters = DlbCounters::default();
        let mut per = Vec::with_capacity(p);
        let mut trace = RunTrace::new(p);
        let mut makespan: f64 = 0.0;
        let mut end_time: f64 = 0.0;
        let mut peak = 0usize;
        for s in shards {
            end_time = end_time.max(s.now);
            peak += s.peak_pending;
            for (k, ps) in s.procs.iter().enumerate() {
                let i = s.lo + k;
                makespan = makespan.max(ps.last_completion);
                counters.merge(ps.counters());
                per.push(*ps.counters());
                traces.per_process[i] = ps.trace.clone();
                if ps.recorder.is_on() {
                    trace.per_process[i] = ps.recorder.events().to_vec();
                }
            }
        }
        traces.makespan = makespan;
        let total_flops: u64 = graph.total_flops();
        let utilization = if makespan > 0.0 {
            total_flops as f64 / (p as f64 * flops_per_sec * makespan)
        } else {
            0.0
        };
        SimResult {
            makespan,
            end_time,
            traces,
            trace,
            counters,
            per_process_counters: per,
            events_processed: events,
            peak_pending_events: peak,
            utilization,
            window,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::core::graph::GraphBuilder;
    use crate::core::task::TaskKind;
    use crate::sim::engine::SimEngine;

    /// Independent tasks all homed on p0 — heavy migration traffic, the
    /// worst case for cross-shard determinism.
    fn bag_cfg(n: usize, p: usize, seed: u64, threads: usize) -> (Config, Arc<TaskGraph>) {
        let mut cfg = Config::default();
        cfg.processes = p;
        cfg.dlb_enabled = true;
        cfg.wt = 3;
        cfg.delta = 0.0005;
        cfg.seed = seed;
        cfg.sim_threads = threads;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            let d = b.data(ProcessId(0), 256, 256);
            b.task(TaskKind::Synthetic, vec![], d, 440_000_000, None);
        }
        (cfg, b.build())
    }

    fn assert_bit_identical(par: &SimResult, single: &SimResult) {
        assert_eq!(par.makespan.to_bits(), single.makespan.to_bits(), "makespan drifted");
        assert_eq!(par.end_time.to_bits(), single.end_time.to_bits(), "end_time drifted");
        assert_eq!(par.events_processed, single.events_processed, "event count drifted");
        assert_eq!(par.counters, single.counters, "aggregate counters drifted");
        assert_eq!(par.per_process_counters, single.per_process_counters, "per-rank drift");
    }

    #[test]
    fn sharded_bag_is_bit_identical_to_single_thread() {
        for threads in [2, 3] {
            let (cfg, g) = bag_cfg(32, 4, 7, threads);
            let single = {
                let mut c1 = cfg.clone();
                c1.sim_threads = 1;
                SimEngine::from_config(&c1, Arc::clone(&g)).run().expect("single")
            };
            let par = ParallelSimEngine::from_config(&cfg, g).run().expect("parallel");
            assert_bit_identical(&par, &single);
            assert!(par.counters.tasks_exported > 0, "work must migrate across shards");
            assert!(par.window.windows > 0 && par.window.cmds_sent > 0, "stats recorded");
            assert_eq!(single.window, WindowStats::default(), "oracle has no windows");
        }
    }

    #[test]
    fn scalar_window_mode_is_bit_identical_and_never_sparse() {
        let (mut cfg, g) = bag_cfg(32, 4, 7, 2);
        cfg.sim_window = WindowMode::Scalar;
        let single = {
            let mut c1 = cfg.clone();
            c1.sim_threads = 1;
            SimEngine::from_config(&c1, Arc::clone(&g)).run().expect("single")
        };
        let par = ParallelSimEngine::from_config(&cfg, g).run().expect("parallel");
        assert_bit_identical(&par, &single);
        assert_eq!(par.window.cmds_skipped, 0, "scalar barriers are dense");
        assert_eq!(
            par.window.cmds_sent,
            par.window.windows * 2,
            "every shard commanded every window"
        );
    }

    #[test]
    fn sharded_run_with_coalescing_is_bit_identical() {
        let (mut cfg, g) = bag_cfg(32, 4, 11, 2);
        cfg.coalesce = true;
        let single = {
            let mut c1 = cfg.clone();
            c1.sim_threads = 1;
            SimEngine::from_config(&c1, Arc::clone(&g)).run().expect("single")
        };
        let par = ParallelSimEngine::from_config(&cfg, g).run().expect("parallel");
        assert_bit_identical(&par, &single);
        assert!(par.counters.messages_coalesced > 0);
    }

    #[test]
    fn single_shard_degenerates_to_one_window() {
        // threads = 1 through the parallel path: lookahead is ∞, the whole
        // run is one window, results still match the oracle.
        let (cfg, g) = bag_cfg(16, 4, 5, 1);
        let single = SimEngine::from_config(&cfg, Arc::clone(&g)).run().expect("single");
        let par = ParallelSimEngine::from_config(&cfg, g).run().expect("parallel");
        assert_bit_identical(&par, &single);
    }

    #[test]
    fn parallel_event_budget_guard() {
        let (cfg, g) = bag_cfg(16, 4, 5, 2);
        let mut eng = ParallelSimEngine::from_config(&cfg, g);
        eng.max_events = 10;
        assert!(matches!(eng.run(), Err(SimError::EventBudget(_))));
    }

    #[test]
    fn chain_across_shards_terminates() {
        // A dependency chain alternating between ranks in different shards:
        // every hand-off crosses the barrier.
        let mut cfg = Config::default();
        cfg.processes = 2;
        cfg.dlb_enabled = false;
        cfg.sim_threads = 2;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        let mut prev = None;
        for i in 0..10 {
            let home = ProcessId((i % 2) as u32);
            let d = b.data(home, 64, 64);
            let args = match prev {
                Some(pd) => vec![pd],
                None => vec![],
            };
            b.task(TaskKind::Synthetic, args, d, 1_000_000, None);
            prev = Some(d);
        }
        let g = b.build();
        let single = {
            let mut c1 = cfg.clone();
            c1.sim_threads = 1;
            SimEngine::from_config(&c1, Arc::clone(&g)).run().expect("single")
        };
        let par = ParallelSimEngine::from_config(&cfg, g).run().expect("parallel");
        assert_bit_identical(&par, &single);
        assert!(par.makespan > 0.0);
    }

    /// Regression for the unbounded-horizon / self-echo bug: shard 1
    /// starts drained (its only task waits on cross-shard data, DLB off),
    /// so without the echo cap shard 0's horizon is ∞ at the first barrier
    /// and it simulates its entire independent local chain before the
    /// reply its own send provokes (A → B on the far shard → C back home)
    /// has arrived.  C then executes "in the past" on a rank the oracle
    /// keeps busy until well after C's arrival, and the makespan diverges.
    #[test]
    fn echo_capped_horizon_keeps_cross_shard_replies_causal() {
        let mut cfg = Config::default();
        cfg.processes = 2;
        cfg.dlb_enabled = false;
        cfg.sim_threads = 2;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        // Ping-pong: A (rank 0) → B (rank 1) → C (rank 0).
        let da = b.data(ProcessId(0), 64, 64);
        b.task(TaskKind::Synthetic, vec![], da, 1_000_000, None);
        let db = b.data(ProcessId(1), 64, 64);
        b.task(TaskKind::Synthetic, vec![da], db, 1_000_000, None);
        let dc = b.data(ProcessId(0), 64, 64);
        b.task(TaskKind::Synthetic, vec![db], dc, 1_000_000, None);
        // Independent serial chain keeping rank 0 busy long past C's
        // arrival — the work the reply must interleave with.
        let mut prev = None;
        for _ in 0..10 {
            let d = b.data(ProcessId(0), 64, 64);
            let args = match prev {
                Some(pd) => vec![pd],
                None => vec![],
            };
            b.task(TaskKind::Synthetic, args, d, 1_000_000, None);
            prev = Some(d);
        }
        let g = b.build();
        let single = {
            let mut c1 = cfg.clone();
            c1.sim_threads = 1;
            SimEngine::from_config(&c1, Arc::clone(&g)).run().expect("single")
        };
        let par = ParallelSimEngine::from_config(&cfg, g).run().expect("parallel");
        assert_bit_identical(&par, &single);
        assert!(par.window.windows > 1, "echo cap must bound the first window");
    }

    /// Chain of `len` tasks alternating between two home ranks.
    fn chain_graph(a: u32, b_rank: u32, len: usize) -> Arc<TaskGraph> {
        let mut b = GraphBuilder::new();
        let mut prev = None;
        for i in 0..len {
            let home = ProcessId(if i % 2 == 0 { a } else { b_rank });
            let d = b.data(home, 64, 64);
            let args = match prev {
                Some(pd) => vec![pd],
                None => vec![],
            };
            b.task(TaskKind::Synthetic, args, d, 1_000_000, None);
            prev = Some(d);
        }
        b.build()
    }

    /// Headline property of the distance-aware protocol: strictly fewer
    /// coordinator windows than the scalar-L barrier on a multi-hop
    /// topology at 3 shards, with bit-identical results.  The chain lives
    /// entirely inside shard 0, so under matrix horizons the drained idle
    /// shards never constrain it — only the self-echo cap does, which is
    /// a full round trip (2 hops) wide and swallows each link's local
    /// delivery in the same window; the scalar protocol crawls forward
    /// one 2 µs lookahead at a time, splitting every link in two.
    #[test]
    fn matrix_mode_takes_fewer_windows_than_scalar() {
        let mut cfg = Config::default();
        cfg.processes = 12;
        cfg.topology = TopologyKind::Ring;
        cfg.dlb_enabled = false;
        cfg.sim_threads = 3;
        cfg.validate().expect("valid");
        let g = chain_graph(0, 1, 10); // both homes in shard 0 = ranks [0..4)
        let single = {
            let mut c1 = cfg.clone();
            c1.sim_threads = 1;
            SimEngine::from_config(&c1, Arc::clone(&g)).run().expect("single")
        };
        let matrix =
            ParallelSimEngine::from_config(&cfg, Arc::clone(&g)).run().expect("matrix");
        let scalar = {
            let mut c2 = cfg.clone();
            c2.sim_window = WindowMode::Scalar;
            ParallelSimEngine::from_config(&c2, g).run().expect("scalar")
        };
        assert_bit_identical(&matrix, &single);
        assert_bit_identical(&scalar, &single);
        assert!(
            matrix.window.windows < scalar.window.windows,
            "matrix {} windows vs scalar {}",
            matrix.window.windows,
            scalar.window.windows
        );
        assert!(matrix.window.cmds_skipped > 0, "idle shards must be skipped");
        assert_eq!(scalar.window.cmds_skipped, 0);
        assert_eq!(scalar.window.cmds_sent, scalar.window.windows * 3);
    }

    /// Sparse-barrier rule, observed per shard: a ping-pong chain between
    /// shards 0 and 1 keeps them commanded nearly every window, while the
    /// far idle shard 2 is only woken for the terminal shutdown flights —
    /// and the run still terminates with oracle-identical results.
    #[test]
    fn idle_far_shard_is_skipped_while_ping_pong_terminates() {
        let mut cfg = Config::default();
        cfg.processes = 12;
        cfg.topology = TopologyKind::Ring;
        cfg.dlb_enabled = false;
        cfg.sim_threads = 3;
        cfg.validate().expect("valid");
        // Shards on ring-12: ranks [0..4), [4..8), [8..12).  The chain
        // alternates ranks 3 and 4 — every hand-off crosses shards 0↔1.
        let g = chain_graph(3, 4, 10);
        let single = {
            let mut c1 = cfg.clone();
            c1.sim_threads = 1;
            SimEngine::from_config(&c1, Arc::clone(&g)).run().expect("single")
        };
        let mut eng = ParallelSimEngine::from_config(&cfg, g);
        let par = eng.run().expect("parallel");
        assert_bit_identical(&par, &single);
        assert!(par.window.cmds_skipped > 0, "far shard must be skipped");
        let cmds = &eng.cmds_per_shard;
        assert!(
            cmds[2] < cmds[0] && cmds[2] < cmds[1],
            "far shard commanded {} times vs hot shards {}/{}",
            cmds[2],
            cmds[0],
            cmds[1]
        );
        assert!(
            cmds[2] * 2 < par.window.windows,
            "far shard woken {} of {} windows",
            cmds[2],
            par.window.windows
        );
        assert!(cmds[2] > 0, "the shutdown broadcast still reaches shard 2");
    }
}
