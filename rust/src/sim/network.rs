//! The simulated interconnect: per-message delivery time
//! `hops(from, to) × latency + doubles / bandwidth`.
//!
//! Contention is not modeled (links are infinitely parallel); the paper's
//! protocol keeps control traffic tiny (≤ 5 requests per δ per process) and
//! data traffic is charged at the same R that the §4 analysis uses, so the
//! quantities the experiments compare are preserved.  The topology term is
//! new relative to the paper: a `Flat` topology reproduces its uniform
//! single-hop network exactly, while ring/torus/cluster shapes make
//! far-apart pairs pay proportionally more — the regime where
//! neighbor-restricted balancers (diffusion) become competitive.

use crate::core::ids::ProcessId;
use crate::net::topology::Topology;

/// Latency/bandwidth model (R in doubles per second, as in §4), plus the
/// interconnect shape.  (Not `Copy`: graph-backed topologies carry an
/// `Arc`'d distance table — clone instead, it is cheap.)
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// Per-hop latency, seconds.
    pub latency: f64,
    pub doubles_per_sec: f64,
    pub topology: Topology,
}

impl NetworkModel {
    /// Uniform single-hop network (the paper's model).
    pub fn new(latency: f64, doubles_per_sec: f64) -> Self {
        Self::with_topology(latency, doubles_per_sec, Topology::Flat)
    }

    pub fn with_topology(latency: f64, doubles_per_sec: f64, topology: Topology) -> Self {
        assert!(latency >= 0.0 && doubles_per_sec > 0.0);
        NetworkModel { latency, doubles_per_sec, topology }
    }

    /// Wall time between send and delivery for a message of `doubles`,
    /// assuming a single hop (flat-topology shorthand).
    pub fn delivery_delay(&self, doubles: u64) -> f64 {
        self.latency + doubles as f64 / self.doubles_per_sec
    }

    /// Topology-aware delivery time: latency is paid per hop, bandwidth
    /// once (store-and-forward of small messages is dominated by the wire
    /// time of the single largest segment).
    ///
    /// This value doubles as the transport-coalescing key: two sends of one
    /// process step may share a delivery event iff they agree on
    /// `(destination, delay_between(..).to_bits())`.  Because the delay
    /// already contains the per-message size term, only same-size messages
    /// to the same destination can merge — coalescing never moves an
    /// arrival, it only removes scheduler events.
    pub fn delay_between(&self, from: ProcessId, to: ProcessId, doubles: u64) -> f64 {
        let hops = self.topology.hops(from, to).max(1);
        hops as f64 * self.latency + doubles as f64 / self.doubles_per_sec
    }

    /// Conservative lookahead for the sharded DES (`sim::parallel`): a lower
    /// bound on `delay_between` over every cross-shard pair under the given
    /// partition, taken at zero payload — `hops·latency ≤ hops·latency +
    /// doubles/R` for every message size.  `None` when fewer than two shards
    /// are populated (no cross-shard traffic; the window is unbounded).
    ///
    /// Safety: a message sent at `t ≥ t_window` crossing shards arrives at
    /// `t + delay ≥ t_window + lookahead` — correctly-rounded f64 `+`/`×`
    /// are weakly monotone, so the bound survives rounding bit-exactly and
    /// a strict `< horizon` pop never dispatches an event a future
    /// cross-shard arrival could precede.
    pub fn min_cross_shard_delay(&self, shard_of: &[u32]) -> Option<f64> {
        self.topology
            .min_cross_partition_hops(shard_of)
            .map(|hops| hops.max(1) as f64 * self.latency)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_is_latency_plus_transfer() {
        let n = NetworkModel::new(1e-6, 2.2e8);
        let d = n.delivery_delay(2_200_000);
        assert!((d - (1e-6 + 0.01)).abs() < 1e-12);
    }

    #[test]
    fn zero_size_is_pure_latency() {
        let n = NetworkModel::new(5e-6, 1e8);
        assert_eq!(n.delivery_delay(0), 5e-6);
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_rejected() {
        let _ = NetworkModel::new(0.0, 0.0);
    }

    #[test]
    fn flat_between_matches_single_hop() {
        let n = NetworkModel::new(2e-6, 1e8);
        assert_eq!(n.delay_between(ProcessId(0), ProcessId(5), 100), n.delivery_delay(100));
    }

    #[test]
    fn ring_charges_per_hop() {
        let n = NetworkModel::with_topology(1e-6, 1e8, Topology::Ring { len: 10 });
        let near = n.delay_between(ProcessId(0), ProcessId(1), 0);
        let far = n.delay_between(ProcessId(0), ProcessId(5), 0);
        assert!((near - 1e-6).abs() < 1e-15);
        assert!((far - 5e-6).abs() < 1e-15);
    }

    #[test]
    fn equal_size_messages_share_the_coalesce_delay() {
        // same pair + same size → bit-identical delay (the coalescing key);
        // a different size must produce a different delay
        let n = NetworkModel::with_topology(1e-6, 1e8, Topology::Ring { len: 8 });
        let a = n.delay_between(ProcessId(0), ProcessId(3), 8);
        let b = n.delay_between(ProcessId(0), ProcessId(3), 8);
        assert_eq!(a.to_bits(), b.to_bits());
        let c = n.delay_between(ProcessId(0), ProcessId(3), 9);
        assert_ne!(a.to_bits(), c.to_bits());
    }

    #[test]
    fn min_cross_shard_delay_lower_bounds_every_cross_pair() {
        let t = Topology::Cluster { nodes: 2, per_node: 4, inter_hops: 4 };
        let n = NetworkModel::with_topology(1e-6, 1e8, t.clone());
        let shard_of = t.shard_partition(8, 2); // node-aligned: [0,0,0,0,1,1,1,1]
        let la = n.min_cross_shard_delay(&shard_of).expect("two shards");
        assert!((la - 4e-6).abs() < 1e-18, "inter-node tier: {la}");
        for a in 0..8u32 {
            for b in 0..8u32 {
                if shard_of[a as usize] != shard_of[b as usize] {
                    let d = n.delay_between(ProcessId(a), ProcessId(b), 0);
                    assert!(d >= la, "pair ({a},{b}): {d} < lookahead {la}");
                }
            }
        }
        // single populated shard → unbounded window
        assert_eq!(n.min_cross_shard_delay(&[0, 0, 0]), None);
    }

    #[test]
    fn cluster_penalizes_inter_node() {
        let t = Topology::Cluster { nodes: 2, per_node: 5, inter_hops: 4 };
        let n = NetworkModel::with_topology(1e-6, 1e8, t);
        let intra = n.delay_between(ProcessId(0), ProcessId(4), 0);
        let inter = n.delay_between(ProcessId(0), ProcessId(5), 0);
        assert!(inter > 3.0 * intra);
    }
}
