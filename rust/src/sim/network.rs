//! The simulated interconnect: per-message delivery time
//! `hops(from, to) × latency + doubles / bandwidth`.
//!
//! Contention is not modeled (links are infinitely parallel); the paper's
//! protocol keeps control traffic tiny (≤ 5 requests per δ per process) and
//! data traffic is charged at the same R that the §4 analysis uses, so the
//! quantities the experiments compare are preserved.  The topology term is
//! new relative to the paper: a `Flat` topology reproduces its uniform
//! single-hop network exactly, while ring/torus/cluster shapes make
//! far-apart pairs pay proportionally more — the regime where
//! neighbor-restricted balancers (diffusion) become competitive.

use crate::core::ids::ProcessId;
use crate::net::topology::Topology;

/// Latency/bandwidth model (R in doubles per second, as in §4), plus the
/// interconnect shape.  (Not `Copy`: graph-backed topologies carry an
/// `Arc`'d distance table — clone instead, it is cheap.)
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// Per-hop latency, seconds.
    pub latency: f64,
    pub doubles_per_sec: f64,
    pub topology: Topology,
}

impl NetworkModel {
    /// Uniform single-hop network (the paper's model).
    pub fn new(latency: f64, doubles_per_sec: f64) -> Self {
        Self::with_topology(latency, doubles_per_sec, Topology::Flat)
    }

    pub fn with_topology(latency: f64, doubles_per_sec: f64, topology: Topology) -> Self {
        assert!(latency >= 0.0 && doubles_per_sec > 0.0);
        NetworkModel { latency, doubles_per_sec, topology }
    }

    /// Wall time between send and delivery for a message of `doubles`,
    /// assuming a single hop (flat-topology shorthand).
    pub fn delivery_delay(&self, doubles: u64) -> f64 {
        self.latency + doubles as f64 / self.doubles_per_sec
    }

    /// Topology-aware delivery time: latency is paid per hop, bandwidth
    /// once (store-and-forward of small messages is dominated by the wire
    /// time of the single largest segment).
    ///
    /// This value doubles as the transport-coalescing key: two sends of one
    /// process step may share a delivery event iff they agree on
    /// `(destination, delay_between(..).to_bits())`.  Because the delay
    /// already contains the per-message size term, only same-size messages
    /// to the same destination can merge — coalescing never moves an
    /// arrival, it only removes scheduler events.
    pub fn delay_between(&self, from: ProcessId, to: ProcessId, doubles: u64) -> f64 {
        let hops = self.topology.hops(from, to).max(1);
        hops as f64 * self.latency + doubles as f64 / self.doubles_per_sec
    }

    /// Conservative lookahead for the sharded DES (`sim::parallel`): a lower
    /// bound on `delay_between` over every cross-shard pair under the given
    /// partition, taken at zero payload — `hops·latency ≤ hops·latency +
    /// doubles/R` for every message size.  `None` when fewer than two shards
    /// are populated (no cross-shard traffic; the window is unbounded).
    ///
    /// Safety: a message sent at `t ≥ t_window` crossing shards arrives at
    /// `t + delay ≥ t_window + lookahead` — correctly-rounded f64 `+`/`×`
    /// are weakly monotone, so the bound survives rounding bit-exactly and
    /// a strict `< horizon` pop never dispatches an event a future
    /// cross-shard arrival could precede.
    pub fn min_cross_shard_delay(&self, shard_of: &[u32]) -> Option<f64> {
        self.topology
            .min_cross_partition_hops(shard_of)
            .map(|hops| hops.max(1) as f64 * self.latency)
    }

    /// Per-pair generalization of [`Self::min_cross_shard_delay`]: the S×S
    /// minimum delay matrix `D[j][i]` = (**metric closure** of the min hops
    /// between shard blocks) × latency, size term at its zero lower bound.
    ///
    /// The raw block-min matrix from
    /// [`Topology::cross_partition_hops_matrix`] lower-bounds *direct*
    /// sends only, and it need not satisfy the triangle inequality (a
    /// gateway shard with one rank near shard j's block and another near
    /// shard i's can have `L[j][k] + L[k][i] < L[j][i]`).  The horizon
    /// safety argument in `sim::parallel` must also cover *relayed* chains
    /// — j wakes k, k's induced send reaches i — whose total delay is a
    /// path sum, so each entry is closed over paths with Floyd–Warshall
    /// before use: `D[j][i] = min over shard paths of Σ hops`.  The
    /// closure runs on exact integer hops (S ≤ thread count, so S³ is
    /// trivial) and only then converts with the same single
    /// `hops as f64 × latency` rounding the scalar bound uses; a chain's
    /// simulated arrival accumulates `t ← fl(t + delay_m)` with every
    /// `delay_m ≥ fl(hops_m × latency)`, which weak monotonicity keeps at
    /// or above `fl(next_j + D[j][i])` in the non-degenerate regime the
    /// engine operates in (the `t + L == t` extreme-magnitude corner is
    /// answered by its Deadlock guard).
    ///
    /// Alongside the matrix, [`ShardDelays::echo`] gives each shard the
    /// closed round-trip bound `min_j≠i (D[i][j] + D[j][i])` — the
    /// earliest a chain *provoked by shard i's own sends* can return to i.
    /// `sim::parallel` caps every horizon with it; without the cap a
    /// shard whose peers are all drained would get an unbounded horizon
    /// and simulate past the replies its own outbox will provoke.
    ///
    /// Every entry ≥ the scalar bound (closure path sums are sums of
    /// entries ≥ the minimum), and the matrix minimum equals it
    /// bit-exactly (the minimum entry cannot be shortened by a two-leg
    /// path of entries each ≥ it).  `None` when fewer than two shards are
    /// populated.
    pub fn cross_shard_delay_matrix(&self, shard_of: &[u32]) -> Option<ShardDelays> {
        let hops = self.topology.cross_partition_hops_matrix(shard_of)?;
        let n = (hops.len() as f64).sqrt() as usize;
        debug_assert_eq!(n * n, hops.len());
        // Metric closure in exact integer arithmetic; u64 so `MAX`
        // (unpopulated, relays nothing) needs no overflow care.
        let mut h: Vec<u64> = hops
            .iter()
            .map(|&x| if x == u32::MAX { u64::MAX } else { x as u64 })
            .collect();
        for k in 0..n {
            for j in 0..n {
                let hjk = h[j * n + k];
                if hjk == u64::MAX {
                    continue;
                }
                for i in 0..n {
                    let hki = h[k * n + i];
                    if hki != u64::MAX && hjk + hki < h[j * n + i] {
                        h[j * n + i] = hjk + hki;
                    }
                }
            }
        }
        let to_delay = |x: u64| {
            if x == u64::MAX {
                // Unpopulated shard id: no rank can send from / to it,
                // so it never constrains a horizon.
                f64::INFINITY
            } else {
                x.max(1) as f64 * self.latency
            }
        };
        let delays: Vec<f64> = h
            .iter()
            .enumerate()
            .map(|(k, &x)| if k / n == k % n { 0.0 } else { to_delay(x) })
            .collect();
        let echo: Vec<f64> = (0..n)
            .map(|i| {
                let mut best = u64::MAX;
                for j in 0..n {
                    if j != i && h[i * n + j] != u64::MAX && h[j * n + i] != u64::MAX {
                        best = best.min(h[i * n + j] + h[j * n + i]);
                    }
                }
                to_delay(best)
            })
            .collect();
        Some(ShardDelays { n, delays, echo })
    }
}

/// Row-major S×S minimum inter-shard delay matrix (seconds, metric-closed
/// over shard paths), produced by
/// [`NetworkModel::cross_shard_delay_matrix`].  Diagonal 0, unpopulated
/// pairs `+∞`, all other entries strictly positive whenever latency is
/// (enforced by `Config::validate` for `--sim-threads > 1`).
#[derive(Debug, Clone)]
pub struct ShardDelays {
    n: usize,
    delays: Vec<f64>,
    /// Per-shard round-trip bound `min_j≠i (D[i][j] + D[j][i])`.
    echo: Vec<f64>,
}

impl ShardDelays {
    /// Number of shard slots (max shard id + 1, populated or not).
    pub fn shards(&self) -> usize {
        self.n
    }

    /// Minimum delay of any message chain originating in shard `from` —
    /// direct or relayed through other shards — that can reach shard `to`.
    pub fn delay(&self, from: usize, to: usize) -> f64 {
        self.delays[from * self.n + to]
    }

    /// Minimum round trip leaving shard `i` and returning: a lower bound
    /// on how long after its own earliest send a reply it provokes can
    /// arrive back.  `+∞` when no other shard is populated.
    pub fn echo(&self, i: usize) -> f64 {
        self.echo[i]
    }

    /// The matrix minimum over off-diagonal populated pairs — bit-identical
    /// to the old scalar `min_cross_shard_delay` bound.
    pub fn min_delay(&self) -> f64 {
        let mut m = f64::INFINITY;
        for j in 0..self.n {
            for i in 0..self.n {
                if i != j {
                    let d = self.delays[j * self.n + i];
                    if d < m {
                        m = d;
                    }
                }
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_is_latency_plus_transfer() {
        let n = NetworkModel::new(1e-6, 2.2e8);
        let d = n.delivery_delay(2_200_000);
        assert!((d - (1e-6 + 0.01)).abs() < 1e-12);
    }

    #[test]
    fn zero_size_is_pure_latency() {
        let n = NetworkModel::new(5e-6, 1e8);
        assert_eq!(n.delivery_delay(0), 5e-6);
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_rejected() {
        let _ = NetworkModel::new(0.0, 0.0);
    }

    #[test]
    fn flat_between_matches_single_hop() {
        let n = NetworkModel::new(2e-6, 1e8);
        assert_eq!(n.delay_between(ProcessId(0), ProcessId(5), 100), n.delivery_delay(100));
    }

    #[test]
    fn ring_charges_per_hop() {
        let n = NetworkModel::with_topology(1e-6, 1e8, Topology::Ring { len: 10 });
        let near = n.delay_between(ProcessId(0), ProcessId(1), 0);
        let far = n.delay_between(ProcessId(0), ProcessId(5), 0);
        assert!((near - 1e-6).abs() < 1e-15);
        assert!((far - 5e-6).abs() < 1e-15);
    }

    #[test]
    fn equal_size_messages_share_the_coalesce_delay() {
        // same pair + same size → bit-identical delay (the coalescing key);
        // a different size must produce a different delay
        let n = NetworkModel::with_topology(1e-6, 1e8, Topology::Ring { len: 8 });
        let a = n.delay_between(ProcessId(0), ProcessId(3), 8);
        let b = n.delay_between(ProcessId(0), ProcessId(3), 8);
        assert_eq!(a.to_bits(), b.to_bits());
        let c = n.delay_between(ProcessId(0), ProcessId(3), 9);
        assert_ne!(a.to_bits(), c.to_bits());
    }

    #[test]
    fn min_cross_shard_delay_lower_bounds_every_cross_pair() {
        let t = Topology::Cluster { nodes: 2, per_node: 4, inter_hops: 4 };
        let n = NetworkModel::with_topology(1e-6, 1e8, t.clone());
        let shard_of = t.shard_partition(8, 2); // node-aligned: [0,0,0,0,1,1,1,1]
        let la = n.min_cross_shard_delay(&shard_of).expect("two shards");
        assert!((la - 4e-6).abs() < 1e-18, "inter-node tier: {la}");
        for a in 0..8u32 {
            for b in 0..8u32 {
                if shard_of[a as usize] != shard_of[b as usize] {
                    let d = n.delay_between(ProcessId(a), ProcessId(b), 0);
                    assert!(d >= la, "pair ({a},{b}): {d} < lookahead {la}");
                }
            }
        }
        // single populated shard → unbounded window
        assert_eq!(n.min_cross_shard_delay(&[0, 0, 0]), None);
    }

    #[test]
    fn delay_matrix_symmetric_and_dominates_scalar() {
        // Every shape the sharded engine can see: the matrix must be
        // symmetric (hops is), entry-wise ≥ the scalar lookahead, finite on
        // populated pairs, and its minimum bit-identical to the scalar.
        let shapes = [
            Topology::Flat,
            Topology::Ring { len: 12 },
            Topology::Torus { rows: 3, cols: 4 },
            Topology::Cluster { nodes: 3, per_node: 4, inter_hops: 5 },
        ];
        for t in shapes {
            for shards in [2usize, 3, 4] {
                let n = NetworkModel::with_topology(1e-6, 1e8, t.clone());
                let shard_of = t.shard_partition(12, shards);
                let m = n.cross_shard_delay_matrix(&shard_of).expect("populated");
                let scalar = n.min_cross_shard_delay(&shard_of).expect("populated");
                assert_eq!(m.min_delay().to_bits(), scalar.to_bits(), "{t:?}/{shards}");
                for j in 0..m.shards() {
                    assert_eq!(m.delay(j, j), 0.0);
                    for i in 0..m.shards() {
                        assert_eq!(
                            m.delay(j, i).to_bits(),
                            m.delay(i, j).to_bits(),
                            "{t:?}/{shards} asymmetric at ({j},{i})"
                        );
                        if i != j {
                            let d = m.delay(j, i);
                            assert!(d.is_finite(), "{t:?}/{shards} ∞ at ({j},{i})");
                            assert!(d >= scalar, "{t:?}/{shards} entry {d} < scalar {scalar}");
                        }
                    }
                }
            }
        }
    }

    /// Reference closure: O(P²) block-min over all rank pairs, then
    /// Floyd–Warshall over the S×S integer hops — the oracle for what
    /// `cross_shard_delay_matrix` must produce.
    fn brute_closed_hops(t: &Topology, shard_of: &[u32], n: usize) -> Vec<u64> {
        let mut h = vec![u64::MAX; n * n];
        (0..n).for_each(|s| h[s * n + s] = 0);
        for (a, &sa) in shard_of.iter().enumerate() {
            for (b, &sb) in shard_of.iter().enumerate() {
                if sa != sb {
                    let e = &mut h[sa as usize * n + sb as usize];
                    *e = (*e).min(
                        t.hops(ProcessId(a as u32), ProcessId(b as u32)).max(1) as u64,
                    );
                }
            }
        }
        for k in 0..n {
            for j in 0..n {
                for i in 0..n {
                    if h[j * n + k] != u64::MAX && h[k * n + i] != u64::MAX {
                        let via = h[j * n + k] + h[k * n + i];
                        if via < h[j * n + i] {
                            h[j * n + i] = via;
                        }
                    }
                }
            }
        }
        h
    }

    #[test]
    fn delay_matrix_separates_far_ring_blocks() {
        // Ring of 16 in 4 contiguous blocks of 4: adjacent blocks touch at
        // 1 hop; opposite blocks ([0..4) vs [8..12)) are 5 hops apart
        // pairwise, closed to 2 via either adjacent block — a relayed
        // chain really can bridge them in two 1-hop legs, so 2 is the
        // honest lookahead, and it still doubles the scalar bound.
        let t = Topology::Ring { len: 16 };
        let n = NetworkModel::with_topology(1e-6, 1e8, t.clone());
        let shard_of = t.shard_partition(16, 4);
        let m = n.cross_shard_delay_matrix(&shard_of).expect("populated");
        assert!((m.delay(0, 1) - 1e-6).abs() < 1e-18);
        assert!((m.delay(0, 2) - 2e-6).abs() < 1e-18, "far pair: {}", m.delay(0, 2));
        assert!((m.delay(1, 3) - 2e-6).abs() < 1e-18);
        assert!(m.delay(0, 2) > m.delay(0, 1), "distance separation survives closure");
        // And every entry is exactly the closed min-hops × latency.
        let closed = brute_closed_hops(&t, &shard_of, 4);
        for j in 0..4 {
            for i in 0..4 {
                if i == j {
                    continue;
                }
                let want = closed[j * 4 + i] as f64 * 1e-6;
                assert_eq!(m.delay(j, i).to_bits(), want.to_bits(), "({j},{i})");
            }
        }
    }

    #[test]
    fn delay_matrix_is_metric_closed_with_echo_bounds() {
        use crate::net::graph::GraphTopo;
        use std::sync::Arc;
        // Path graph 0-1-…-8 in 3 blocks of 3: the raw block-min matrix
        // violates the triangle inequality (L(0,2) = d(2,6) = 4 while
        // L(0,1) + L(1,2) = 1 + 1 = 2) — exactly the gateway-relay case
        // the closure exists for.
        let edges: Vec<(usize, usize)> = (0..8).map(|i| (i, i + 1)).collect();
        let t = Topology::Graph(Arc::new(GraphTopo::from_edges(9, &edges, "path9").unwrap()));
        let lat = 1e-6;
        let nm = NetworkModel::with_topology(lat, 1e8, t.clone());
        let shard_of = vec![0u32, 0, 0, 1, 1, 1, 2, 2, 2];
        let m = nm.cross_shard_delay_matrix(&shard_of).expect("populated");
        assert_eq!(m.delay(0, 1).to_bits(), lat.to_bits());
        assert_eq!(m.delay(0, 2).to_bits(), (2.0 * lat).to_bits(), "closed via the gateway");
        for j in 0..3 {
            for k in 0..3 {
                for i in 0..3 {
                    assert!(
                        m.delay(j, i) <= m.delay(j, k) + m.delay(k, i) + 1e-18,
                        "triangle violated at ({j},{k},{i})"
                    );
                }
            }
        }
        // echo(i) = min round trip through any other shard.
        assert_eq!(m.echo(0).to_bits(), (2.0 * lat).to_bits());
        assert_eq!(m.echo(1).to_bits(), (2.0 * lat).to_bits());
        assert_eq!(m.echo(2).to_bits(), (2.0 * lat).to_bits());
        // Single populated peer gone: echo is unbounded only when no other
        // shard is populated — and then the whole matrix is None anyway.
        let gapped = nm.cross_shard_delay_matrix(&[0, 2, 2]).expect("two populated");
        assert!(gapped.echo(1).is_infinite(), "unpopulated id echoes nothing");
        assert!(gapped.echo(0).is_finite() && gapped.echo(2).is_finite());
    }

    #[test]
    fn delay_matrix_none_only_when_single_shard_populated() {
        let n = NetworkModel::new(1e-6, 1e8);
        assert!(n.cross_shard_delay_matrix(&[0, 0, 0]).is_none());
        assert!(n.cross_shard_delay_matrix(&[]).is_none());
        assert!(n.cross_shard_delay_matrix(&[0, 0, 1]).is_some());
        // Gap in shard ids: id 1 unpopulated → its rows/cols are ∞, but the
        // populated pair is finite and the matrix still exists.
        let m = n.cross_shard_delay_matrix(&[0, 2, 2]).expect("two populated");
        assert_eq!(m.shards(), 3);
        assert!(m.delay(0, 2).is_finite());
        assert!(m.delay(0, 1).is_infinite() && m.delay(1, 2).is_infinite());
    }

    #[test]
    fn cluster_penalizes_inter_node() {
        let t = Topology::Cluster { nodes: 2, per_node: 5, inter_hops: 4 };
        let n = NetworkModel::with_topology(1e-6, 1e8, t);
        let intra = n.delay_between(ProcessId(0), ProcessId(4), 0);
        let inter = n.delay_between(ProcessId(0), ProcessId(5), 0);
        assert!(inter > 3.0 * intra);
    }
}
