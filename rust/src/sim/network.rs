//! The simulated interconnect: per-message delivery time
//! `latency + doubles / bandwidth`.
//!
//! Contention is not modeled (links are infinitely parallel); the paper's
//! protocol keeps control traffic tiny (≤ 5 requests per δ per process) and
//! data traffic is charged at the same R that the §4 analysis uses, so the
//! quantities the experiments compare are preserved.

/// Latency/bandwidth model (R in doubles per second, as in §4).
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    pub latency: f64,
    pub doubles_per_sec: f64,
}

impl NetworkModel {
    pub fn new(latency: f64, doubles_per_sec: f64) -> Self {
        assert!(latency >= 0.0 && doubles_per_sec > 0.0);
        NetworkModel { latency, doubles_per_sec }
    }

    /// Wall time between send and delivery for a message of `doubles`.
    pub fn delivery_delay(&self, doubles: u64) -> f64 {
        self.latency + doubles as f64 / self.doubles_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_is_latency_plus_transfer() {
        let n = NetworkModel::new(1e-6, 2.2e8);
        let d = n.delivery_delay(2_200_000);
        assert!((d - (1e-6 + 0.01)).abs() < 1e-12);
    }

    #[test]
    fn zero_size_is_pure_latency() {
        let n = NetworkModel::new(5e-6, 1e8);
        assert_eq!(n.delivery_delay(0), 5e-6);
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_rejected() {
        let _ = NetworkModel::new(0.0, 0.0);
    }
}
