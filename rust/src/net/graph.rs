//! Arbitrary-graph interconnects backing `Topology::Graph`: CSR adjacency
//! plus an all-pairs BFS distance table, both built once per run.
//!
//! The four legacy shapes (`flat|ring|torus|cluster`) keep their closed-form
//! O(1) `hops` — a dense distance table at the bench's P = 65 536 Ring
//! frontier would be gigabytes — so only `GraphTopo` materializes the
//! table.  Everything the rest of the stack needs reads from these two
//! arrays: `hops` (one table lookup), diffusion's `neighbors` (one CSR
//! row), hierarchical stealing's distance shells (one table row,
//! counting-sorted), the parallel DES's cut-aware shard partition, and the
//! SOS diffusion policy's spectral bound (degree + adjacency).
//!
//! Construction validates what `Config::validate` promises the engines:
//! the graph is symmetric by construction (every edge is inserted both
//! ways), self-loop-free, and connected — a bad graph is an error here,
//! never a mid-run surprise.

use crate::util::rng::Rng;

/// Hard cap on graph-backed ranks: the dense distance table is `n² × 2`
/// bytes (32 MiB at the cap).  The closed-form legacy shapes cover the
/// larger scales.
pub const MAX_GRAPH_RANKS: usize = 4096;

/// An undirected, connected, simple graph in CSR form with its all-pairs
/// BFS distance table.  One rank per node.
#[derive(Clone, PartialEq, Eq)]
pub struct GraphTopo {
    n: usize,
    /// CSR row offsets, `n + 1` entries.
    xadj: Vec<u32>,
    /// CSR column indices; each row sorted ascending.
    adj: Vec<u32>,
    /// Row-major `n × n` hop distances.
    dist: Vec<u16>,
    diameter: u32,
    label: String,
}

impl std::fmt::Debug for GraphTopo {
    // The table is n² entries — summarize instead of dumping it.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GraphTopo")
            .field("label", &self.label)
            .field("n", &self.n)
            .field("edges", &(self.adj.len() / 2))
            .field("diameter", &self.diameter)
            .finish()
    }
}

impl GraphTopo {
    /// Build from an undirected edge list over nodes `0..n`.  Duplicate
    /// edges collapse; self-loops, out-of-range endpoints, and
    /// disconnected graphs are errors.
    pub fn from_edges(
        n: usize,
        edges: &[(usize, usize)],
        label: impl Into<String>,
    ) -> Result<GraphTopo, String> {
        let label = label.into();
        if n == 0 {
            return Err(format!("graph '{label}': must have at least one node"));
        }
        if n > MAX_GRAPH_RANKS {
            return Err(format!(
                "graph '{label}': {n} nodes exceeds the {MAX_GRAPH_RANKS}-rank cap \
                 (the distance table is dense; use a closed-form topology beyond it)"
            ));
        }
        let mut nbr: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(u, v) in edges {
            if u == v {
                return Err(format!("graph '{label}': self-loop at node {u}"));
            }
            if u >= n || v >= n {
                return Err(format!(
                    "graph '{label}': edge {u}-{v} exceeds node count {n}"
                ));
            }
            nbr[u].push(v as u32);
            nbr[v].push(u as u32);
        }
        for row in nbr.iter_mut() {
            row.sort_unstable();
            row.dedup();
        }
        let mut xadj: Vec<u32> = Vec::with_capacity(n + 1);
        xadj.push(0);
        let mut adj: Vec<u32> = Vec::new();
        for row in &nbr {
            adj.extend_from_slice(row);
            xadj.push(adj.len() as u32);
        }

        // All-pairs BFS.  n ≤ 4096 keeps every distance well inside u16.
        let mut dist = vec![u16::MAX; n * n];
        let mut diameter: u32 = 0;
        let mut queue: std::collections::VecDeque<u32> = std::collections::VecDeque::new();
        for s in 0..n {
            let row = &mut dist[s * n..(s + 1) * n];
            row[s] = 0;
            queue.clear();
            queue.push_back(s as u32);
            while let Some(u) = queue.pop_front() {
                let du = row[u as usize];
                let (lo, hi) = (xadj[u as usize] as usize, xadj[u as usize + 1] as usize);
                for &v in &adj[lo..hi] {
                    if row[v as usize] == u16::MAX {
                        row[v as usize] = du + 1;
                        queue.push_back(v);
                    }
                }
            }
            for (t, &d) in row.iter().enumerate() {
                if d == u16::MAX {
                    return Err(format!(
                        "graph '{label}': disconnected (node {t} unreachable from node {s})"
                    ));
                }
                diameter = diameter.max(d as u32);
            }
        }

        Ok(GraphTopo { n, xadj, adj, dist, diameter, label })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn diameter(&self) -> u32 {
        self.diameter
    }

    /// CSR neighbor row of node `i`, sorted ascending (empty when out of
    /// range).
    pub fn neighbors_of(&self, i: usize) -> &[u32] {
        if i >= self.n {
            return &[];
        }
        &self.adj[self.xadj[i] as usize..self.xadj[i + 1] as usize]
    }

    /// One row of the distance table (empty when out of range).
    pub fn dist_row(&self, i: usize) -> &[u16] {
        if i >= self.n {
            return &[];
        }
        &self.dist[i * self.n..(i + 1) * self.n]
    }

    /// Total hop metric: 0 iff `a == b`, table lookup in range, and 1 for
    /// out-of-range ranks — a plain fallback, **no** modulo aliasing onto
    /// in-shape slots (`Config::validate` rejects runs whose rank count
    /// differs from the node count, so this path is a misconfiguration
    /// guard only).
    pub fn hops(&self, a: usize, b: usize) -> u32 {
        if a == b {
            return 0;
        }
        if a < self.n && b < self.n {
            // connected ⇒ ≥ 1 for distinct nodes
            self.dist[a * self.n + b] as u32
        } else {
            1
        }
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n)
            .map(|i| (self.xadj[i + 1] - self.xadj[i]) as usize)
            .max()
            .unwrap_or(0)
    }

    /// Undirected edge list with `u < v`, ascending.
    fn edge_list(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.adj.len() / 2);
        for u in 0..self.n {
            for &v in self.neighbors_of(u) {
                if (u as u32) < v {
                    out.push((u as u32, v));
                }
            }
        }
        out
    }

    /// Partition `p` ranks into at most `shards` **contiguous** blocks,
    /// greedily nudging each block boundary (within half a block of the
    /// balanced split) to the position crossed by the fewest edges.  The
    /// sharded DES requires contiguous rank intervals (`sim::shard`
    /// addresses its ranks as `lo..hi`); within that constraint fewer cut
    /// edges means less cross-shard traffic per window.  Ties prefer the
    /// balanced position.  Returns `shard_of[rank]`, non-decreasing with
    /// no gaps in the shard ids.
    pub fn shard_partition(&self, p: usize, shards: usize) -> Vec<u32> {
        let shards = shards.clamp(1, p.max(1));
        let block = p.div_ceil(shards).max(1);
        let edges = self.edge_list();
        let slack = block / 2;
        let mut bounds: Vec<usize> = Vec::with_capacity(shards.saturating_sub(1));
        let mut prev = 0usize;
        for i in 1..shards {
            let init = (i * block).min(p);
            let lo = init.saturating_sub(slack).max(prev);
            let hi = (init + slack).min(p).max(lo);
            let mut best = (usize::MAX, usize::MAX, usize::MAX, lo);
            for cand in lo..=hi {
                let cut = edges
                    .iter()
                    .filter(|&&(u, v)| (u as usize) < cand && cand <= v as usize)
                    .count();
                let key = (cut, cand.abs_diff(init), cand, cand);
                if (key.0, key.1, key.2) < (best.0, best.1, best.2) {
                    best = key;
                }
            }
            prev = best.3;
            bounds.push(prev);
        }
        // Materialize, renumbering so coincident boundaries (empty blocks)
        // never leave a gap in the shard-id sequence.
        let mut out = vec![0u32; p];
        let mut id: u32 = 0;
        let mut start = 0usize;
        for &b in bounds.iter().chain(std::iter::once(&p)) {
            if b > start {
                for slot in out.iter_mut().take(b).skip(start) {
                    *slot = id;
                }
                id += 1;
                start = b;
            }
        }
        out
    }
}

/// Parse a whitespace/comma-separated `u-v` edge list (e.g. `"0-1 1-2
/// 2-0"`).  The node count is the largest endpoint + 1.
pub fn parse_edge_list(text: &str) -> Result<(usize, Vec<(usize, usize)>), String> {
    let mut edges: Vec<(usize, usize)> = Vec::new();
    let mut max_node = 0usize;
    for tok in text.split(|c: char| c.is_whitespace() || c == ',' || c == ';') {
        if tok.is_empty() {
            continue;
        }
        let (u, v) = tok
            .split_once('-')
            .ok_or_else(|| format!("edge '{tok}' is not of the form u-v"))?;
        let u: usize = u.trim().parse().map_err(|_| format!("bad node id in edge '{tok}'"))?;
        let v: usize = v.trim().parse().map_err(|_| format!("bad node id in edge '{tok}'"))?;
        max_node = max_node.max(u).max(v);
        edges.push((u, v));
    }
    if edges.is_empty() {
        return Err("edge list is empty".to_string());
    }
    Ok((max_node + 1, edges))
}

/// Canonical dragonfly: `g = a·h + 1` groups of `a` routers (intra-group
/// clique), each router with `h` global links assigned consecutively so
/// every group pair shares exactly one link, and `p` ranks per router
/// (intra-router clique; each router-level edge realized as same-slot rank
/// edges).  `n = (a·h + 1) · a · p`.
pub fn dragonfly(a: usize, p: usize, h: usize) -> Result<GraphTopo, String> {
    if a == 0 || p == 0 || h == 0 {
        return Err("dragonfly a,p,h must all be ≥ 1".to_string());
    }
    let g = a * h + 1;
    let routers = g * a;
    let n = routers * p;
    let label = format!("dragonfly{a}x{p}x{h}");
    let mut router_edges: Vec<(usize, usize)> = Vec::new();
    // intra-group router cliques
    for grp in 0..g {
        for r1 in 0..a {
            for r2 in (r1 + 1)..a {
                router_edges.push((grp * a + r1, grp * a + r2));
            }
        }
    }
    // one global link per group pair: group g1's (g2-g1-1)-th link slot to
    // group g2's (g-1-(g2-g1))-th — router = slot / h on each side
    for g1 in 0..g {
        for g2 in (g1 + 1)..g {
            let r1 = (g2 - g1 - 1) / h;
            let r2 = (g - 1 - (g2 - g1)) / h;
            router_edges.push((g1 * a + r1, g2 * a + r2));
        }
    }
    // expand routers to ranks
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for router in 0..routers {
        for s1 in 0..p {
            for s2 in (s1 + 1)..p {
                edges.push((router * p + s1, router * p + s2));
            }
        }
    }
    for &(ra, rb) in &router_edges {
        for s in 0..p {
            edges.push((ra * p + s, rb * p + s));
        }
    }
    GraphTopo::from_edges(n, &edges, label)
}

/// Two-level leaf–spine fold of a k-ary fat tree: `k` leaves of `k/2`
/// ranks each; ranks on one leaf form a clique, and every leaf pair is
/// joined by same-slot edges (any two ranks are ≤ 2 hops apart, the
/// uniform-bisection property the full folded Clos provides).  `n = k²/2`.
pub fn fat_tree(k: usize) -> Result<GraphTopo, String> {
    if k < 2 || k % 2 != 0 {
        return Err(format!("fattree k must be even and ≥ 2, got {k}"));
    }
    let per = k / 2;
    let n = k * per;
    let label = format!("fattree{k}");
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for leaf in 0..k {
        for s1 in 0..per {
            for s2 in (s1 + 1)..per {
                edges.push((leaf * per + s1, leaf * per + s2));
            }
        }
    }
    for l1 in 0..k {
        for l2 in (l1 + 1)..k {
            for s in 0..per {
                edges.push((l1 * per + s, l2 * per + s));
            }
        }
    }
    GraphTopo::from_edges(n, &edges, label)
}

/// Random d-regular graph on `n` nodes via the configuration model: pair
/// up `n·d` stubs under a seeded shuffle, retry (reseeding
/// deterministically) until the pairing is simple and connected.
pub fn random_regular(n: usize, d: usize, seed: u64) -> Result<GraphTopo, String> {
    if n < 2 {
        return Err(format!("randreg needs ≥ 2 nodes, got {n}"));
    }
    if d == 0 || d >= n {
        return Err(format!("randreg degree must satisfy 1 ≤ d < n, got d={d}, n={n}"));
    }
    if n * d % 2 != 0 {
        return Err(format!("randreg requires n·d even, got n={n}, d={d}"));
    }
    if d < 2 && n > 2 {
        return Err(format!("randreg d=1 is a disconnected matching for n={n} > 2"));
    }
    let label = format!("randreg{d}x{n}");
    for attempt in 0..256u64 {
        let mut rng = Rng::new(seed.wrapping_add(attempt.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
        let mut stubs: Vec<usize> = (0..n).flat_map(|v| std::iter::repeat(v).take(d)).collect();
        rng.shuffle(&mut stubs);
        let mut pairs: Vec<(usize, usize)> = stubs
            .chunks_exact(2)
            .map(|c| (c[0].min(c[1]), c[0].max(c[1])))
            .collect();
        if pairs.iter().any(|&(u, v)| u == v) {
            continue; // self-loop — redraw
        }
        pairs.sort_unstable();
        if pairs.windows(2).any(|w| w[0] == w[1]) {
            continue; // multi-edge — redraw
        }
        match GraphTopo::from_edges(n, &pairs, label.clone()) {
            Ok(g) => return Ok(g),
            Err(_) => continue, // disconnected — redraw
        }
    }
    Err(format!(
        "randreg{d}x{n}: no simple connected pairing found in 256 attempts (seed {seed})"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_graph_distances_and_diameter() {
        let g = GraphTopo::from_edges(4, &[(0, 1), (1, 2), (2, 3)], "path4").expect("path");
        assert_eq!(g.n(), 4);
        assert_eq!(g.diameter(), 3);
        assert_eq!(g.hops(0, 0), 0);
        assert_eq!(g.hops(0, 3), 3);
        assert_eq!(g.hops(3, 0), 3, "symmetric");
        assert_eq!(g.neighbors_of(1), &[0, 2]);
        assert_eq!(g.dist_row(0), &[0, 1, 2, 3]);
    }

    #[test]
    fn duplicate_edges_collapse() {
        let g =
            GraphTopo::from_edges(3, &[(0, 1), (1, 0), (1, 2), (1, 2)], "dup").expect("dedup");
        assert_eq!(g.neighbors_of(1), &[0, 2]);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn bad_graphs_are_errors_not_panics() {
        assert!(GraphTopo::from_edges(0, &[], "empty").is_err());
        assert!(GraphTopo::from_edges(3, &[(0, 0)], "loop").is_err());
        assert!(GraphTopo::from_edges(3, &[(0, 5)], "oob").is_err());
        // 2 components
        assert!(GraphTopo::from_edges(4, &[(0, 1), (2, 3)], "split").is_err());
        // isolated node
        assert!(GraphTopo::from_edges(3, &[(0, 1)], "stranded").is_err());
        assert!(GraphTopo::from_edges(MAX_GRAPH_RANKS + 1, &[(0, 1)], "huge").is_err());
    }

    #[test]
    fn single_node_graph_is_fine() {
        let g = GraphTopo::from_edges(1, &[], "lone").expect("n=1");
        assert_eq!(g.diameter(), 0);
        assert!(g.neighbors_of(0).is_empty());
    }

    #[test]
    fn out_of_range_hops_are_total_without_aliasing() {
        // 4-cycle: aliasing rank 4 onto slot 0 would answer hops(0,4) = 0;
        // the graph path must answer 1 (plain fallback) instead.
        let g = GraphTopo::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)], "c4").expect("c4");
        assert_eq!(g.hops(0, 4), 1);
        assert_eq!(g.hops(9, 2), 1);
        assert_eq!(g.hops(7, 7), 0, "self is 0 even out of range");
        assert!(g.neighbors_of(4).is_empty());
        assert!(g.dist_row(4).is_empty());
    }

    #[test]
    fn edge_list_parses_and_rejects() {
        let (n, edges) = parse_edge_list("0-1, 1-2\n2-0").expect("parse");
        assert_eq!(n, 3);
        assert_eq!(edges, vec![(0, 1), (1, 2), (2, 0)]);
        assert!(parse_edge_list("").is_err());
        assert!(parse_edge_list("0:1").is_err());
        assert!(parse_edge_list("0-x").is_err());
    }

    #[test]
    fn dragonfly_shape_and_connectivity() {
        // a=2, p=2, h=1: g = 3 groups × 2 routers × 2 ranks = 12 ranks
        let g = dragonfly(2, 2, 1).expect("dragonfly");
        assert_eq!(g.n(), 12);
        assert_eq!(g.label(), "dragonfly2x2x1");
        // rank 0 (group 0, router 0, slot 0): router-mate 1, same-slot in
        // router 1 of its group (rank 2), plus one global same-slot link
        assert!(g.neighbors_of(0).contains(&1));
        assert!(g.neighbors_of(0).contains(&2));
        assert!(g.diameter() >= 2 && g.diameter() <= 5, "diameter {}", g.diameter());
        assert!(dragonfly(0, 1, 1).is_err());
    }

    #[test]
    fn fat_tree_is_two_hop_everywhere() {
        let g = fat_tree(4).expect("fattree4"); // 4 leaves × 2 ranks
        assert_eq!(g.n(), 8);
        assert_eq!(g.diameter(), 2);
        assert_eq!(g.hops(0, 1), 1, "leaf-mates adjacent");
        assert_eq!(g.hops(0, 2), 1, "same slot across leaves adjacent");
        assert_eq!(g.hops(0, 3), 2, "different leaf, different slot");
        assert!(fat_tree(3).is_err(), "odd k rejected");
        assert!(fat_tree(0).is_err());
    }

    #[test]
    fn random_regular_has_uniform_degree_and_is_deterministic() {
        let g = random_regular(10, 3, 42).expect("randreg");
        assert_eq!(g.n(), 10);
        for i in 0..10 {
            assert_eq!(g.neighbors_of(i).len(), 3, "node {i} degree");
        }
        let h = random_regular(10, 3, 42).expect("again");
        assert_eq!(g, h, "same seed ⇒ same graph");
        let k = random_regular(10, 3, 43).expect("other seed");
        // almost surely a different pairing
        assert_ne!(g, k);
        assert!(random_regular(10, 0, 1).is_err());
        assert!(random_regular(10, 10, 1).is_err());
        assert!(random_regular(5, 3, 1).is_err(), "n·d odd");
        assert!(random_regular(6, 1, 1).is_err(), "d=1 matching disconnected");
    }

    #[test]
    fn shard_partition_contiguous_balanced_and_cut_aware() {
        // 8-cycle split into 2: any contiguous 2-split cuts exactly 2
        // edges; the partition must stay contiguous and cover all ranks.
        let ring = GraphTopo::from_edges(
            8,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7), (7, 0)],
            "c8",
        )
        .expect("c8");
        let shard_of = ring.shard_partition(8, 2);
        assert_eq!(shard_of.len(), 8);
        for w in shard_of.windows(2) {
            assert!(w[0] <= w[1], "non-decreasing: {shard_of:?}");
        }
        assert_eq!(*shard_of.last().expect("nonempty"), 1, "both shards populated");

        // Two 4-cliques joined by one bridge edge (3-4): the balanced
        // boundary is also the 1-edge cut, and the greedy pass must find it.
        let mut edges = vec![(3usize, 4usize)];
        for base in [0usize, 4] {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    edges.push((base + i, base + j));
                }
            }
        }
        let barbell = GraphTopo::from_edges(8, &edges, "barbell").expect("barbell");
        assert_eq!(barbell.shard_partition(8, 2), vec![0, 0, 0, 0, 1, 1, 1, 1]);

        // degenerate requests clamp instead of panicking
        assert_eq!(ring.shard_partition(4, 0), vec![0, 0, 0, 0]);
        assert!(ring.shard_partition(0, 3).is_empty());
        let ids = ring.shard_partition(8, 100);
        assert_eq!(ids, (0..8).map(|i| i as u32).collect::<Vec<_>>());
    }

    #[test]
    fn shard_ids_never_gap() {
        // A shape where a boundary could collapse onto its neighbor: ids
        // must stay 0..k contiguous for the parallel engine's counting.
        let path = GraphTopo::from_edges(3, &[(0, 1), (1, 2)], "p3").expect("p3");
        let shard_of = path.shard_partition(3, 3);
        let max = *shard_of.iter().max().expect("nonempty");
        for id in 0..=max {
            assert!(shard_of.contains(&id), "gap at shard {id}: {shard_of:?}");
        }
    }

    #[test]
    fn debug_is_compact() {
        let g = fat_tree(4).expect("fattree");
        let s = format!("{g:?}");
        assert!(s.contains("fattree4") && s.contains("diameter"));
        assert!(s.len() < 200, "must not dump the table: {s}");
    }
}
