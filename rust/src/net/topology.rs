//! Interconnect topology: who is "near" whom, and how many hops a message
//! crosses.
//!
//! Two consumers share one model:
//!
//! - the **DES network** (`sim::network`) and the threaded-mode `Shaper`
//!   charge `hops × latency` per message, so far-apart processes pay more
//!   for both control traffic and migrated task data;
//! - the **Diffusion balancer** (`dlb::policy::diffusion`) restricts its
//!   load exchange to `neighbors(me)`, the defining constraint of
//!   diffusion-based balancing (Demirel & Sbalzarini 2013) versus the
//!   paper's anywhere-to-anywhere random pairing.
//!
//! All variants carry their own dimensions so `hops`/`neighbors` need no
//! extra context; `Flat` reproduces the seed's uniform single-hop network
//! exactly.

use crate::core::ids::ProcessId;

/// A process interconnect shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Fully connected, uniform single-hop (the paper's implicit model).
    Flat,
    /// Bidirectional ring of `len` processes.
    Ring { len: usize },
    /// 2D torus, row-major `rows × cols`; hops = wraparound Manhattan
    /// distance.
    Torus { rows: usize, cols: usize },
    /// Two-level cluster: `nodes` groups of `per_node` consecutive ranks.
    /// Intra-node messages are one hop; inter-node messages cost
    /// `inter_hops` hops (NIC + switch + NIC).
    Cluster { nodes: usize, per_node: usize, inter_hops: u32 },
}

impl Topology {
    /// Hops between two processes (0 for self, ≥ 1 otherwise).
    pub fn hops(&self, from: ProcessId, to: ProcessId) -> u32 {
        if from == to {
            return 0;
        }
        match *self {
            Topology::Flat => 1,
            Topology::Ring { len } => {
                let a = from.idx() % len;
                let b = to.idx() % len;
                let d = a.abs_diff(b);
                d.min(len - d) as u32
            }
            Topology::Torus { rows, cols } => {
                let (r1, c1) = (from.idx() / cols, from.idx() % cols);
                let (r2, c2) = (to.idx() / cols, to.idx() % cols);
                let dr = r1.abs_diff(r2);
                let dc = c1.abs_diff(c2);
                (dr.min(rows - dr) + dc.min(cols - dc)) as u32
            }
            Topology::Cluster { per_node, inter_hops, .. } => {
                if from.idx() / per_node == to.idx() / per_node {
                    1
                } else {
                    inter_hops.max(1)
                }
            }
        }
    }

    /// The neighbor set diffusion exchanges load with.  Always symmetric
    /// (j ∈ N(i) ⇔ i ∈ N(j)), never contains `me`, sorted ascending.
    ///
    /// - flat: everyone else (diffusion degenerates to global averaging);
    /// - ring: the two adjacent ranks;
    /// - torus: the 4-neighborhood;
    /// - cluster: all same-node ranks plus the same-slot rank in the two
    ///   adjacent nodes (nodes form a ring), so load can leave a node.
    pub fn neighbors(&self, me: ProcessId, p: usize) -> Vec<ProcessId> {
        let m = me.idx();
        let mut out: Vec<usize> = Vec::new();
        if p >= 2 {
            match *self {
                Topology::Flat => {
                    out.extend((0..p).filter(|&i| i != m));
                }
                Topology::Ring { len } => {
                    let len = len.min(p).max(1);
                    if m < len {
                        out.push((m + 1) % len);
                        out.push((m + len - 1) % len);
                    }
                }
                Topology::Torus { rows, cols } => {
                    if m < rows * cols && rows * cols <= p {
                        let (r, c) = (m / cols, m % cols);
                        out.push(((r + 1) % rows) * cols + c);
                        out.push(((r + rows - 1) % rows) * cols + c);
                        out.push(r * cols + (c + 1) % cols);
                        out.push(r * cols + (c + cols - 1) % cols);
                    }
                }
                Topology::Cluster { nodes, per_node, .. } => {
                    if per_node > 0 && m < nodes * per_node && nodes * per_node <= p {
                        let node = m / per_node;
                        let slot = m % per_node;
                        for s in 0..per_node {
                            if s != slot {
                                out.push(node * per_node + s);
                            }
                        }
                        if nodes >= 2 {
                            out.push(((node + 1) % nodes) * per_node + slot);
                            out.push(((node + nodes - 1) % nodes) * per_node + slot);
                        }
                    }
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out.retain(|&i| i != m && i < p);
        out.into_iter().map(|i| ProcessId(i as u32)).collect()
    }

    /// Human-readable tag for tables and CSV.
    pub fn label(&self) -> String {
        match *self {
            Topology::Flat => "flat".to_string(),
            Topology::Ring { len } => format!("ring{len}"),
            Topology::Torus { rows, cols } => format!("torus{rows}x{cols}"),
            Topology::Cluster { nodes, per_node, .. } => format!("cluster{nodes}x{per_node}"),
        }
    }
}

impl Default for Topology {
    fn default() -> Self {
        Topology::Flat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(i: u32) -> ProcessId {
        ProcessId(i)
    }

    #[test]
    fn flat_is_single_hop_everyone() {
        let t = Topology::Flat;
        assert_eq!(t.hops(p(0), p(7)), 1);
        assert_eq!(t.hops(p(3), p(3)), 0);
        let n = t.neighbors(p(2), 5);
        assert_eq!(n, vec![p(0), p(1), p(3), p(4)]);
    }

    #[test]
    fn ring_distance_wraps() {
        let t = Topology::Ring { len: 8 };
        assert_eq!(t.hops(p(0), p(1)), 1);
        assert_eq!(t.hops(p(0), p(7)), 1);
        assert_eq!(t.hops(p(0), p(4)), 4);
        assert_eq!(t.hops(p(1), p(6)), 3);
        assert_eq!(t.neighbors(p(0), 8), vec![p(1), p(7)]);
        assert_eq!(t.neighbors(p(4), 8), vec![p(3), p(5)]);
    }

    #[test]
    fn ring_of_two_dedups() {
        let t = Topology::Ring { len: 2 };
        assert_eq!(t.neighbors(p(0), 2), vec![p(1)]);
        assert_eq!(t.hops(p(0), p(1)), 1);
    }

    #[test]
    fn torus_manhattan_wraps() {
        let t = Topology::Torus { rows: 3, cols: 4 };
        // rank = r*4 + c
        assert_eq!(t.hops(p(0), p(1)), 1); // (0,0)→(0,1)
        assert_eq!(t.hops(p(0), p(3)), 1); // (0,0)→(0,3) wraps
        assert_eq!(t.hops(p(0), p(8)), 1); // (0,0)→(2,0) wraps
        assert_eq!(t.hops(p(0), p(6)), 3); // (0,0)→(1,2): 1 + 2
        let n = t.neighbors(p(5), 12); // (1,1)
        assert_eq!(n, vec![p(1), p(4), p(6), p(9)]);
    }

    #[test]
    fn torus_neighbors_symmetric() {
        let t = Topology::Torus { rows: 3, cols: 3 };
        for i in 0..9u32 {
            for j in t.neighbors(p(i), 9) {
                assert!(
                    t.neighbors(j, 9).contains(&p(i)),
                    "asymmetric: {i} lists {j:?} but not vice versa"
                );
            }
        }
    }

    #[test]
    fn cluster_hops_two_level() {
        let t = Topology::Cluster { nodes: 2, per_node: 4, inter_hops: 4 };
        assert_eq!(t.hops(p(0), p(3)), 1); // same node
        assert_eq!(t.hops(p(0), p(4)), 4); // across
        assert_eq!(t.hops(p(5), p(1)), 4);
    }

    #[test]
    fn cluster_neighbors_include_gateway() {
        let t = Topology::Cluster { nodes: 2, per_node: 3, inter_hops: 4 };
        // rank 1 (node 0, slot 1): node-mates 0, 2; same slot in node 1 → 4
        assert_eq!(t.neighbors(p(1), 6), vec![p(0), p(2), p(4)]);
        // symmetry
        assert!(t.neighbors(p(4), 6).contains(&p(1)));
    }

    #[test]
    fn neighbors_never_self_and_connected() {
        for t in [
            Topology::Flat,
            Topology::Ring { len: 6 },
            Topology::Torus { rows: 2, cols: 3 },
            Topology::Cluster { nodes: 3, per_node: 2, inter_hops: 4 },
        ] {
            // BFS from 0 must reach everyone (diffusion needs connectivity)
            let p_n = 6;
            let mut seen = vec![false; p_n];
            let mut stack = vec![0usize];
            seen[0] = true;
            while let Some(i) = stack.pop() {
                assert!(!t.neighbors(p(i as u32), p_n).contains(&p(i as u32)));
                for q in t.neighbors(p(i as u32), p_n) {
                    if !seen[q.idx()] {
                        seen[q.idx()] = true;
                        stack.push(q.idx());
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "{t:?} disconnected");
        }
    }

    #[test]
    fn single_process_has_no_neighbors() {
        for t in [Topology::Flat, Topology::Ring { len: 1 }] {
            assert!(t.neighbors(p(0), 1).is_empty());
        }
    }
}
