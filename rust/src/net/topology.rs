//! Interconnect topology: who is "near" whom, and how many hops a message
//! crosses.
//!
//! Two consumers share one model:
//!
//! - the **DES network** (`sim::network`) and the threaded-mode `Shaper`
//!   charge `hops × latency` per message, so far-apart processes pay more
//!   for both control traffic and migrated task data;
//! - the **Diffusion balancer** (`dlb::policy::diffusion`) restricts its
//!   load exchange to `neighbors(me)`, the defining constraint of
//!   diffusion-based balancing (Demirel & Sbalzarini 2013) versus the
//!   paper's anywhere-to-anywhere random pairing.
//!
//! All variants carry their own dimensions so `hops`/`neighbors` need no
//! extra context; `Flat` reproduces the seed's uniform single-hop network
//! exactly.  `Graph` generalizes the closed shapes to arbitrary connected
//! graphs (`net::graph`: dragonfly / fat-tree / random-regular generators
//! or a config-loaded edge list) answering from a CSR adjacency and a
//! precomputed all-pairs BFS distance table; cloning shares the table via
//! `Arc`, so a `Topology` stays cheap to pass around.

use std::sync::Arc;

use crate::core::ids::ProcessId;
use crate::util::rng::Rng;

use super::graph::GraphTopo;

/// A process interconnect shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Topology {
    /// Fully connected, uniform single-hop (the paper's implicit model).
    Flat,
    /// Bidirectional ring of `len` processes.
    Ring { len: usize },
    /// 2D torus, row-major `rows × cols`; hops = wraparound Manhattan
    /// distance.
    Torus { rows: usize, cols: usize },
    /// Two-level cluster: `nodes` groups of `per_node` consecutive ranks.
    /// Intra-node messages are one hop; inter-node messages cost
    /// `inter_hops` hops (NIC + switch + NIC).
    Cluster { nodes: usize, per_node: usize, inter_hops: u32 },
    /// Arbitrary connected graph, one rank per node; hops = BFS distance
    /// from the precomputed table.
    Graph(Arc<GraphTopo>),
}

impl Topology {
    /// Hops between two processes — **total**: 0 iff `from == to`, ≥ 1 for
    /// every distinct pair, for every shape and every rank.
    ///
    /// Ranks outside a legacy shape's dimensions are reduced modulo the
    /// slot count first; when two *distinct* ranks alias to the same slot
    /// the distance is still 1, never 0 — a message between two real
    /// processes always crosses the wire.  `Graph` never aliases: ranks
    /// beyond the node count answer a plain 1.  (`Config::validate`
    /// rejects shapes whose dimensions do not cover `run.processes`, so
    /// either fallback is a misconfiguration guard, not a steady-state
    /// code path.)
    pub fn hops(&self, from: ProcessId, to: ProcessId) -> u32 {
        if from == to {
            return 0;
        }
        match *self {
            Topology::Flat => 1,
            Topology::Ring { len } => {
                if len < 2 {
                    return 1;
                }
                let a = from.idx() % len;
                let b = to.idx() % len;
                let d = a.abs_diff(b);
                (d.min(len - d) as u32).max(1)
            }
            Topology::Torus { rows, cols } => {
                let cells = rows * cols;
                if cells < 2 {
                    return 1;
                }
                let a = from.idx() % cells;
                let b = to.idx() % cells;
                let (r1, c1) = (a / cols, a % cols);
                let (r2, c2) = (b / cols, b % cols);
                let dr = r1.abs_diff(r2);
                let dc = c1.abs_diff(c2);
                ((dr.min(rows - dr) + dc.min(cols - dc)) as u32).max(1)
            }
            Topology::Cluster { nodes, per_node, inter_hops } => {
                let slots = nodes * per_node;
                if slots < 2 {
                    return 1;
                }
                let a = from.idx() % slots;
                let b = to.idx() % slots;
                if a / per_node == b / per_node {
                    1
                } else {
                    inter_hops.max(1)
                }
            }
            Topology::Graph(ref g) => g.hops(from.idx(), to.idx()),
        }
    }

    /// Does this shape assign every rank of a `p`-process run its own slot?
    /// When false, `neighbors` strands out-of-shape ranks with an empty set
    /// (their load can never leave under diffusion) and `hops` falls back to
    /// modular aliasing — `Config::validate` rejects such configurations.
    pub fn covers(&self, p: usize) -> bool {
        match *self {
            Topology::Flat => true,
            Topology::Ring { len } => len == p,
            Topology::Torus { rows, cols } => rows * cols == p,
            Topology::Cluster { nodes, per_node, .. } => nodes * per_node == p,
            // Exactly one rank per node — ranks ≥ the node count are a
            // config error, not a silent modulo wrap.
            Topology::Graph(ref g) => g.n() == p,
        }
    }

    /// Partition `p` ranks into at most `shards` contiguous blocks for the
    /// sharded DES (`sim::parallel`); returns `shard_of[rank]`.
    ///
    /// Blocks are contiguous so each shard owns a rank interval, and on
    /// `Cluster` the block size is rounded up to a multiple of `per_node`
    /// so node-mates always co-locate — intra-node traffic (the 1-hop bulk
    /// of a cluster workload) then never crosses a shard boundary, and the
    /// cross-shard lookahead grows to the inter-node price.  `Graph`
    /// keeps the contiguous-interval contract but greedily nudges each
    /// block boundary to the position crossed by the fewest edges
    /// (`GraphTopo::shard_partition`).  Later blocks may end up empty
    /// (e.g. 4 ranks into 3 shards of block 2); empty shards are simply
    /// never materialized by the coordinator.
    pub fn shard_partition(&self, p: usize, shards: usize) -> Vec<u32> {
        if let Topology::Graph(g) = self {
            return g.shard_partition(p, shards);
        }
        let shards = shards.clamp(1, p.max(1));
        let mut block = p.div_ceil(shards).max(1);
        if let Topology::Cluster { per_node, .. } = *self {
            if per_node > 1 {
                block = block.div_ceil(per_node) * per_node;
            }
        }
        (0..p).map(|r| (r / block) as u32).collect()
    }

    /// Minimum `hops` over all cross-partition pairs, or `None` when fewer
    /// than two shards are populated (then there is no cross-shard traffic
    /// and the lookahead is unbounded).
    ///
    /// Computed per shape in O(P) instead of scanning all pairs:
    /// - `Flat`/`Ring`/`Torus`/`Graph` are connected graphs whose every
    ///   edge costs 1 hop, so any path between two differently-sharded
    ///   ranks contains an edge that crosses a partition boundary — the
    ///   minimum is 1 whenever ≥ 2 shards are populated.  (Consecutive
    ///   ranks are *not* always 1 hop apart on a torus or a graph; the
    ///   crossing-edge argument is the proof, not rank adjacency.)
    /// - `Cluster`: 1 if some node's ranks span two shards, otherwise every
    ///   cross-shard pair is cross-node and costs `inter_hops`.
    pub fn min_cross_partition_hops(&self, shard_of: &[u32]) -> Option<u32> {
        let mut populated = std::collections::BTreeSet::new();
        for &s in shard_of {
            populated.insert(s);
        }
        if populated.len() < 2 {
            return None;
        }
        match *self {
            Topology::Flat
            | Topology::Ring { .. }
            | Topology::Torus { .. }
            | Topology::Graph(_) => Some(1),
            Topology::Cluster { per_node, inter_hops, .. } => {
                let split_node = per_node > 0
                    && shard_of
                        .chunks(per_node)
                        .any(|node| node.iter().any(|&s| s != node[0]));
                if split_node {
                    Some(1)
                } else {
                    Some(inter_hops.max(1))
                }
            }
        }
    }

    /// Per-pair minimum `hops` between partition blocks: a row-major
    /// `n × n` matrix with `n = max shard id + 1`, where entry `[j·n + i]`
    /// is the minimum of `hops(a, b)` over all pairs with rank `a` in
    /// shard `j` and rank `b` in shard `i`.  The diagonal is 0; entries
    /// touching a shard id that owns no rank stay `u32::MAX` (no such
    /// message exists, so it constrains nothing).  `None` when fewer than
    /// two shards are populated — then there is no cross-shard traffic at
    /// all and the caller's window is unbounded.
    ///
    /// This is the per-pair generalization of
    /// [`Self::min_cross_partition_hops`]: the sharded DES
    /// (`sim::parallel`) turns each row into a per-shard lookahead, so a
    /// far-apart block pair buys a window proportional to its distance
    /// instead of the global minimum.  Every entry is therefore ≥ the
    /// scalar bound, and the matrix is symmetric because `hops` is.
    ///
    /// Cost per shape (never O(P²) pair scans):
    /// - `Flat`: all populated off-diagonal pairs are 1 — O(P + S²);
    /// - `Ring`/`Torus`: one multi-source BFS per shard over the unit-edge
    ///   cycle/grid — O(S·P); the closed-form `hops` of these shapes *is*
    ///   the BFS distance (out-of-shape ranks fold onto their modulo slot
    ///   exactly as `hops` does, with the same `max(1)` floor for distinct
    ///   ranks sharing a slot);
    /// - `Cluster`: `inter_hops` for every populated pair, collapsed to 1
    ///   for pairs co-resident in some node — O(P + S²);
    /// - `Graph`: one multi-source BFS per shard over the CSR adjacency —
    ///   O(S·(V+E)); ranks beyond the node count answer `hops` = 1, so
    ///   they pin their shard's rows and columns to 1 (a misconfiguration
    ///   guard — `Config::validate` rejects non-covering graphs).
    pub fn cross_partition_hops_matrix(&self, shard_of: &[u32]) -> Option<Vec<u32>> {
        let n = shard_of.iter().map(|&s| s as usize + 1).max().unwrap_or(0);
        let mut populated = vec![false; n];
        for &s in shard_of {
            populated[s as usize] = true;
        }
        if populated.iter().filter(|&&b| b).count() < 2 {
            return None;
        }
        let mut m = vec![u32::MAX; n * n];
        for s in 0..n {
            m[s * n + s] = 0;
        }
        // Fill every populated off-diagonal pair with one value — the
        // degenerate-shape fallback (`hops` answers 1 whenever a shape has
        // fewer than two slots) and the uniform tiers below.
        let fill = |m: &mut [u32], populated: &[bool], h: u32| {
            for j in 0..n {
                for i in 0..n {
                    if i != j && populated[j] && populated[i] {
                        let e = &mut m[j * n + i];
                        *e = (*e).min(h);
                    }
                }
            }
        };
        // Multi-source BFS per shard over a unit-edge cell graph; ranks
        // map onto cells via `cell_of` (`None` = out-of-shape, handled by
        // the caller).  Matches `hops` because each shape's closed form is
        // its cell graph's BFS metric.
        let bfs = |m: &mut [u32],
                   cells: usize,
                   cell_of: &dyn Fn(usize) -> Option<usize>,
                   neigh: &dyn Fn(usize, &mut Vec<usize>)| {
            use std::collections::VecDeque;
            let mut dist = vec![u32::MAX; cells];
            let mut q: VecDeque<usize> = VecDeque::new();
            let mut scratch: Vec<usize> = Vec::with_capacity(8);
            for j in 0..n {
                dist.iter_mut().for_each(|d| *d = u32::MAX);
                q.clear();
                for (r, &s) in shard_of.iter().enumerate() {
                    if s as usize == j {
                        if let Some(c) = cell_of(r) {
                            if dist[c] == u32::MAX {
                                dist[c] = 0;
                                q.push_back(c);
                            }
                        }
                    }
                }
                if q.is_empty() {
                    // Shard j owns no in-shape rank: nothing can originate
                    // there (Ring/Torus map every rank in-shape, so this is
                    // an unpopulated id; Graph's out-of-shape senders are
                    // pinned by the caller) — leave the row untouched.
                    continue;
                }
                while let Some(c) = q.pop_front() {
                    let d = dist[c];
                    scratch.clear();
                    neigh(c, &mut scratch);
                    for &v in &scratch {
                        if dist[v] == u32::MAX {
                            dist[v] = d + 1;
                            q.push_back(v);
                        }
                    }
                }
                for (r, &s) in shard_of.iter().enumerate() {
                    let i = s as usize;
                    if i == j {
                        continue;
                    }
                    let h = match cell_of(r) {
                        // Out-of-shape destination: `hops` answers a plain
                        // total 1 for such ranks.
                        None => 1,
                        Some(c) => {
                            // An in-shape cell is always reachable: the
                            // ring/torus cell graphs are connected by
                            // construction and `GraphTopo::from_edges`
                            // rejects disconnected graphs — an unreached
                            // cell is a broken invariant, not a distance
                            // (and `u32::MAX` stays "no such message",
                            // never a silent 1).
                            debug_assert!(
                                dist[c] != u32::MAX,
                                "in-shape cell {c} unreachable from shard {j}: \
                                 disconnected topology"
                            );
                            dist[c].max(1)
                        }
                    };
                    let e = &mut m[j * n + i];
                    *e = (*e).min(h);
                }
            }
        };
        match *self {
            Topology::Flat => fill(&mut m, &populated, 1),
            Topology::Ring { len } => {
                if len < 2 {
                    fill(&mut m, &populated, 1);
                } else {
                    bfs(&mut m, len, &|r| Some(r % len), &|c, out| {
                        out.push((c + 1) % len);
                        out.push((c + len - 1) % len);
                    });
                }
            }
            Topology::Torus { rows, cols } => {
                let cells = rows * cols;
                if cells < 2 {
                    fill(&mut m, &populated, 1);
                } else {
                    bfs(&mut m, cells, &|r| Some(r % cells), &|c, out| {
                        let (r, cc) = (c / cols, c % cols);
                        out.push(((r + 1) % rows) * cols + cc);
                        out.push(((r + rows - 1) % rows) * cols + cc);
                        out.push(r * cols + (cc + 1) % cols);
                        out.push(r * cols + (cc + cols - 1) % cols);
                    });
                }
            }
            Topology::Cluster { nodes, per_node, inter_hops } => {
                let slots = nodes * per_node;
                if slots < 2 {
                    fill(&mut m, &populated, 1);
                } else {
                    fill(&mut m, &populated, inter_hops.max(1));
                    // Shard pairs sharing a node meet at the 1-hop tier.
                    let mut node_shards: Vec<Vec<usize>> = vec![Vec::new(); nodes];
                    for (r, &s) in shard_of.iter().enumerate() {
                        let node = (r % slots) / per_node;
                        if !node_shards[node].contains(&(s as usize)) {
                            node_shards[node].push(s as usize);
                        }
                    }
                    for in_node in &node_shards {
                        for &a in in_node {
                            for &b in in_node {
                                if a != b {
                                    m[a * n + b] = 1;
                                }
                            }
                        }
                    }
                }
            }
            Topology::Graph(ref g) => {
                let nodes = g.n();
                bfs(&mut m, nodes.max(1), &|r| (r < nodes).then_some(r), &|c, out| {
                    out.extend(g.neighbors_of(c).iter().map(|&v| v as usize));
                });
                // Out-of-shape ranks (`hops` = 1 to everything) pin their
                // shard's row *and* column — they can be the sender too.
                for (r, &s) in shard_of.iter().enumerate() {
                    if r >= nodes {
                        let s = s as usize;
                        for i in 0..n {
                            if i != s && populated[i] {
                                m[s * n + i] = 1;
                                m[i * n + s] = 1;
                            }
                        }
                    }
                }
            }
        }
        Some(m)
    }

    /// The neighbor set diffusion exchanges load with.  Always symmetric
    /// (j ∈ N(i) ⇔ i ∈ N(j)), never contains `me`, sorted ascending.
    ///
    /// - flat: everyone else (diffusion degenerates to global averaging);
    /// - ring: the two adjacent ranks;
    /// - torus: the 4-neighborhood;
    /// - cluster: all same-node ranks plus the same-slot rank in the two
    ///   adjacent nodes (nodes form a ring), so load can leave a node;
    /// - graph: the CSR adjacency row (symmetric by construction).
    pub fn neighbors(&self, me: ProcessId, p: usize) -> Vec<ProcessId> {
        let m = me.idx();
        let mut out: Vec<usize> = Vec::new();
        if p >= 2 {
            match *self {
                Topology::Flat => {
                    out.extend((0..p).filter(|&i| i != m));
                }
                Topology::Ring { len } => {
                    let len = len.min(p).max(1);
                    if m < len {
                        out.push((m + 1) % len);
                        out.push((m + len - 1) % len);
                    }
                }
                Topology::Torus { rows, cols } => {
                    if m < rows * cols && rows * cols <= p {
                        let (r, c) = (m / cols, m % cols);
                        out.push(((r + 1) % rows) * cols + c);
                        out.push(((r + rows - 1) % rows) * cols + c);
                        out.push(r * cols + (c + 1) % cols);
                        out.push(r * cols + (c + cols - 1) % cols);
                    }
                }
                Topology::Cluster { nodes, per_node, .. } => {
                    if per_node > 0 && m < nodes * per_node && nodes * per_node <= p {
                        let node = m / per_node;
                        let slot = m % per_node;
                        for s in 0..per_node {
                            if s != slot {
                                out.push(node * per_node + s);
                            }
                        }
                        if nodes >= 2 {
                            out.push(((node + 1) % nodes) * per_node + slot);
                            out.push(((node + nodes - 1) % nodes) * per_node + slot);
                        }
                    }
                }
                Topology::Graph(ref g) => {
                    out.extend(g.neighbors_of(m).iter().map(|&v| v as usize));
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out.retain(|&i| i != m && i < p);
        out.into_iter().map(|i| ProcessId(i as u32)).collect()
    }

    /// Every other rank of a `p`-process run with its hop distance, sorted
    /// ascending by `(hops, rank)` — the distance-ranked victim table behind
    /// hierarchical stealing's escalation ladder.  The leading run of
    /// minimum-distance entries is the "local" tier: the cluster node, or
    /// the same adjacency shell diffusion exchanges with on ring/torus.
    ///
    /// One shared cache path for every shape: distances come from a single
    /// per-rank pass (`Graph` reads its precomputed BFS table row, the
    /// legacy shapes their closed forms), then a counting sort over the
    /// distance shells emits the table in O(p + diameter) — ascending rank
    /// within each shell, identical order to sorting by `(hops, rank)`.
    pub fn neighbors_by_distance(&self, me: ProcessId, p: usize) -> Vec<(ProcessId, u32)> {
        let m = me.idx();
        let mut hops_of: Vec<u32> = Vec::with_capacity(p);
        let mut max_h: u32 = 0;
        for q in 0..p {
            let h = if q == m { 0 } else { self.hops(me, ProcessId(q as u32)) };
            max_h = max_h.max(h);
            hops_of.push(h);
        }
        // bucket counts → prefix offsets → ascending-rank emission
        let mut count = vec![0usize; max_h as usize + 2];
        for (q, &h) in hops_of.iter().enumerate() {
            if q != m {
                count[h as usize + 1] += 1;
            }
        }
        for i in 1..count.len() {
            count[i] += count[i - 1];
        }
        let total = p - usize::from(m < p);
        let mut out = vec![(ProcessId(0), 0u32); total];
        for (q, &h) in hops_of.iter().enumerate() {
            if q == m {
                continue;
            }
            out[count[h as usize]] = (ProcessId(q as u32), h);
            count[h as usize] += 1;
        }
        out
    }

    /// The sampling weight a rank at `hops` distance carries: 1/hops².
    /// Single source of truth for both [`Self::sample_near`] and
    /// hierarchical stealing's precomputed escalation table.
    pub fn locality_weight(hops: u32) -> f64 {
        let h = hops.max(1) as f64;
        1.0 / (h * h)
    }

    /// Draw one victim with probability ∝ 1/hops²: near ranks dominate, but
    /// every rank stays reachable, so load can still escape a saturated
    /// neighborhood.  `None` only when there is no other rank.
    pub fn sample_near(&self, me: ProcessId, p: usize, rng: &mut Rng) -> Option<ProcessId> {
        let weight = |i: usize| Self::locality_weight(self.hops(me, ProcessId(i as u32)));
        let total: f64 = (0..p).filter(|&i| i != me.idx()).map(weight).sum();
        if total <= 0.0 {
            return None;
        }
        let mut x = rng.next_f64() * total;
        let mut last = None;
        for i in (0..p).filter(|&i| i != me.idx()) {
            last = Some(ProcessId(i as u32));
            x -= weight(i);
            if x <= 0.0 {
                break;
            }
        }
        last
    }

    /// Human-readable tag for tables and CSV.
    pub fn label(&self) -> String {
        match *self {
            Topology::Flat => "flat".to_string(),
            Topology::Ring { len } => format!("ring{len}"),
            Topology::Torus { rows, cols } => format!("torus{rows}x{cols}"),
            Topology::Cluster { nodes, per_node, .. } => format!("cluster{nodes}x{per_node}"),
            Topology::Graph(ref g) => g.label().to_string(),
        }
    }
}

impl Default for Topology {
    fn default() -> Self {
        Topology::Flat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(i: u32) -> ProcessId {
        ProcessId(i)
    }

    #[test]
    fn flat_is_single_hop_everyone() {
        let t = Topology::Flat;
        assert_eq!(t.hops(p(0), p(7)), 1);
        assert_eq!(t.hops(p(3), p(3)), 0);
        let n = t.neighbors(p(2), 5);
        assert_eq!(n, vec![p(0), p(1), p(3), p(4)]);
    }

    #[test]
    fn ring_distance_wraps() {
        let t = Topology::Ring { len: 8 };
        assert_eq!(t.hops(p(0), p(1)), 1);
        assert_eq!(t.hops(p(0), p(7)), 1);
        assert_eq!(t.hops(p(0), p(4)), 4);
        assert_eq!(t.hops(p(1), p(6)), 3);
        assert_eq!(t.neighbors(p(0), 8), vec![p(1), p(7)]);
        assert_eq!(t.neighbors(p(4), 8), vec![p(3), p(5)]);
    }

    #[test]
    fn ring_of_two_dedups() {
        let t = Topology::Ring { len: 2 };
        assert_eq!(t.neighbors(p(0), 2), vec![p(1)]);
        assert_eq!(t.hops(p(0), p(1)), 1);
    }

    #[test]
    fn torus_manhattan_wraps() {
        let t = Topology::Torus { rows: 3, cols: 4 };
        // rank = r*4 + c
        assert_eq!(t.hops(p(0), p(1)), 1); // (0,0)→(0,1)
        assert_eq!(t.hops(p(0), p(3)), 1); // (0,0)→(0,3) wraps
        assert_eq!(t.hops(p(0), p(8)), 1); // (0,0)→(2,0) wraps
        assert_eq!(t.hops(p(0), p(6)), 3); // (0,0)→(1,2): 1 + 2
        let n = t.neighbors(p(5), 12); // (1,1)
        assert_eq!(n, vec![p(1), p(4), p(6), p(9)]);
    }

    #[test]
    fn torus_neighbors_symmetric() {
        let t = Topology::Torus { rows: 3, cols: 3 };
        for i in 0..9u32 {
            for j in t.neighbors(p(i), 9) {
                assert!(
                    t.neighbors(j, 9).contains(&p(i)),
                    "asymmetric: {i} lists {j:?} but not vice versa"
                );
            }
        }
    }

    #[test]
    fn cluster_hops_two_level() {
        let t = Topology::Cluster { nodes: 2, per_node: 4, inter_hops: 4 };
        assert_eq!(t.hops(p(0), p(3)), 1); // same node
        assert_eq!(t.hops(p(0), p(4)), 4); // across
        assert_eq!(t.hops(p(5), p(1)), 4);
    }

    #[test]
    fn cluster_neighbors_include_gateway() {
        let t = Topology::Cluster { nodes: 2, per_node: 3, inter_hops: 4 };
        // rank 1 (node 0, slot 1): node-mates 0, 2; same slot in node 1 → 4
        assert_eq!(t.neighbors(p(1), 6), vec![p(0), p(2), p(4)]);
        // symmetry
        assert!(t.neighbors(p(4), 6).contains(&p(1)));
    }

    #[test]
    fn neighbors_never_self_and_connected() {
        for t in [
            Topology::Flat,
            Topology::Ring { len: 6 },
            Topology::Torus { rows: 2, cols: 3 },
            Topology::Cluster { nodes: 3, per_node: 2, inter_hops: 4 },
        ] {
            // BFS from 0 must reach everyone (diffusion needs connectivity)
            let p_n = 6;
            let mut seen = vec![false; p_n];
            let mut stack = vec![0usize];
            seen[0] = true;
            while let Some(i) = stack.pop() {
                assert!(!t.neighbors(p(i as u32), p_n).contains(&p(i as u32)));
                for q in t.neighbors(p(i as u32), p_n) {
                    if !seen[q.idx()] {
                        seen[q.idx()] = true;
                        stack.push(q.idx());
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "{t:?} disconnected");
        }
    }

    #[test]
    fn single_process_has_no_neighbors() {
        for t in [Topology::Flat, Topology::Ring { len: 1 }] {
            assert!(t.neighbors(p(0), 1).is_empty());
        }
    }

    /// The PR-4 contract bug: ranks beyond the shape's dimensions aliased
    /// onto in-shape slots and reported distance 0 for distinct processes
    /// (Ring) or arithmetic garbage (Torus/Cluster).  `hops` must be total.
    #[test]
    fn hops_total_for_out_of_shape_ranks() {
        let ring = Topology::Ring { len: 4 };
        assert_eq!(ring.hops(p(0), p(4)), 1, "rank 4 aliases slot 0 — still ≥ 1");
        assert_eq!(ring.hops(p(4), p(0)), 1, "and symmetric");
        assert_eq!(ring.hops(p(1), p(9)), 1, "both sides aliased");

        let torus = Topology::Torus { rows: 2, cols: 2 };
        // rank 9 → slot 1: no usize underflow, distance ≥ 1
        assert_eq!(torus.hops(p(0), p(9)), torus.hops(p(0), p(1)));
        assert_eq!(torus.hops(p(4), p(8)), 1, "distinct ranks on one slot");

        let cl = Topology::Cluster { nodes: 2, per_node: 2, inter_hops: 4 };
        assert_eq!(cl.hops(p(0), p(4)), 1, "alias lands in node 0");
        assert_eq!(cl.hops(p(1), p(6)), 4, "alias lands in node 1");

        // degenerate shapes must not panic and must stay ≥ 1
        assert_eq!(Topology::Ring { len: 1 }.hops(p(0), p(1)), 1);
        assert_eq!(Topology::Torus { rows: 1, cols: 1 }.hops(p(2), p(3)), 1);
        assert_eq!(
            Topology::Cluster { nodes: 1, per_node: 1, inter_hops: 4 }.hops(p(0), p(1)),
            1
        );
    }

    #[test]
    fn covers_matches_slot_count() {
        assert!(Topology::Flat.covers(1) && Topology::Flat.covers(100));
        assert!(Topology::Ring { len: 4 }.covers(4));
        assert!(!Topology::Ring { len: 4 }.covers(5));
        assert!(Topology::Torus { rows: 3, cols: 4 }.covers(12));
        assert!(!Topology::Torus { rows: 3, cols: 4 }.covers(8));
        let cl = Topology::Cluster { nodes: 2, per_node: 4, inter_hops: 4 };
        assert!(cl.covers(8));
        assert!(!cl.covers(10));
    }

    /// Stranded-rank regression: whenever the shape covers P and P ≥ 2,
    /// *every* rank must have at least one neighbor (else its load can
    /// never leave under diffusion).
    #[test]
    fn covering_shapes_leave_no_rank_stranded() {
        let shapes: Vec<(Topology, usize)> = vec![
            (Topology::Flat, 2),
            (Topology::Flat, 7),
            (Topology::Ring { len: 2 }, 2),
            (Topology::Ring { len: 9 }, 9),
            (Topology::Torus { rows: 1, cols: 2 }, 2),
            (Topology::Torus { rows: 3, cols: 5 }, 15),
            (Topology::Cluster { nodes: 2, per_node: 1, inter_hops: 4 }, 2),
            (Topology::Cluster { nodes: 4, per_node: 4, inter_hops: 4 }, 16),
        ];
        for (t, p_n) in shapes {
            assert!(t.covers(p_n), "{t:?} must cover {p_n}");
            for i in 0..p_n {
                assert!(
                    !t.neighbors(p(i as u32), p_n).is_empty(),
                    "{t:?}: rank {i} of {p_n} is stranded"
                );
            }
        }
    }

    #[test]
    fn distance_ranking_orders_cluster_tiers() {
        let t = Topology::Cluster { nodes: 4, per_node: 4, inter_hops: 4 };
        let ranked = t.neighbors_by_distance(p(5), 16);
        assert_eq!(ranked.len(), 15);
        // node 1 = ranks 4..8; the three node-mates lead at distance 1
        let local: Vec<_> = ranked.iter().take_while(|&&(_, h)| h == 1).collect();
        assert_eq!(
            local.iter().map(|&&(q, _)| q).collect::<Vec<_>>(),
            vec![p(4), p(6), p(7)]
        );
        assert!(ranked.iter().skip(3).all(|&(_, h)| h == 4), "remote tier at inter_hops");
        // sorted ascending by (hops, rank)
        for w in ranked.windows(2) {
            assert!((w[0].1, w[0].0.idx()) < (w[1].1, w[1].0.idx()));
        }
    }

    #[test]
    fn shard_partition_is_contiguous_and_balanced() {
        let shard_of = Topology::Flat.shard_partition(10, 3);
        // block = ceil(10/3) = 4 → shards of 4, 4, 2 ranks
        assert_eq!(shard_of, vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2]);
        // degenerate requests clamp instead of panicking
        assert_eq!(Topology::Flat.shard_partition(4, 100), vec![0, 1, 2, 3]);
        assert_eq!(Topology::Flat.shard_partition(4, 0), vec![0, 0, 0, 0]);
        assert!(Topology::Flat.shard_partition(0, 3).is_empty());
    }

    #[test]
    fn cluster_sharding_keeps_node_mates_together() {
        let t = Topology::Cluster { nodes: 4, per_node: 4, inter_hops: 4 };
        // 16 ranks into 3 shards: block ceil(16/3)=6 rounds up to 8 (two
        // whole nodes per shard) — no node is ever split across shards.
        let shard_of = t.shard_partition(16, 3);
        for node in shard_of.chunks(4) {
            assert!(node.iter().all(|&s| s == node[0]), "split node: {shard_of:?}");
        }
        // and the lookahead therefore prices at the inter-node tier
        assert_eq!(t.min_cross_partition_hops(&shard_of), Some(4));
    }

    #[test]
    fn min_cross_partition_hops_per_shape() {
        // unit-edge shapes: any populated 2-shard split crosses at 1 hop
        let ring = Topology::Ring { len: 8 };
        assert_eq!(ring.min_cross_partition_hops(&ring.shard_partition(8, 2)), Some(1));
        let torus = Topology::Torus { rows: 2, cols: 4 };
        assert_eq!(torus.min_cross_partition_hops(&torus.shard_partition(8, 3)), Some(1));
        assert_eq!(Topology::Flat.min_cross_partition_hops(&[0, 0, 1, 1]), Some(1));
        // a split node collapses a cluster's lookahead to the 1-hop tier
        let cl = Topology::Cluster { nodes: 2, per_node: 4, inter_hops: 4 };
        assert_eq!(cl.min_cross_partition_hops(&[0, 0, 1, 1, 1, 1, 1, 1]), Some(1));
        assert_eq!(cl.min_cross_partition_hops(&[0, 0, 0, 0, 1, 1, 1, 1]), Some(4));
        // fewer than two populated shards → no cross-shard traffic at all
        assert_eq!(Topology::Flat.min_cross_partition_hops(&[0, 0, 0]), None);
        assert_eq!(Topology::Flat.min_cross_partition_hops(&[]), None);
    }

    /// Oracle for `cross_partition_hops_matrix`: the O(P²) scan over every
    /// rank pair it is forbidden from doing.
    fn brute_hops_matrix(t: &Topology, shard_of: &[u32]) -> Option<Vec<u32>> {
        let n = shard_of.iter().map(|&s| s as usize + 1).max().unwrap_or(0);
        let mut pop = vec![false; n];
        shard_of.iter().for_each(|&s| pop[s as usize] = true);
        if pop.iter().filter(|&&b| b).count() < 2 {
            return None;
        }
        let mut m = vec![u32::MAX; n * n];
        (0..n).for_each(|s| m[s * n + s] = 0);
        for (a, &sa) in shard_of.iter().enumerate() {
            for (b, &sb) in shard_of.iter().enumerate() {
                if sa != sb {
                    let e = &mut m[sa as usize * n + sb as usize];
                    *e = (*e).min(t.hops(p(a as u32), p(b as u32)).max(1));
                }
            }
        }
        Some(m)
    }

    #[test]
    fn hops_matrix_matches_brute_force_per_shape() {
        let cases: Vec<(Topology, usize)> = vec![
            (Topology::Flat, 7),
            (Topology::Ring { len: 9 }, 9),
            (Topology::Ring { len: 16 }, 16),
            (Topology::Torus { rows: 3, cols: 4 }, 12),
            (Topology::Torus { rows: 4, cols: 4 }, 16),
            (Topology::Cluster { nodes: 4, per_node: 4, inter_hops: 4 }, 16),
            (cycle6(), 6),
            // out-of-shape ranks: ring slots alias modulo len, graph ranks
            // beyond the node count answer 1 — the matrix must agree
            (Topology::Ring { len: 4 }, 6),
            (cycle6(), 8),
        ];
        for (t, p_n) in cases {
            for shards in 1..=4usize {
                let shard_of = t.shard_partition(p_n, shards);
                let got = t.cross_partition_hops_matrix(&shard_of);
                let want = brute_hops_matrix(&t, &shard_of);
                assert_eq!(got, want, "{t:?} p={p_n} shards={shards}");
                if let Some(m) = got {
                    let n = shard_of.iter().map(|&s| s as usize + 1).max().unwrap();
                    let min = m
                        .iter()
                        .enumerate()
                        .filter(|&(k, &h)| k / n != k % n && h != u32::MAX)
                        .map(|(_, &h)| h)
                        .min();
                    assert_eq!(
                        min,
                        t.min_cross_partition_hops(&shard_of),
                        "{t:?} p={p_n} shards={shards}: matrix min vs scalar"
                    );
                    for j in 0..n {
                        for i in 0..n {
                            assert_eq!(m[j * n + i], m[i * n + j], "asymmetric ({j},{i})");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn hops_matrix_handles_noncontiguous_and_gapped_ids() {
        // Hand-built partitions the contiguous `shard_partition` never
        // emits: interleaved blocks and a gap in the id space.
        let ring = Topology::Ring { len: 8 };
        for shard_of in [
            vec![0u32, 1, 0, 1, 0, 1, 0, 1],
            vec![0, 0, 2, 2, 0, 0, 2, 2],
            vec![3, 0, 0, 0, 0, 0, 0, 3],
        ] {
            let got = ring.cross_partition_hops_matrix(&shard_of);
            assert_eq!(got, brute_hops_matrix(&ring, &shard_of), "{shard_of:?}");
        }
        // gapped ids: unpopulated rows/cols stay MAX (never 1)
        let m = ring.cross_partition_hops_matrix(&[0, 0, 2, 2, 0, 0, 2, 2]).unwrap();
        let n = 3;
        for i in 0..n {
            if i != 1 {
                assert_eq!(m[n + i], u32::MAX, "unpopulated row leaked a bound");
                assert_eq!(m[i * n + 1], u32::MAX, "unpopulated col leaked a bound");
            }
        }
    }

    #[test]
    fn sample_near_prefers_the_near_tier() {
        let t = Topology::Cluster { nodes: 2, per_node: 4, inter_hops: 4 };
        let mut rng = Rng::new(99);
        let mut local = 0usize;
        let n = 4000;
        for _ in 0..n {
            let q = t.sample_near(p(0), 8, &mut rng).expect("has peers");
            assert_ne!(q, p(0), "never self");
            if q.idx() < 4 {
                local += 1;
            }
        }
        // weights: 3 node-mates at 1/1 vs 4 remote at 1/16 → local share
        // = 3 / 3.25 ≈ 92%
        assert!(local as f64 / n as f64 > 0.85, "local draws {local}/{n}");
        // single-process population has nobody to draw
        assert_eq!(t.sample_near(p(0), 1, &mut rng), None);
    }

    // ------------------------------------------------------------------
    // graph-backed variant
    // ------------------------------------------------------------------

    use crate::net::graph::GraphTopo;
    use std::sync::Arc;

    /// A 6-cycle as a Topology::Graph.
    fn cycle6() -> Topology {
        let edges = [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0)];
        Topology::Graph(Arc::new(GraphTopo::from_edges(6, &edges, "c6").expect("c6")))
    }

    #[test]
    fn graph_hops_answer_from_the_table() {
        let t = cycle6();
        assert_eq!(t.hops(p(0), p(0)), 0);
        assert_eq!(t.hops(p(0), p(1)), 1);
        assert_eq!(t.hops(p(0), p(3)), 3);
        assert_eq!(t.hops(p(0), p(5)), 1, "wraps like a ring");
        assert_eq!(t.hops(p(5), p(0)), 1, "symmetric");
    }

    /// Satellite regression: graph ranks ≥ the node count must NOT alias
    /// modulo the node count (the pre-PR-4 Ring bug) — `hops` answers a
    /// plain total 1 and `covers` rejects the configuration outright.
    #[test]
    fn graph_out_of_shape_ranks_rejected_not_aliased() {
        let t = cycle6();
        // modulo aliasing would answer hops(0, 6) = 0 and hops(0, 9) = 3
        assert_eq!(t.hops(p(0), p(6)), 1, "no wrap onto slot 0");
        assert_eq!(t.hops(p(0), p(9)), 1, "no wrap onto slot 3");
        assert_eq!(t.hops(p(7), p(7)), 0, "self stays 0");
        assert!(t.covers(6), "exactly one rank per node");
        assert!(!t.covers(5), "fewer ranks than nodes rejected");
        assert!(!t.covers(7), "extra ranks rejected — no silent modulo");
        assert!(t.neighbors(p(6), 7).is_empty(), "out-of-shape rank has no edges");
    }

    #[test]
    fn graph_neighbors_come_from_csr_rows() {
        let t = cycle6();
        assert_eq!(t.neighbors(p(0), 6), vec![p(1), p(5)]);
        assert_eq!(t.neighbors(p(3), 6), vec![p(2), p(4)]);
        // symmetry + connectivity, same walk as the legacy shapes
        let mut seen = vec![false; 6];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(i) = stack.pop() {
            for q in t.neighbors(p(i as u32), 6) {
                assert!(t.neighbors(q, 6).contains(&p(i as u32)), "asymmetric at {i}");
                if !seen[q.idx()] {
                    seen[q.idx()] = true;
                    stack.push(q.idx());
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "graph disconnected through Topology");
    }

    #[test]
    fn graph_distance_ranking_matches_brute_force() {
        let t = cycle6();
        let ranked = t.neighbors_by_distance(p(2), 6);
        let mut brute: Vec<(ProcessId, u32)> =
            (0..6).filter(|&i| i != 2).map(|i| (p(i), t.hops(p(2), p(i)))).collect();
        brute.sort_unstable_by_key(|&(q, h)| (h, q.0));
        assert_eq!(ranked, brute);
    }

    /// The counting-sort path must reproduce the legacy sort order bit for
    /// bit on every closed shape (the hierarchical ladder's tier layout
    /// depends on it).
    #[test]
    fn distance_ranking_counting_sort_matches_legacy_order() {
        let shapes: Vec<(Topology, usize)> = vec![
            (Topology::Flat, 7),
            (Topology::Ring { len: 9 }, 9),
            (Topology::Torus { rows: 3, cols: 4 }, 12),
            (Topology::Cluster { nodes: 4, per_node: 4, inter_hops: 4 }, 16),
        ];
        for (t, p_n) in shapes {
            for me in 0..p_n {
                let got = t.neighbors_by_distance(p(me as u32), p_n);
                let mut want: Vec<(ProcessId, u32)> = (0..p_n)
                    .filter(|&i| i != me)
                    .map(|i| (p(i as u32), t.hops(p(me as u32), p(i as u32))))
                    .collect();
                want.sort_unstable_by_key(|&(q, h)| (h, q.0));
                assert_eq!(got, want, "{t:?} me={me}");
            }
        }
    }

    #[test]
    fn graph_shard_partition_feeds_positive_lookahead() {
        let t = cycle6();
        let shard_of = t.shard_partition(6, 2);
        assert_eq!(shard_of.len(), 6);
        for w in shard_of.windows(2) {
            assert!(w[0] <= w[1], "contiguous intervals required: {shard_of:?}");
        }
        assert_eq!(
            t.min_cross_partition_hops(&shard_of),
            Some(1),
            "connected unit-edge graph crosses at 1 hop"
        );
        assert_eq!(t.min_cross_partition_hops(&[0, 0, 0]), None);
    }

    #[test]
    fn graph_label_and_sample_near() {
        let t = cycle6();
        assert_eq!(t.label(), "c6");
        let mut rng = Rng::new(5);
        let mut near = 0usize;
        let n = 3000;
        for _ in 0..n {
            let q = t.sample_near(p(0), 6, &mut rng).expect("has peers");
            assert_ne!(q, p(0));
            if t.hops(p(0), q) == 1 {
                near += 1;
            }
        }
        // weights: 2 at 1/1, 2 at 1/4, 1 at 1/9 → near share = 2/2.61 ≈ 77%
        let share = near as f64 / n as f64;
        assert!(share > 0.68 && share < 0.86, "near share {share}");
    }
}
