//! Inter-process messaging: the wire protocol (`message`) and the
//! threaded-mode transport (`transport`).  The DES mode delivers the same
//! `Envelope`s through `sim::network` instead.

pub mod message;
pub mod transport;

pub use message::{Envelope, MigratedTask, Msg, Role};
pub use transport::{mesh, Mailbox, Router, Shaper};
