//! Inter-process messaging: the wire protocol (`message`) and the
//! threaded-mode transport (`transport`).  The DES mode delivers the same
//! `Envelope`s through `sim::network` instead.

pub mod graph;
pub mod message;
pub mod topology;
pub mod transport;

pub use graph::GraphTopo;
pub use message::{Envelope, Flight, MigratedTask, Msg, Role};
pub use topology::Topology;
pub use transport::{mesh, mesh_on, precise_wait, FromEnvelope, Mailbox, Router, Shaper};
