//! The wire protocol between processes.
//!
//! Two planes share one mailbox:
//!
//! - **data plane**: `TaskDone` fan-out of completed outputs to dependent
//!   processes, and `ResultReturn` of migrated-task outputs to their origin;
//! - **DLB control plane**: the randomized pairing handshake
//!   (`PairRequest` → `PairAccept`/`PairDecline` → `PairConfirm`/`PairRelease`
//!   → `TaskExport` → `ExportAck`) and termination
//!   (`OwnerDone` → `Shutdown`).

use crate::core::data::Payload;
use crate::core::ids::{DataId, ProcessId, TaskId};

/// Which side of the load divide a process is on (w > W_T ⇒ busy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Busy,
    Idle,
}

impl Role {
    pub fn opposite(self) -> Role {
        match self {
            Role::Busy => Role::Idle,
            Role::Idle => Role::Busy,
        }
    }
}

/// A task shipped to a thief: the task id, the process the result must be
/// returned to (the task's home — preserved across re-exports so load can
/// propagate through intermediaries, §7), and every input value it needs.
#[derive(Debug, Clone)]
pub struct MigratedTask {
    pub task: TaskId,
    pub origin: ProcessId,
    /// Input blocks in kernel-argument order.
    pub inputs: Vec<(DataId, Payload)>,
}

/// All inter-process messages.
#[derive(Debug, Clone)]
pub enum Msg {
    /// Task `task` finished; its output (handle `data`) is attached when a
    /// dependent on the receiving process reads it (`Payload::None` for pure
    /// ordering edges — WAR/WAW).
    TaskDone {
        task: TaskId,
        data: DataId,
        payload: Payload,
    },

    /// A thief returns the output of a migrated task to its origin.
    ResultReturn {
        task: TaskId,
        payload: Payload,
    },

    /// Initial-distribution push: version-0 data needed by a remote
    /// consumer that has no producing task (sent once at startup).
    DataSend {
        data: DataId,
        payload: Payload,
    },

    // ---- pairing handshake -------------------------------------------
    /// "I am `role` with load `load`; pair with me?"  `eta` is the idle
    /// side's expected time to drain its queue (used by the Smart strategy);
    /// busy requesters send their own eta for symmetry.
    PairRequest {
        round: u64,
        role: Role,
        load: usize,
        eta: f64,
    },
    /// Positive answer; responder is now soft-locked awaiting Confirm.
    PairAccept {
        round: u64,
        load: usize,
        eta: f64,
    },
    /// Negative answer (wrong role, already locked, or shutting down).
    PairDecline {
        round: u64,
    },
    /// Requester commits to this partner.
    PairConfirm {
        round: u64,
        load: usize,
        eta: f64,
    },
    /// Requester already paired elsewhere; release the soft lock.
    PairRelease {
        round: u64,
    },

    // ---- work stealing ------------------------------------------------
    /// An idle thief asks `to` for work; the victim answers with a
    /// (possibly empty) `TaskExport` carrying the same `round` — empty
    /// means "nothing to steal", the thief's cue to retry elsewhere.
    StealRequest {
        round: u64,
        load: usize,
        eta: f64,
    },

    // ---- diffusion ----------------------------------------------------
    /// Periodic load broadcast to topology neighbors (first-order
    /// diffusion: receivers use it to estimate the local load gradient).
    LoadReport {
        load: usize,
    },

    /// The busy side's export: zero or more ready tasks with their inputs.
    TaskExport {
        round: u64,
        tasks: Vec<MigratedTask>,
    },
    /// The idle side acknowledges; transaction complete, both unlock.
    ExportAck {
        round: u64,
        accepted: usize,
    },

    // ---- termination --------------------------------------------------
    /// All tasks homed at `proc` have completed (sent to rank 0).
    OwnerDone {
        proc: ProcessId,
    },
    /// Rank 0 broadcast: stop event loops.
    Shutdown,
}

impl Msg {
    /// Payload size in doubles for the network model: control messages cost
    /// `control_doubles`; data-bearing messages add their block sizes.
    pub fn wire_doubles(&self, control_doubles: u64) -> u64 {
        match self {
            Msg::TaskDone { payload, .. }
            | Msg::ResultReturn { payload, .. }
            | Msg::DataSend { payload, .. } => control_doubles + payload_doubles(payload),
            Msg::TaskExport { tasks, .. } => {
                control_doubles
                    + tasks
                        .iter()
                        .map(|t| {
                            control_doubles
                                + t.inputs.iter().map(|(_, p)| payload_doubles(p)).sum::<u64>()
                        })
                        .sum::<u64>()
            }
            _ => control_doubles,
        }
    }

    /// Static variant name, for trace events and debug output.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Msg::TaskDone { .. } => "task_done",
            Msg::ResultReturn { .. } => "result_return",
            Msg::DataSend { .. } => "data_send",
            Msg::PairRequest { .. } => "pair_request",
            Msg::PairAccept { .. } => "pair_accept",
            Msg::PairDecline { .. } => "pair_decline",
            Msg::PairConfirm { .. } => "pair_confirm",
            Msg::PairRelease { .. } => "pair_release",
            Msg::StealRequest { .. } => "steal_request",
            Msg::LoadReport { .. } => "load_report",
            Msg::TaskExport { .. } => "task_export",
            Msg::ExportAck { .. } => "export_ack",
            Msg::OwnerDone { .. } => "owner_done",
            Msg::Shutdown => "shutdown",
        }
    }

    /// True for messages belonging to the DLB control plane (metrics).
    pub fn is_dlb(&self) -> bool {
        matches!(
            self,
            Msg::PairRequest { .. }
                | Msg::PairAccept { .. }
                | Msg::PairDecline { .. }
                | Msg::PairConfirm { .. }
                | Msg::PairRelease { .. }
                | Msg::StealRequest { .. }
                | Msg::LoadReport { .. }
                | Msg::TaskExport { .. }
                | Msg::ExportAck { .. }
        )
    }
}

fn payload_doubles(p: &Payload) -> u64 {
    match p {
        Payload::None => 0,
        // Sim payloads are sized by the graph metadata at the send site; the
        // engine passes explicit sizes for them (see sim::network).
        Payload::Sim => 0,
        Payload::Real(v) => v.len() as u64,
    }
}

/// An addressed message in flight.
#[derive(Debug, Clone)]
pub struct Envelope {
    pub from: ProcessId,
    pub to: ProcessId,
    pub msg: Msg,
    /// Size in doubles for the network model (includes Sim payload sizes
    /// which are not recoverable from the Msg itself).
    pub wire_doubles: u64,
}

/// One scheduled delivery on the simulated transport: a head envelope plus
/// any further messages coalesced behind it.
///
/// The DES packs every `Effect::Send` emitted by one `ProcessState` step
/// that shares `(destination, computed delay)` into a single `Flight` and
/// a single `Deliver` event (`[sim] coalesce = true`).  Members necessarily
/// share the arrival time — the delay already includes the per-message
/// bandwidth term, so only same-size messages can coalesce and nobody's
/// delivery moves.  At dispatch the engine unpacks `head` first, then the
/// `tail` messages in their original emission order, so the receiving state
/// machine observes exactly the uncoalesced message sequence.  (Packing
/// makes a flight's messages dispatch contiguously, so the *global*
/// interleaving with other same-instant deliveries to other receivers may
/// shift — still deterministic, just not bit-identical to coalesce-off
/// unless every step sends ≤ 1 message per destination.)
#[derive(Debug, Clone)]
pub struct Flight {
    pub head: Envelope,
    /// Messages delivered immediately after `head`, in emission order.
    /// Empty unless coalescing is enabled.
    pub tail: Vec<Msg>,
    /// Simulated send instant, stamped by the engine when the flight is
    /// scheduled.  Tail members share it (coalescing only packs messages
    /// emitted in the same process step).  Feeds the trace recorder's
    /// message-flight spans; 0.0 until stamped.
    pub sent_at: f64,
}

impl Flight {
    pub fn new(head: Envelope) -> Self {
        Flight { head, tail: Vec::new(), sent_at: 0.0 }
    }

    /// A flight stamped with its send instant — the form both simulator
    /// engines open flights in (the recorder derives its flight spans from
    /// `sent_at`, and cross-shard outbox flights carry it across the window
    /// barrier unchanged).
    pub fn sent(head: Envelope, at: f64) -> Self {
        Flight { head, tail: Vec::new(), sent_at: at }
    }

    /// Messages carried by this delivery (head + coalesced tail).
    pub fn messages(&self) -> usize {
        1 + self.tail.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn role_opposite() {
        assert_eq!(Role::Busy.opposite(), Role::Idle);
        assert_eq!(Role::Idle.opposite(), Role::Busy);
    }

    #[test]
    fn wire_size_control_vs_data() {
        let ctrl = Msg::PairDecline { round: 1 };
        assert_eq!(ctrl.wire_doubles(8), 8);
        let data = Msg::TaskDone {
            task: TaskId(0),
            data: DataId(0),
            payload: Payload::real_from(vec![0.0; 100]),
        };
        assert_eq!(data.wire_doubles(8), 108);
    }

    #[test]
    fn export_counts_all_inputs() {
        let m = Msg::TaskExport {
            round: 0,
            tasks: vec![
                MigratedTask {
                    task: TaskId(1),
                    origin: ProcessId(0),
                    inputs: vec![
                        (DataId(0), Payload::real_from(vec![0.0; 10])),
                        (DataId(1), Payload::real_from(vec![0.0; 20])),
                    ],
                },
                MigratedTask { task: TaskId(2), origin: ProcessId(0), inputs: vec![] },
            ],
        };
        assert_eq!(m.wire_doubles(4), 4 + (4 + 30) + 4);
    }

    #[test]
    fn flight_counts_head_plus_tail() {
        let env = Envelope {
            from: ProcessId(0),
            to: ProcessId(1),
            msg: Msg::PairDecline { round: 1 },
            wire_doubles: 8,
        };
        let mut fl = Flight::new(env);
        assert_eq!(fl.messages(), 1);
        fl.tail.push(Msg::PairDecline { round: 2 });
        fl.tail.push(Msg::LoadReport { load: 3 });
        assert_eq!(fl.messages(), 3);
    }

    #[test]
    fn flight_sent_stamps_send_instant() {
        let env = Envelope {
            from: ProcessId(0),
            to: ProcessId(1),
            msg: Msg::PairDecline { round: 1 },
            wire_doubles: 8,
        };
        let fl = Flight::sent(env, 2.5);
        assert_eq!(fl.sent_at, 2.5);
        assert_eq!(fl.messages(), 1);
    }

    #[test]
    fn dlb_classification() {
        assert!(Msg::PairRequest { round: 0, role: Role::Idle, load: 0, eta: 0.0 }.is_dlb());
        assert!(!Msg::Shutdown.is_dlb());
        assert!(
            !Msg::TaskDone { task: TaskId(0), data: DataId(0), payload: Payload::None }.is_dlb()
        );
    }
}
