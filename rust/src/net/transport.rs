//! Message transport for the threaded real mode: a full mpsc mailbox mesh
//! with an optional latency/bandwidth shaper.
//!
//! The DES does not use this (it delivers envelopes through its event heap —
//! `sim::network`); the `Router`/`Mailbox` pair is the real-mode equivalent
//! with wallclock semantics.
//!
//! Shaping is **asynchronous**: `Router::send` never sleeps.  When a shaper
//! is configured, the send stamps the envelope with its modeled arrival
//! deadline (`hops × latency + doubles / R`, the same formula the DES's
//! `NetworkModel` charges) and hands it to a dedicated net thread that
//! releases envelopes in deadline order.  The caller — the coordinator
//! thread, whose responsiveness the whole pairing protocol depends on —
//! returns in O(1).  An earlier design waited out the delay inline on the
//! sender, which stalled the coordinator for the full wire time of every
//! protocol message.

use std::collections::BinaryHeap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

use crate::core::ids::ProcessId;
use crate::util::fxhash::FxHashMap;

use super::message::Envelope;
use super::topology::Topology;

/// What a mailbox channel carries.  The threaded runtime's coordinator
/// multiplexes network messages and worker completions over ONE channel (so
/// either kind of event wakes its park immediately), which means the mesh is
/// generic over the event type it delivers into.  Plain `Envelope` mailboxes
/// are the identity case.
pub trait FromEnvelope: Send + 'static {
    fn from_envelope(env: Envelope) -> Self;
}

impl FromEnvelope for Envelope {
    fn from_envelope(env: Envelope) -> Self {
        env
    }
}

/// Sender side: can address any process.
pub struct Router<E: FromEnvelope = Envelope> {
    senders: Vec<Sender<E>>,
    shaper: Option<Shaper>,
    topology: Topology,
    /// Handle to the net thread; `Some` iff a shaper is configured.
    outbox: Option<Sender<Timed>>,
}

// Manual impl: `#[derive(Clone)]` would demand `E: Clone`, which event enums
// holding non-Clone payloads (worker completions) cannot provide.  Cloning a
// Router only clones channel handles.
impl<E: FromEnvelope> Clone for Router<E> {
    fn clone(&self) -> Self {
        Router {
            senders: self.senders.clone(),
            shaper: self.shaper,
            topology: self.topology.clone(),
            outbox: self.outbox.clone(),
        }
    }
}

/// Receiver side: one per process.
pub struct Mailbox<E = Envelope> {
    pub me: ProcessId,
    rx: Receiver<E>,
}

/// Build a fully-connected mesh for `p` processes (flat topology).
pub fn mesh<E: FromEnvelope>(p: usize, shaper: Option<Shaper>) -> (Router<E>, Vec<Mailbox<E>>) {
    mesh_on(p, shaper, Topology::Flat)
}

/// Build a mesh whose shaper charges `hops(from, to)` of latency per
/// message — the threaded-mode counterpart of the DES topology model.
///
/// With a shaper, this also spawns the mesh's net thread (detached: it
/// drains its holding queue and exits once every `Router` clone is gone).
pub fn mesh_on<E: FromEnvelope>(
    p: usize,
    shaper: Option<Shaper>,
    topology: Topology,
) -> (Router<E>, Vec<Mailbox<E>>) {
    let mut senders = Vec::with_capacity(p);
    let mut mailboxes = Vec::with_capacity(p);
    for i in 0..p {
        let (tx, rx) = channel();
        senders.push(tx);
        mailboxes.push(Mailbox { me: ProcessId(i as u32), rx });
    }
    let outbox = shaper.map(|_| {
        let (tx, rx) = channel::<Timed>();
        let senders = senders.clone();
        std::thread::Builder::new()
            .name("ductr-net".into())
            .spawn(move || outbox_loop::<E>(rx, senders))
            .expect("spawn net thread");
        tx
    });
    (Router { senders, shaper, topology, outbox }, mailboxes)
}

impl<E: FromEnvelope> Router<E> {
    /// Send an envelope to its destination.  O(1), never sleeps: a shaped
    /// send stamps the arrival deadline and enqueues to the net thread; an
    /// unshaped send delivers directly.
    ///
    /// The destination is validated **before** anything is enqueued: a bad
    /// address must fail fast at the caller.
    ///
    /// Sending to a process that has already halted (mailbox dropped) is
    /// not an error: during shutdown, in-flight DLB traffic may race the
    /// `Shutdown` broadcast, and the halted peer would have discarded the
    /// message anyway.
    pub fn send(&self, env: Envelope) -> Result<(), String> {
        let to = env.to.idx();
        if to >= self.senders.len() {
            return Err(format!("no such process: {}", env.to));
        }
        match (&self.shaper, &self.outbox) {
            (Some(sh), Some(tx)) => {
                let delay = sh.delay_for(env.wire_doubles, self.topology.hops(env.from, env.to));
                // net thread gone only after every Router dropped — not here
                let _ = tx.send(Timed { deadline: Instant::now() + delay, env });
            }
            _ => {
                let _ = self.senders[to].send(E::from_envelope(env)); // closed mailbox == halted peer
            }
        }
        Ok(())
    }

    /// A raw handle into `p`'s mailbox channel, bypassing shaping and
    /// envelope wrapping.  This is how a process's worker threads inject
    /// local events (exec completions) into the same channel the network
    /// delivers to, so the coordinator has one unified thing to park on.
    pub fn direct_sender(&self, p: ProcessId) -> Sender<E> {
        self.senders[p.idx()].clone()
    }

    pub fn num_processes(&self) -> usize {
        self.senders.len()
    }
}

impl<E> Mailbox<E> {
    /// Non-blocking poll.
    pub fn try_recv(&self) -> Option<E> {
        match self.rx.try_recv() {
            Ok(e) => Some(e),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&self, d: Duration) -> Option<E> {
        self.rx.recv_timeout(d).ok()
    }
}

/// An envelope in the net thread's holding queue, due at `deadline`.
struct Timed {
    deadline: Instant,
    env: Envelope,
}

/// Heap entry: earliest deadline first, arrival order (`seq`) among equals.
struct Pending {
    deadline: Instant,
    seq: u64,
    env: Envelope,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.deadline == other.deadline && self.seq == other.seq
    }
}
impl Eq for Pending {}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pending {
    // reversed: BinaryHeap is a max-heap, we pop the earliest deadline
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.deadline.cmp(&self.deadline).then(other.seq.cmp(&self.seq))
    }
}

/// The net thread: hold each envelope until its deadline, then deliver.
///
/// FIFO per (src, dst) pair is preserved by clamping: a message's release
/// instant is `max(its own deadline, the pair's previous release instant)`,
/// so a small message can never overtake a big one on the same ordered pair
/// (matching both mpsc's unshaped FIFO and the in-order channels the
/// protocol's correctness argument assumes).  Messages of one sender reach
/// this thread in send order through the outbox channel, which makes the
/// clamp well-defined.
///
/// On disconnect (all routers dropped) the remaining queue is drained at its
/// deadlines before the thread exits, so late shutdown traffic still lands.
fn outbox_loop<E: FromEnvelope>(rx: Receiver<Timed>, senders: Vec<Sender<E>>) {
    let mut heap: BinaryHeap<Pending> = BinaryHeap::new();
    let mut floor: FxHashMap<(u32, u32), Instant> = FxHashMap::default();
    let mut seq = 0u64;
    let mut open = true;
    while open || !heap.is_empty() {
        // deliver everything due
        let now = Instant::now();
        while heap.peek().is_some_and(|p| p.deadline <= now) {
            let p = heap.pop().expect("peeked");
            let to = p.env.to.idx();
            let _ = senders[to].send(E::from_envelope(p.env)); // closed == halted peer
        }
        let next = heap.peek().map(|p| p.deadline);
        let received = if open {
            match next {
                // park until the next deadline OR the next enqueue
                Some(d) => match rx.recv_timeout(d.saturating_duration_since(Instant::now())) {
                    Ok(t) => Some(t),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        open = false;
                        None
                    }
                },
                None => match rx.recv() {
                    Ok(t) => Some(t),
                    Err(_) => {
                        open = false;
                        None
                    }
                },
            }
        } else {
            // drain mode: wait out the earliest remaining deadline
            if let Some(d) = next {
                precise_wait(d.saturating_duration_since(Instant::now()));
            }
            None
        };
        if let Some(t) = received {
            let key = (t.env.from.0, t.env.to.0);
            let mut deadline = t.deadline;
            if let Some(f) = floor.get(&key) {
                if *f > deadline {
                    deadline = *f;
                }
            }
            floor.insert(key, deadline);
            heap.push(Pending { deadline, seq, env: t.env });
            seq += 1;
        }
    }
}

/// Wait for `total` with sub-sleep-granularity precision without burning a
/// core: sleep through all but the last [`SPIN_RESIDUE`], then spin the
/// residue.  A pure busy-wait pinned a core for the full delay (the old
/// `Shaper` behavior); a pure sleep overshoots by the scheduler quantum,
/// which is larger than the sub-millisecond delays shaped sends model.
pub fn precise_wait(total: Duration) {
    /// Largest wait that is spun in full; longer waits sleep the excess
    /// first.  ~100 µs is safely above the sleep wake-up slop on Linux, so
    /// the residual spin still ends on time.
    const SPIN_RESIDUE: Duration = Duration::from_micros(100);
    if total.is_zero() {
        return;
    }
    let t0 = Instant::now();
    if total > SPIN_RESIDUE {
        std::thread::sleep(total - SPIN_RESIDUE);
    }
    while t0.elapsed() < total {
        std::hint::spin_loop();
    }
}

/// Delay model for emulating a slower interconnect on a laptop:
/// `hops × latency + doubles / bandwidth`, identical to the DES's
/// `NetworkModel::delay_between` (there is a parity test below).  Pure —
/// the waiting happens on the net thread, never in the caller.
#[derive(Debug, Clone, Copy)]
pub struct Shaper {
    pub latency: Duration,
    /// Doubles per second; `f64::INFINITY` disables the size term.
    pub doubles_per_sec: f64,
}

impl Shaper {
    /// The modeled wire delay for `doubles` over `hops` (bandwidth is paid
    /// once; latency per hop, with the same `hops ≥ 1` floor as the DES).
    pub fn delay_for(&self, doubles: u64, hops: u32) -> Duration {
        let size_s = if self.doubles_per_sec.is_finite() && self.doubles_per_sec > 0.0 {
            doubles as f64 / self.doubles_per_sec
        } else {
            0.0
        };
        self.latency * hops.max(1) + Duration::from_secs_f64(size_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::message::Msg;

    fn env(from: u32, to: u32) -> Envelope {
        Envelope {
            from: ProcessId(from),
            to: ProcessId(to),
            msg: Msg::OwnerDone { proc: ProcessId(from) },
            wire_doubles: 8,
        }
    }

    #[test]
    fn mesh_delivers_to_addressee_only() {
        let (router, boxes): (Router, Vec<Mailbox>) = mesh(3, None);
        router.send(env(0, 2)).expect("send");
        assert!(boxes[0].try_recv().is_none());
        assert!(boxes[1].try_recv().is_none());
        let got = boxes[2].try_recv().expect("delivered");
        assert_eq!(got.from, ProcessId(0));
    }

    #[test]
    fn unknown_destination_errors() {
        let (router, _boxes): (Router, Vec<Mailbox>) = mesh(2, None);
        assert!(router.send(env(0, 7)).is_err());
    }

    #[test]
    fn unknown_destination_fails_before_enqueue() {
        // a 50 ms shaper must not matter for a bad address: validation first,
        // and nothing reaches the net thread
        let sh = Shaper { latency: Duration::from_millis(50), doubles_per_sec: f64::INFINITY };
        let (router, _boxes): (Router, Vec<Mailbox>) = mesh(2, Some(sh));
        let t0 = Instant::now();
        assert!(router.send(env(0, 9)).is_err());
        assert!(
            t0.elapsed() < Duration::from_millis(40),
            "bad address burned shaper time: {:?}",
            t0.elapsed()
        );
    }

    /// The headline contract of the async outbox: the caller returns in
    /// well under a millisecond while the receiver still observes the full
    /// modeled (≥ 5 ms) delay.
    #[test]
    fn send_returns_immediately_receiver_sees_full_delay() {
        let sh = Shaper { latency: Duration::from_millis(5), doubles_per_sec: f64::INFINITY };
        let (router, boxes): (Router, Vec<Mailbox>) = mesh(2, Some(sh));
        let t0 = Instant::now();
        router.send(env(0, 1)).expect("send");
        let sent = t0.elapsed();
        assert!(sent < Duration::from_millis(1), "Router::send slept: {sent:?}");
        assert!(boxes[1].recv_timeout(Duration::from_secs(1)).is_some(), "delivered");
        let arrived = t0.elapsed();
        assert!(arrived >= Duration::from_millis(5), "arrived early: {arrived:?}");
    }

    #[test]
    fn topology_mesh_charges_per_hop_latency() {
        use crate::net::topology::Topology;
        let sh = Shaper { latency: Duration::from_millis(2), doubles_per_sec: f64::INFINITY };
        let (router, boxes): (Router, Vec<Mailbox>) =
            mesh_on(8, Some(sh), Topology::Ring { len: 8 });
        let t0 = Instant::now();
        router.send(env(0, 4)).expect("send"); // 4 hops on the ring
        assert!(t0.elapsed() < Duration::from_millis(2), "send must not wait the wire out");
        assert!(boxes[4].recv_timeout(Duration::from_secs(1)).is_some());
        assert!(t0.elapsed() >= Duration::from_millis(7), "4 hops × 2 ms expected");
    }

    #[test]
    fn fifo_per_sender() {
        let (router, boxes): (Router, Vec<Mailbox>) = mesh(2, None);
        for i in 0..10 {
            let mut e = env(0, 1);
            e.msg = Msg::OwnerDone { proc: ProcessId(i) };
            router.send(e).expect("send");
        }
        for i in 0..10 {
            match boxes[1].try_recv().expect("msg").msg {
                Msg::OwnerDone { proc } => assert_eq!(proc, ProcessId(i)),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    /// Deadline ordering alone would invert a big-then-small send (the small
    /// message's own deadline lands first); the per-(src,dst) floor clamps
    /// the small one behind the big one, keeping the pair FIFO.
    #[test]
    fn shaped_fifo_preserved_per_pair() {
        let sh = Shaper { latency: Duration::from_micros(100), doubles_per_sec: 1e6 };
        let (router, boxes): (Router, Vec<Mailbox>) = mesh(2, Some(sh));
        let mut big = env(0, 1);
        big.wire_doubles = 5000; // 5 ms of wire time
        big.msg = Msg::OwnerDone { proc: ProcessId(100) };
        let mut small = env(0, 1);
        small.wire_doubles = 0; // would arrive first unclamped
        small.msg = Msg::OwnerDone { proc: ProcessId(200) };
        router.send(big).expect("send big");
        router.send(small).expect("send small");
        let first = boxes[1].recv_timeout(Duration::from_secs(1)).expect("first");
        let second = boxes[1].recv_timeout(Duration::from_secs(1)).expect("second");
        match (first.msg, second.msg) {
            (Msg::OwnerDone { proc: a }, Msg::OwnerDone { proc: b }) => {
                assert_eq!(a, ProcessId(100), "send order must be arrival order");
                assert_eq!(b, ProcessId(200));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn shaped_messages_drain_after_routers_drop() {
        // the net thread must deliver what it holds even when every Router
        // is gone before the deadlines pass (shutdown-race traffic)
        let sh = Shaper { latency: Duration::from_millis(3), doubles_per_sec: f64::INFINITY };
        let (router, boxes): (Router, Vec<Mailbox>) = mesh(2, Some(sh));
        router.send(env(0, 1)).expect("send");
        drop(router);
        assert!(boxes[1].recv_timeout(Duration::from_secs(1)).is_some(), "drained on exit");
    }

    #[test]
    fn recv_timeout_expires() {
        let (_router, boxes): (Router, Vec<Mailbox>) = mesh(1, None);
        let t0 = Instant::now();
        assert!(boxes[0].recv_timeout(Duration::from_millis(10)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn cross_thread_delivery() {
        let (router, mut boxes): (Router, Vec<Mailbox>) = mesh(2, None);
        let mb1 = boxes.remove(1);
        let r2 = router.clone();
        let h = std::thread::spawn(move || {
            mb1.recv_timeout(Duration::from_secs(1)).expect("delivered").from
        });
        r2.send(env(0, 1)).expect("send");
        assert_eq!(h.join().expect("join"), ProcessId(0));
    }

    #[test]
    fn direct_sender_bypasses_shaping() {
        let sh = Shaper { latency: Duration::from_millis(50), doubles_per_sec: f64::INFINITY };
        let (router, boxes): (Router, Vec<Mailbox>) = mesh(2, Some(sh));
        router.direct_sender(ProcessId(1)).send(env(0, 1)).expect("send");
        // no 50 ms wait: the raw handle goes straight into the mailbox
        assert!(boxes[1].recv_timeout(Duration::from_millis(5)).is_some());
    }

    #[test]
    fn shaper_delay_for_latency_and_bandwidth_terms() {
        let sh = Shaper { latency: Duration::from_millis(2), doubles_per_sec: f64::INFINITY };
        assert_eq!(sh.delay_for(100, 1), Duration::from_millis(2));
        assert_eq!(sh.delay_for(0, 3), Duration::from_millis(6));
        let bw = Shaper { latency: Duration::ZERO, doubles_per_sec: 1e6 };
        let d = bw.delay_for(5000, 1); // 5 ms at 1e6 doubles/s
        assert!(d >= Duration::from_millis(4) && d <= Duration::from_millis(6), "{d:?}");
        // hops floor: 0 hops still pays one latency, like the DES
        assert_eq!(sh.delay_for(0, 0), Duration::from_millis(2));
    }

    /// Real mode and sim mode must price the wire identically: the Shaper
    /// is the wallclock twin of the DES's `NetworkModel::delay_between`.
    #[test]
    fn shaper_matches_des_cost_model() {
        use crate::net::topology::Topology;
        use crate::sim::network::NetworkModel;
        let topo = Topology::Ring { len: 8 };
        let nm = NetworkModel { latency: 0.003, doubles_per_sec: 2e6, topology: topo.clone() };
        let sh = Shaper { latency: Duration::from_secs_f64(0.003), doubles_per_sec: 2e6 };
        for (from, to, doubles) in [(0u32, 1u32, 0u64), (0, 4, 4096), (2, 7, 123), (5, 5, 64)] {
            let des = nm.delay_between(ProcessId(from), ProcessId(to), doubles);
            let hops = topo.hops(ProcessId(from), ProcessId(to));
            let real = sh.delay_for(doubles, hops).as_secs_f64();
            assert!((des - real).abs() < 1e-12, "{from}->{to} ({doubles}): des={des} real={real}");
        }
    }

    #[test]
    fn precise_wait_is_accurate_above_and_below_the_sleep_cutoff() {
        for total in [Duration::from_micros(50), Duration::from_millis(2)] {
            let t0 = Instant::now();
            precise_wait(total);
            let elapsed = t0.elapsed();
            assert!(elapsed >= total, "{elapsed:?} < {total:?}");
        }
        // zero is a no-op, not a panic
        precise_wait(Duration::ZERO);
    }
}
