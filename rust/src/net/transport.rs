//! Message transport for the threaded real mode: a full mpsc mailbox mesh
//! with an optional latency/bandwidth shaper.
//!
//! The DES does not use this (it delivers envelopes through its event heap —
//! `sim::network`); the `Router`/`Mailbox` pair is the real-mode equivalent
//! with wallclock semantics.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

use crate::core::ids::ProcessId;

use super::message::Envelope;
use super::topology::Topology;

/// Sender side: can address any process.
#[derive(Clone)]
pub struct Router {
    senders: Vec<Sender<Envelope>>,
    shaper: Option<Shaper>,
    topology: Topology,
}

/// Receiver side: one per process.
pub struct Mailbox {
    pub me: ProcessId,
    rx: Receiver<Envelope>,
}

/// Build a fully-connected mesh for `p` processes (flat topology).
pub fn mesh(p: usize, shaper: Option<Shaper>) -> (Router, Vec<Mailbox>) {
    mesh_on(p, shaper, Topology::Flat)
}

/// Build a mesh whose shaper charges `hops(from, to)` of latency per
/// message — the threaded-mode counterpart of the DES topology model.
pub fn mesh_on(p: usize, shaper: Option<Shaper>, topology: Topology) -> (Router, Vec<Mailbox>) {
    let mut senders = Vec::with_capacity(p);
    let mut mailboxes = Vec::with_capacity(p);
    for i in 0..p {
        let (tx, rx) = channel();
        senders.push(tx);
        mailboxes.push(Mailbox { me: ProcessId(i as u32), rx });
    }
    (Router { senders, shaper, topology }, mailboxes)
}

impl Router {
    /// Send an envelope to its destination; applies the shaper's serial
    /// delay at the *sender* (models NIC injection time).
    ///
    /// The destination is validated **before** the shaper runs: a bad
    /// address must fail fast, not burn simulated NIC time first.
    ///
    /// Sending to a process that has already halted (mailbox dropped) is
    /// not an error: during shutdown, in-flight DLB traffic may race the
    /// `Shutdown` broadcast, and the halted peer would have discarded the
    /// message anyway.
    pub fn send(&self, env: Envelope) -> Result<(), String> {
        let to = env.to.idx();
        if to >= self.senders.len() {
            return Err(format!("no such process: {}", env.to));
        }
        if let Some(sh) = &self.shaper {
            sh.delay_hops(env.wire_doubles, self.topology.hops(env.from, env.to));
        }
        let _ = self.senders[to].send(env); // closed mailbox == halted peer
        Ok(())
    }

    pub fn num_processes(&self) -> usize {
        self.senders.len()
    }
}

impl Mailbox {
    /// Non-blocking poll.
    pub fn try_recv(&self) -> Option<Envelope> {
        match self.rx.try_recv() {
            Ok(e) => Some(e),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&self, d: Duration) -> Option<Envelope> {
        self.rx.recv_timeout(d).ok()
    }
}

/// Wait for `total` with sub-sleep-granularity precision without burning a
/// core: sleep through all but the last [`SPIN_RESIDUE`], then spin the
/// residue.  A pure busy-wait pinned a core for the full delay (the old
/// `Shaper` behavior); a pure sleep overshoots by the scheduler quantum,
/// which is larger than the sub-millisecond delays shaped sends model.
pub fn precise_wait(total: Duration) {
    /// Largest wait that is spun in full; longer waits sleep the excess
    /// first.  ~100 µs is safely above the sleep wake-up slop on Linux, so
    /// the residual spin still ends on time.
    const SPIN_RESIDUE: Duration = Duration::from_micros(100);
    if total.is_zero() {
        return;
    }
    let t0 = Instant::now();
    if total > SPIN_RESIDUE {
        std::thread::sleep(total - SPIN_RESIDUE);
    }
    while t0.elapsed() < total {
        std::hint::spin_loop();
    }
}

/// Optional outbound delay to emulate a slower interconnect on a laptop:
/// `latency + doubles/bandwidth` of [`precise_wait`] (sleep alone is too
/// coarse under 1 ms on Linux for the sizes involved; spinning alone
/// burned a full core per shaped sender).
#[derive(Debug, Clone, Copy)]
pub struct Shaper {
    pub latency: Duration,
    /// Doubles per second; `f64::INFINITY` disables the size term.
    pub doubles_per_sec: f64,
}

impl Shaper {
    pub fn delay(&self, doubles: u64) {
        self.delay_hops(doubles, 1)
    }

    /// Wait out `hops × latency + size / bandwidth` — the topology-aware
    /// injection delay (bandwidth is paid once; latency per hop).
    pub fn delay_hops(&self, doubles: u64, hops: u32) {
        let size_s = if self.doubles_per_sec.is_finite() && self.doubles_per_sec > 0.0 {
            doubles as f64 / self.doubles_per_sec
        } else {
            0.0
        };
        precise_wait(self.latency * hops.max(1) + Duration::from_secs_f64(size_s));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::message::Msg;

    fn env(from: u32, to: u32) -> Envelope {
        Envelope {
            from: ProcessId(from),
            to: ProcessId(to),
            msg: Msg::OwnerDone { proc: ProcessId(from) },
            wire_doubles: 8,
        }
    }

    #[test]
    fn mesh_delivers_to_addressee_only() {
        let (router, boxes) = mesh(3, None);
        router.send(env(0, 2)).expect("send");
        assert!(boxes[0].try_recv().is_none());
        assert!(boxes[1].try_recv().is_none());
        let got = boxes[2].try_recv().expect("delivered");
        assert_eq!(got.from, ProcessId(0));
    }

    #[test]
    fn unknown_destination_errors() {
        let (router, _boxes) = mesh(2, None);
        assert!(router.send(env(0, 7)).is_err());
    }

    #[test]
    fn unknown_destination_fails_before_shaper_delay() {
        // a 50 ms shaper must NOT run for a bad address: validation first
        let sh = Shaper { latency: Duration::from_millis(50), doubles_per_sec: f64::INFINITY };
        let (router, _boxes) = mesh(2, Some(sh));
        let t0 = Instant::now();
        assert!(router.send(env(0, 9)).is_err());
        assert!(
            t0.elapsed() < Duration::from_millis(40),
            "bad address burned shaper time: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn topology_mesh_charges_per_hop_latency() {
        use crate::net::topology::Topology;
        let sh = Shaper { latency: Duration::from_millis(2), doubles_per_sec: f64::INFINITY };
        let (router, boxes) = mesh_on(8, Some(sh), Topology::Ring { len: 8 });
        let t0 = Instant::now();
        router.send(env(0, 4)).expect("send"); // 4 hops on the ring
        assert!(t0.elapsed() >= Duration::from_millis(7), "4 hops × 2 ms expected");
        assert!(boxes[4].try_recv().is_some());
    }

    #[test]
    fn fifo_per_sender() {
        let (router, boxes) = mesh(2, None);
        for i in 0..10 {
            let mut e = env(0, 1);
            e.msg = Msg::OwnerDone { proc: ProcessId(i) };
            router.send(e).expect("send");
        }
        for i in 0..10 {
            match boxes[1].try_recv().expect("msg").msg {
                Msg::OwnerDone { proc } => assert_eq!(proc, ProcessId(i)),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn recv_timeout_expires() {
        let (_router, boxes) = mesh(1, None);
        let t0 = Instant::now();
        assert!(boxes[0].recv_timeout(Duration::from_millis(10)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn cross_thread_delivery() {
        let (router, mut boxes) = mesh(2, None);
        let mb1 = boxes.remove(1);
        let r2 = router.clone();
        let h = std::thread::spawn(move || {
            mb1.recv_timeout(Duration::from_secs(1)).expect("delivered").from
        });
        r2.send(env(0, 1)).expect("send");
        assert_eq!(h.join().expect("join"), ProcessId(0));
    }

    #[test]
    fn shaper_adds_measurable_delay() {
        let sh = Shaper { latency: Duration::from_millis(2), doubles_per_sec: f64::INFINITY };
        let t0 = Instant::now();
        sh.delay(100);
        assert!(t0.elapsed() >= Duration::from_millis(2));
    }

    #[test]
    fn shaper_bandwidth_term() {
        let sh = Shaper { latency: Duration::ZERO, doubles_per_sec: 1e6 };
        let t0 = Instant::now();
        sh.delay(5000); // 5 ms at 1e6 doubles/s
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn precise_wait_is_accurate_above_and_below_the_sleep_cutoff() {
        for total in [Duration::from_micros(50), Duration::from_millis(2)] {
            let t0 = Instant::now();
            precise_wait(total);
            let elapsed = t0.elapsed();
            assert!(elapsed >= total, "{elapsed:?} < {total:?}");
        }
        // zero is a no-op, not a panic
        precise_wait(Duration::ZERO);
    }
}
