//! W_T calibration (§6).
//!
//! The paper calibrates offline: run once without DLB, record the maximum
//! workload over all processes and times, and set `W_T = max w_i(t) / 2`.
//! It also sketches a production alternative — a locally-adapted threshold —
//! which `AdaptiveThreshold` implements: an exponential moving average of
//! the local workload, clamped by the §4 cost-model guideline.

use crate::core::task::TaskKind;
use crate::metrics::trace::RunTraces;

use super::costmodel::CostModel;

/// The paper's offline rule: W_T = ⌈max_{i,t} w_i(t) / 2⌉ (at least 1).
pub fn calibrate_from_traces(traces: &RunTraces) -> usize {
    (traces.max_workload() / 2).max(1)
}

/// Locally-adapting threshold (the production variant suggested in §6):
/// tracks an EWMA of the observed workload and sets W_T to half its current
/// estimate, never below the §4 guideline floor for the dominant task kind.
#[derive(Debug, Clone)]
pub struct AdaptiveThreshold {
    ewma: f64,
    alpha: f64,
    floor: usize,
}

impl AdaptiveThreshold {
    /// `alpha` ∈ (0, 1]: smoothing factor; `kind`/`block` set the §4 floor.
    pub fn new(initial_wt: usize, alpha: f64, model: &CostModel, kind: TaskKind, block: u64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        // For compute-bound kinds the guideline is ~1 and the floor is moot;
        // for gemv-like kinds it is ~Q ≈ 20 (§4's "20 tasks per export").
        let floor = if kind == TaskKind::Synthetic { 1 } else { model.wt_guideline(kind, block) };
        AdaptiveThreshold { ewma: 2.0 * initial_wt as f64, alpha, floor: floor.max(1) }
    }

    /// Observe the local workload; returns the updated threshold.
    pub fn observe(&mut self, w: usize) -> usize {
        self.ewma = (1.0 - self.alpha) * self.ewma + self.alpha * w as f64;
        self.current()
    }

    pub fn current(&self) -> usize {
        ((self.ewma / 2.0).round() as usize).max(self.floor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ids::ProcessId;

    #[test]
    fn offline_rule_matches_paper() {
        let mut traces = RunTraces::new(3);
        traces.record(ProcessId(0), 0.0, 4);
        traces.record(ProcessId(1), 1.0, 10); // max
        traces.record(ProcessId(2), 2.0, 7);
        assert_eq!(calibrate_from_traces(&traces), 5); // 10/2, the §6 value
    }

    #[test]
    fn offline_rule_floors_at_one() {
        let traces = RunTraces::new(2);
        assert_eq!(calibrate_from_traces(&traces), 1);
    }

    #[test]
    fn adaptive_tracks_load() {
        let m = CostModel::new(8.8e9, 2.2e8);
        let mut t = AdaptiveThreshold::new(5, 0.5, &m, TaskKind::Gemm, 512);
        // workload settles around 30 → threshold toward 15
        for _ in 0..50 {
            t.observe(30);
        }
        assert!((14..=16).contains(&t.current()), "{}", t.current());
        // workload collapses → threshold follows down to the floor
        for _ in 0..50 {
            t.observe(0);
        }
        assert_eq!(t.current(), 1);
    }

    #[test]
    fn adaptive_respects_gemv_floor() {
        let m = CostModel::new(8.8e9, 2.2e8);
        let mut t = AdaptiveThreshold::new(2, 0.5, &m, TaskKind::Gemv, 512);
        for _ in 0..50 {
            t.observe(0);
        }
        // §4: don't export gemv until ~20 tasks remain per export
        assert!(t.current() >= 19, "{}", t.current());
    }
}
