//! Runtime performance recorder — the measurement layer behind the Smart
//! strategy (§3): "each process records the average time for running tasks
//! of each type as well as times for communicating tasks of each type and
//! data of a certain size".
//!
//! Falls back to the analytic `CostModel` for kinds never yet observed, so
//! Smart behaves sensibly from the first transaction.

use crate::core::task::{TaskKind, TaskNode};
use crate::util::stats::Running;

use super::costmodel::CostModel;

/// Exponentially-weighted per-kind execution-time estimates plus a linear
/// communication model fitted from observed (doubles, seconds) pairs.
#[derive(Debug, Clone)]
pub struct PerfRecorder {
    exec: [Running; 6],
    /// Observed transfer samples: Σxy, Σx, Σy, Σx², n — least-squares line
    /// through (doubles, seconds) for the communication-time model.
    comm_sxy: f64,
    comm_sx: f64,
    comm_sy: f64,
    comm_sxx: f64,
    comm_n: f64,
    fallback: CostModel,
}

impl PerfRecorder {
    pub fn new(fallback: CostModel) -> Self {
        PerfRecorder {
            exec: Default::default(),
            comm_sxy: 0.0,
            comm_sx: 0.0,
            comm_sy: 0.0,
            comm_sxx: 0.0,
            comm_n: 0.0,
            fallback,
        }
    }

    /// Record a completed execution of `kind` that took `secs`.
    pub fn record_exec(&mut self, kind: TaskKind, secs: f64) {
        self.exec[kind.index()].push(secs);
    }

    /// Record an observed transfer of `doubles` taking `secs`.
    pub fn record_comm(&mut self, doubles: u64, secs: f64) {
        let x = doubles as f64;
        self.comm_sxy += x * secs;
        self.comm_sx += x;
        self.comm_sy += secs;
        self.comm_sxx += x * x;
        self.comm_n += 1.0;
    }

    /// Expected execution time of one task of `kind` with `flops`.
    pub fn exec_estimate(&self, kind: TaskKind, flops: u64) -> f64 {
        let r = &self.exec[kind.index()];
        if r.count() >= 3 {
            r.mean()
        } else {
            self.fallback.local_time(flops)
        }
    }

    /// Expected wire time for `doubles` (fitted latency + bandwidth line, or
    /// the analytic model until ≥ 3 samples exist).
    pub fn comm_estimate(&self, doubles: u64) -> f64 {
        if self.comm_n >= 3.0 {
            let denom = self.comm_n * self.comm_sxx - self.comm_sx * self.comm_sx;
            if denom.abs() > 1e-30 {
                let slope = (self.comm_n * self.comm_sxy - self.comm_sx * self.comm_sy) / denom;
                let intercept = (self.comm_sy - slope * self.comm_sx) / self.comm_n;
                let est = intercept + slope * doubles as f64;
                if est.is_finite() && est >= 0.0 {
                    return est;
                }
            }
        }
        self.fallback.latency + self.fallback.transfer_time(doubles)
    }

    /// Expected time for `node` to run remotely and return: ship inputs,
    /// wait out the remote queue (`remote_eta`), execute, return output.
    pub fn remote_completion(&self, node: &TaskNode, remote_eta: f64) -> f64 {
        self.comm_estimate(node.in_doubles)
            + remote_eta
            + self.exec_estimate(node.kind, node.flops)
            + self.comm_estimate(node.out_doubles)
    }

    /// Expected time for `node` to complete locally if it sits behind
    /// `queue_ahead` tasks of average cost (paper: local queuing + exec).
    pub fn local_completion(&self, node: &TaskNode, queue_ahead: usize, avg_queue_task: f64) -> f64 {
        queue_ahead as f64 * avg_queue_task + self.exec_estimate(node.kind, node.flops)
    }

    /// Average execution time across every kind observed (weighted by count);
    /// analytic gemm-at-64 estimate if nothing is recorded yet.
    pub fn avg_any_exec(&self) -> f64 {
        let mut total = 0.0;
        let mut n = 0u64;
        for r in &self.exec {
            if r.count() > 0 {
                total += r.mean() * r.count() as f64;
                n += r.count();
            }
        }
        if n > 0 {
            total / n as f64
        } else {
            self.fallback.local_time(TaskKind::Gemm.flops_for_block(64))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ids::{DataId, ProcessId, TaskId};

    fn model() -> CostModel {
        let mut m = CostModel::new(1e9, 1e8);
        m.latency = 1e-6;
        m
    }

    fn node(kind: TaskKind, flops: u64, ind: u64, outd: u64) -> TaskNode {
        TaskNode {
            id: TaskId(0),
            kind,
            placement: ProcessId(0),
            args: vec![],
            output: DataId(0),
            flops,
            in_doubles: ind,
            out_doubles: outd,
            deps: vec![],
            dependents: vec![],
            v0_args: vec![],
        }
    }

    #[test]
    fn falls_back_to_analytic_until_warm() {
        let p = PerfRecorder::new(model());
        let est = p.exec_estimate(TaskKind::Gemm, 1_000_000);
        assert!((est - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn exec_estimate_converges_to_observations() {
        let mut p = PerfRecorder::new(model());
        for _ in 0..10 {
            p.record_exec(TaskKind::Gemm, 0.5);
        }
        assert!((p.exec_estimate(TaskKind::Gemm, 1) - 0.5).abs() < 1e-12);
        // other kinds unaffected
        assert!((p.exec_estimate(TaskKind::Trsm, 1_000_000) - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn comm_fit_recovers_line() {
        let mut p = PerfRecorder::new(model());
        // ground truth: 2 µs + doubles/1e8
        for &d in &[1_000u64, 10_000, 100_000, 500_000] {
            p.record_comm(d, 2e-6 + d as f64 / 1e8);
        }
        let est = p.comm_estimate(50_000);
        let truth = 2e-6 + 50_000.0 / 1e8;
        assert!((est - truth).abs() < truth * 0.05, "est {est} vs {truth}");
    }

    #[test]
    fn remote_vs_local_completion_tradeoff() {
        let p = PerfRecorder::new(model());
        let big = node(TaskKind::Gemm, 2 * 512 * 512 * 512, 3 * 512 * 512, 512 * 512);
        // deep local queue → remote wins even with transfer
        let local = p.local_completion(&big, 20, p.exec_estimate(TaskKind::Gemm, big.flops));
        let remote = p.remote_completion(&big, 0.0);
        assert!(remote < local);
        // empty local queue → local wins
        let local0 = p.local_completion(&big, 0, 0.0);
        assert!(remote > local0);
    }

    #[test]
    fn avg_any_exec_weights_counts() {
        let mut p = PerfRecorder::new(model());
        p.record_exec(TaskKind::Gemm, 1.0);
        p.record_exec(TaskKind::Gemm, 1.0);
        p.record_exec(TaskKind::Gemm, 1.0);
        p.record_exec(TaskKind::Potrf, 4.0);
        assert!((p.avg_any_exec() - 1.75).abs() < 1e-12);
    }
}
