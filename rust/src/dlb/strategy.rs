//! The three export strategies of §3.
//!
//! When a busy–idle pair has formed, the busy side decides *which* ready
//! tasks to export:
//!
//! 1. **Basic** — no extra information: send the excess above W_T.
//! 2. **Equalizing** — the idle side's load `w_j` rides on the request; send
//!    `w_i − (w_i + w_j)/2` tasks.
//! 3. **Smart** — the idle side's queue ETA rides on the request; export
//!    only tasks whose predicted remote completion (ship + remote queue +
//!    exec + return) beats their predicted local completion (local queue +
//!    exec).

use crate::config::Strategy;
use crate::core::graph::TaskGraph;
use crate::core::ids::ProcessId;
use crate::sched::queue::{ReadyQueue, ReadyTask};

use super::perfmodel::PerfRecorder;

/// What the busy side knows about its idle partner when exporting.
#[derive(Debug, Clone, Copy)]
pub struct PartnerInfo {
    pub load: usize,
    /// Expected time for the partner to drain its current queue, seconds.
    pub eta: f64,
}

/// Select tasks to export from `queue` (removing them).
///
/// Shared constraints, all strategies:
/// - migrated tasks MAY be re-exported (their `origin` rides along so the
///   result still returns home) — this is what lets load "propagate to
///   anywhere in the system" (§7), unlike diffusion;
/// - the remaining local queue never drops below W_T (the busy process must
///   not make itself idle — §3's overshoot discussion);
/// - at most `w − W_T` tasks leave regardless of strategy arithmetic.
pub fn select_exports(
    strategy: Strategy,
    me: ProcessId,
    queue: &mut ReadyQueue,
    graph: &TaskGraph,
    wt: usize,
    partner: PartnerInfo,
    perf: &PerfRecorder,
) -> Vec<ReadyTask> {
    let _ = me;
    let w = queue.workload();
    if w <= wt {
        return Vec::new();
    }
    let excess = w - wt;
    let count = match strategy {
        // 1. Basic: everything above the threshold.
        Strategy::Basic => excess,
        // 2. Equalizing: meet in the middle; never below W_T.
        Strategy::Equalizing => {
            let target = (w + partner.load) / 2;
            w.saturating_sub(target.max(wt)).min(excess)
        }
        Strategy::Smart => excess, // upper bound; the predicate decides
    };
    if count == 0 {
        return Vec::new();
    }

    match strategy {
        Strategy::Basic | Strategy::Equalizing => queue.drain_back(count, |_| true),
        Strategy::Smart => {
            // Predict per task. Tasks near the queue back have the largest
            // local queuing delay, so iterate back-to-front; `ahead` is the
            // number of tasks that would run before this one locally.
            // The average queue task cost comes from the queue itself via
            // the recorder's estimates (not a fixed fallback).
            let avg = {
                let (mut sum, mut n) = (0.0, 0usize);
                for rt in queue.iter() {
                    let node = graph.task(rt.task);
                    sum += perf.exec_estimate(node.kind, node.flops);
                    n += 1;
                }
                if n > 0 { sum / n as f64 } else { perf.avg_any_exec() }
            };
            // cumulative remote queue: each exported task extends the
            // partner's expected queue by its own exec estimate.
            let mut remote_eta = partner.eta;
            let mut ahead = queue.workload();
            queue.drain_back(count, |t| {
                ahead = ahead.saturating_sub(1);
                let node = graph.task(t.task);
                let local = perf.local_completion(node, ahead, avg);
                let remote = perf.remote_completion(node, remote_eta);
                if remote < local {
                    remote_eta += perf.exec_estimate(node.kind, node.flops);
                    true
                } else {
                    false
                }
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::graph::GraphBuilder;
    use crate::core::ids::TaskId;
    use crate::core::task::TaskKind;
    use crate::dlb::costmodel::CostModel;

    fn setup(n_tasks: usize, kind: TaskKind, block: usize) -> (std::sync::Arc<TaskGraph>, ReadyQueue) {
        let mut b = GraphBuilder::new();
        let mut q = ReadyQueue::new();
        for i in 0..n_tasks {
            let c = b.data(ProcessId(0), block, block);
            let x = b.data(ProcessId(0), block, block);
            let y = b.data(ProcessId(0), block, block);
            let t = b.task(
                kind,
                vec![c, x, y],
                c,
                kind.flops_for_block(block as u64),
                None,
            );
            q.push(ReadyTask { task: t, origin: ProcessId(0) });
            let _ = i;
        }
        (b.build(), q)
    }

    fn perf() -> PerfRecorder {
        let mut m = CostModel::new(8.8e9, 2.2e8);
        m.latency = 2e-6;
        PerfRecorder::new(m)
    }

    #[test]
    fn basic_exports_excess_above_wt() {
        let (g, mut q) = setup(12, TaskKind::Gemm, 64);
        let got = select_exports(
            Strategy::Basic,
            ProcessId(0),
            &mut q,
            &g,
            5,
            PartnerInfo { load: 0, eta: 0.0 },
            &perf(),
        );
        assert_eq!(got.len(), 7);
        assert_eq!(q.workload(), 5); // exactly W_T remain
    }

    #[test]
    fn basic_noop_when_at_threshold() {
        let (g, mut q) = setup(5, TaskKind::Gemm, 64);
        let got = select_exports(
            Strategy::Basic,
            ProcessId(0),
            &mut q,
            &g,
            5,
            PartnerInfo { load: 0, eta: 0.0 },
            &perf(),
        );
        assert!(got.is_empty());
    }

    #[test]
    fn equalizing_meets_in_middle() {
        let (g, mut q) = setup(12, TaskKind::Gemm, 64);
        // w=12, partner 2 → target 7, send 5
        let got = select_exports(
            Strategy::Equalizing,
            ProcessId(0),
            &mut q,
            &g,
            2,
            PartnerInfo { load: 2, eta: 0.0 },
            &perf(),
        );
        assert_eq!(got.len(), 5);
        assert_eq!(q.workload(), 7);
    }

    #[test]
    fn equalizing_never_dips_below_wt() {
        let (g, mut q) = setup(8, TaskKind::Gemm, 64);
        // w=8, partner 0 → naive target 4 < wt 6 → send only down to wt
        let got = select_exports(
            Strategy::Equalizing,
            ProcessId(0),
            &mut q,
            &g,
            6,
            PartnerInfo { load: 0, eta: 0.0 },
            &perf(),
        );
        assert_eq!(got.len(), 2);
        assert_eq!(q.workload(), 6);
    }

    #[test]
    fn smart_exports_high_intensity_tasks() {
        // big gemm blocks: migration is nearly free, deep queue → export
        let (g, mut q) = setup(12, TaskKind::Gemm, 512);
        let got = select_exports(
            Strategy::Smart,
            ProcessId(0),
            &mut q,
            &g,
            2,
            PartnerInfo { load: 0, eta: 0.0 },
            &perf(),
        );
        assert!(!got.is_empty(), "high-intensity tasks should migrate");
    }

    /// GEMV tasks with the real argument shapes (matrix + vector).
    fn setup_gemv(n_tasks: usize, block: usize) -> (std::sync::Arc<TaskGraph>, ReadyQueue) {
        let mut b = GraphBuilder::new();
        let mut q = ReadyQueue::new();
        for _ in 0..n_tasks {
            let a = b.data(ProcessId(0), block, block);
            let x = b.data(ProcessId(0), block, 1);
            let y = b.data(ProcessId(0), block, 1);
            let t = b.task(
                TaskKind::Gemv,
                vec![a, x],
                y,
                TaskKind::Gemv.flops_for_block(block as u64),
                None,
            );
            q.push(ReadyTask { task: t, origin: ProcessId(0) });
        }
        (b.build(), q)
    }

    #[test]
    fn smart_holds_low_intensity_tasks() {
        // gemv: Q ≈ 20 — with a shallow queue nothing should migrate
        let (g, mut q) = setup_gemv(7, 256);
        let got = select_exports(
            Strategy::Smart,
            ProcessId(0),
            &mut q,
            &g,
            2,
            PartnerInfo { load: 0, eta: 0.0 },
            &perf(),
        );
        assert!(got.is_empty(), "gemv with shallow queue must stay local, got {got:?}");
    }

    #[test]
    fn smart_exports_low_intensity_when_queue_very_deep() {
        // same gemv tasks but queue much deeper than Q≈20 → exporting pays
        let (g, mut q) = setup_gemv(60, 256);
        let got = select_exports(
            Strategy::Smart,
            ProcessId(0),
            &mut q,
            &g,
            2,
            PartnerInfo { load: 0, eta: 0.0 },
            &perf(),
        );
        assert!(!got.is_empty(), "deep gemv queue should export");
        assert!(q.workload() >= 2);
    }

    #[test]
    fn wt_zero_basic_empties_the_queue() {
        // W_T = 0: the busy side may hand over everything it has queued
        let (g, mut q) = setup(6, TaskKind::Gemm, 64);
        let got = select_exports(
            Strategy::Basic,
            ProcessId(0),
            &mut q,
            &g,
            0,
            PartnerInfo { load: 0, eta: 0.0 },
            &perf(),
        );
        assert_eq!(got.len(), 6);
        assert_eq!(q.workload(), 0);
    }

    #[test]
    fn wt_zero_empty_queue_exports_nothing() {
        // w = 0 = W_T: nothing to give, and no underflow panic
        let (g, mut q) = setup(0, TaskKind::Gemm, 64);
        let got = select_exports(
            Strategy::Basic,
            ProcessId(0),
            &mut q,
            &g,
            0,
            PartnerInfo { load: 0, eta: 0.0 },
            &perf(),
        );
        assert!(got.is_empty());
        let (g, mut q) = setup(3, TaskKind::Gemm, 64);
        let got = select_exports(
            Strategy::Equalizing,
            ProcessId(0),
            &mut q,
            &g,
            0,
            PartnerInfo { load: 3, eta: 0.0 },
            &perf(),
        );
        assert!(got.is_empty(), "equal loads at wt=0 → no transfer: {got:?}");
        assert_eq!(q.workload(), 3);
    }

    #[test]
    fn equalizing_partner_load_at_or_above_own_sends_nothing() {
        // partner as loaded as us (or more): target ≥ w → zero export,
        // even though w is above W_T
        for partner_load in [12usize, 20, 100] {
            let (g, mut q) = setup(12, TaskKind::Gemm, 64);
            let got = select_exports(
                Strategy::Equalizing,
                ProcessId(0),
                &mut q,
                &g,
                2,
                PartnerInfo { load: partner_load, eta: 0.0 },
                &perf(),
            );
            assert!(got.is_empty(), "partner load {partner_load} must yield nothing: {got:?}");
            assert_eq!(q.workload(), 12, "queue untouched");
        }
    }

    #[test]
    fn smart_rejecting_every_candidate_leaves_queue_intact() {
        // low-intensity gemv with a long remote eta: every per-task
        // prediction says "stay local" — the predicate must restore the
        // queue in its original order with nothing exported
        let (g, mut q) = setup_gemv(7, 256);
        let before: Vec<_> = q.iter().map(|rt| rt.task).collect();
        let got = select_exports(
            Strategy::Smart,
            ProcessId(0),
            &mut q,
            &g,
            2,
            PartnerInfo { load: 50, eta: 10.0 },
            &perf(),
        );
        assert!(got.is_empty(), "hostile partner eta must reject all: {got:?}");
        assert_eq!(q.workload(), 7);
        let after: Vec<_> = q.iter().map(|rt| rt.task).collect();
        assert_eq!(before, after, "rejected scan must not reorder the queue");
    }

    #[test]
    fn migrated_tasks_reexport_preserving_origin() {
        // §7: load must be able to propagate through intermediaries, so
        // stolen tasks are re-exportable — with their origin intact.
        let mut b = GraphBuilder::new();
        let c = b.data(ProcessId(0), 64, 64);
        let t = b.task(TaskKind::Gemm, vec![c], c, 1000, None);
        let g2 = b.build();
        let mut q = ReadyQueue::new();
        for _ in 0..8 {
            q.push(ReadyTask { task: t, origin: ProcessId(9) }); // all stolen
        }
        let got = select_exports(
            Strategy::Basic,
            ProcessId(0),
            &mut q,
            &g2,
            2,
            PartnerInfo { load: 0, eta: 0.0 },
            &perf(),
        );
        assert_eq!(got.len(), 6);
        assert!(got.iter().all(|rt| rt.origin == ProcessId(9)), "origin preserved");
        assert_eq!(q.workload(), 2);
        let _ = TaskId(0);
    }
}
