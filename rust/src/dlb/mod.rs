//! The paper's contribution: distributed dynamic load balancing.
//!
//! - `policy` — the pluggable balancer subsystem: the paper's random
//!   pairing plus work stealing, hierarchical locality-aware stealing and
//!   topology diffusion, behind one trait, with an optional AIMD adaptive-δ
//!   wrapper;
//! - `pairing` — the randomized idle–busy partner search (§3, Fig 1/3);
//! - `strategy` — the Basic / Equalizing / Smart export policies (§3);
//! - `costmodel` — the analytic migration cost model (§4);
//! - `perfmodel` — the runtime performance recorder feeding Smart (§3);
//! - `threshold` — W_T calibration helpers (§6).

pub mod costmodel;
pub mod pairing;
pub mod perfmodel;
pub mod policy;
pub mod strategy;
pub mod threshold;

pub use costmodel::CostModel;
pub use pairing::{PairAction, Pairing, PairingConfig, PairStatus};
pub use perfmodel::PerfRecorder;
pub use policy::{
    AdaptiveConfig, AdaptiveDelta, BalancerPolicy, Diffusion, HierarchicalStealing, PolicyAction,
    PolicyObs, PolicySpec, RandomPairing, WorkStealing,
};
pub use strategy::{select_exports, PartnerInfo};
