//! Neighborhood diffusion as a [`BalancerPolicy`].
//!
//! First-order diffusive load balancing (cf. "Balancing indivisible
//! real-valued loads in arbitrary networks", Demirel & Sbalzarini 2013):
//! every δ each process broadcasts its workload to its **topology
//! neighbors** and pushes `⌊α·(w_i − w_j)⌋` tasks toward each neighbor `j`
//! it believes is lighter, with the standard stable diffusion coefficient
//! `α = 1/(deg + 1)`.
//!
//! Contrast with the other two policies: no handshake, no randomness in
//! partner choice, and strictly local information — load crosses the
//! machine only by flowing hop-by-hop through the topology, which is
//! exactly the propagation weakness (§7 of the paper) that random pairing
//! and stealing do not have.  On a flat topology the neighbor set is
//! everyone and diffusion degenerates to global averaging.

use crate::core::ids::ProcessId;
use crate::dlb::pairing::PairingConfig;
use crate::metrics::counters::DlbCounters;
use crate::net::message::Msg;
use crate::util::rng::Rng;

use super::{BalancerPolicy, PolicyAction, PolicyObs};

/// Sentinel for "no load report received yet from this process".
const NO_REPORT: usize = usize::MAX;

pub struct Diffusion {
    cfg: PairingConfig,
    next_exchange_at: f64,
    /// Latest load each neighbor reported, dense-indexed by process id
    /// (`NO_REPORT` until the first report) — neighbor sets are small and
    /// lookups sit on the per-exchange hot path, so a flat table beats a
    /// hash map.
    neighbor_loads: Vec<usize>,
    next_round: u64,
    pub counters: DlbCounters,
}

impl Diffusion {
    pub fn new(me: ProcessId, cfg: PairingConfig) -> Self {
        let _ = me; // per-process identity lives in the neighbor set
        Diffusion {
            cfg,
            next_exchange_at: 0.0,
            neighbor_loads: Vec::new(),
            next_round: 1,
            counters: DlbCounters::default(),
        }
    }

    fn load_of(&self, q: ProcessId) -> Option<usize> {
        self.neighbor_loads.get(q.idx()).copied().filter(|&w| w != NO_REPORT)
    }

    fn set_load(&mut self, q: ProcessId, load: usize) {
        if q.idx() >= self.neighbor_loads.len() {
            self.neighbor_loads.resize(q.idx() + 1, NO_REPORT);
        }
        self.neighbor_loads[q.idx()] = load;
    }
}

impl BalancerPolicy for Diffusion {
    fn name(&self) -> &'static str {
        "diffusion"
    }

    fn init(&mut self, now: f64, rng: &mut Rng) {
        // stagger exchanges uniformly over one period
        self.next_exchange_at = now + rng.next_f64() * self.cfg.delta;
    }

    fn poll(&mut self, obs: &mut PolicyObs<'_>, now: f64, out: &mut Vec<PolicyAction>) {
        if now < self.next_exchange_at || obs.middle_zone || obs.neighbors.is_empty() {
            return;
        }
        // Slight jitter keeps neighbors from exchanging in global lock-step.
        self.next_exchange_at = now + self.cfg.delta * (0.75 + 0.5 * obs.rng.next_f64());
        self.counters.rounds += 1;

        // 1. Tell every neighbor our load (their gradient input).
        for &q in obs.neighbors {
            self.counters.requests_sent += 1;
            out.push(PolicyAction::Send { to: q, msg: Msg::LoadReport { load: obs.workload } });
        }

        // 2. Push flow down the gradient: α(w_i − w_j) toward each lighter
        //    neighbor, bounded by our remaining excess above W_T.  An
        //    exchange that moves nothing is this policy's "failed round" —
        //    the quiescence signal the adaptive-δ controller lengthens the
        //    period on.
        let alpha = 1.0 / (obs.neighbors.len() as f64 + 1.0);
        let mut budget = obs.workload.saturating_sub(obs.wt);
        if budget == 0 {
            self.counters.failed_rounds += 1;
            return;
        }
        let mut flowed = false;
        for &q in obs.neighbors {
            let Some(wj) = self.load_of(q) else { continue };
            if wj >= obs.workload {
                continue;
            }
            let gap = obs.workload - wj;
            // ⌊α·Δ⌋ with a minimum quantum of one task for any gradient
            // ≥ 2: indivisible loads stall under pure fractional flow when
            // α·Δ < 1 (high-degree flat topologies), cf. the integer
            // schemes of Demirel & Sbalzarini.
            let mut flow = (alpha * gap as f64).floor() as usize;
            if flow == 0 && gap >= 2 {
                flow = 1;
            }
            let flow = flow.min(budget);
            if flow == 0 {
                continue;
            }
            budget -= flow;
            flowed = true;
            let round = self.next_round;
            self.next_round += 1;
            self.counters.transactions += 1;
            // assume the tasks land: avoids re-sending to the same
            // neighbor next period before its report catches up
            self.set_load(q, wj + flow);
            out.push(PolicyAction::ExportCount { to: q, round, count: flow });
            if budget == 0 {
                break;
            }
        }
        if !flowed {
            self.counters.failed_rounds += 1;
        }
    }

    fn on_message(
        &mut self,
        _obs: &mut PolicyObs<'_>,
        from: ProcessId,
        msg: &Msg,
        _now: f64,
        _out: &mut Vec<PolicyAction>,
    ) {
        match *msg {
            Msg::LoadReport { load } => {
                self.counters.requests_received += 1;
                self.set_load(from, load);
            }
            // Transfers are fire-and-forget: the ack needs no bookkeeping.
            Msg::ExportAck { .. } => {}
            _ => {}
        }
    }

    fn on_transfer(
        &mut self,
        _obs: &mut PolicyObs<'_>,
        _from: ProcessId,
        _round: u64,
        received: usize,
        _now: f64,
        _out: &mut Vec<PolicyAction>,
    ) {
        // Count the transfer on the receiving side too, matching the
        // both-participants convention of pairing and stealing — keeps the
        // aggregated `transactions` column comparable across policies.
        if received > 0 {
            self.counters.transactions += 1;
        }
    }

    fn on_tick(&mut self, _now: f64, _rng: &mut Rng) {}

    fn next_wakeup(&self) -> Option<f64> {
        Some(self.next_exchange_at)
    }

    fn set_delta(&mut self, delta: f64) {
        self.cfg.delta = delta;
    }

    fn engaged(&self) -> bool {
        false
    }

    fn counters(&self) -> &DlbCounters {
        &self.counters
    }

    fn counters_mut(&mut self) -> &mut DlbCounters {
        &mut self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::ObsBox;
    use super::*;

    fn difp(me: u32) -> Diffusion {
        Diffusion::new(ProcessId(me), PairingConfig::default())
    }

    #[test]
    fn first_exchange_reports_load_to_all_neighbors() {
        let mut p = difp(0);
        let mut ob = ObsBox::new(0, 5, 10, 2);
        ob.neighbors = vec![ProcessId(1), ProcessId(4)]; // ring-ish
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        let reports = out
            .iter()
            .filter(|a| matches!(a, PolicyAction::Send { msg: Msg::LoadReport { load: 10 }, .. }))
            .count();
        assert_eq!(reports, 2);
        // no exports yet: neighbor loads unknown
        assert!(!out.iter().any(|a| matches!(a, PolicyAction::ExportCount { .. })));
    }

    #[test]
    fn flows_down_the_gradient_after_reports() {
        let mut p = difp(0);
        let mut ob = ObsBox::new(0, 5, 12, 2);
        ob.neighbors = vec![ProcessId(1), ProcessId(4)];
        let mut out = Vec::new();
        p.on_message(&mut ob.obs(), ProcessId(1), &Msg::LoadReport { load: 0 }, 0.0, &mut out);
        p.on_message(&mut ob.obs(), ProcessId(4), &Msg::LoadReport { load: 12 }, 0.0, &mut out);
        assert!(out.is_empty());
        p.poll(&mut ob.obs(), 0.0, &mut out);
        // α = 1/3; flow to p1 = ⌊12/3⌋ = 4; p4 is level — nothing
        let exports: Vec<_> = out
            .iter()
            .filter_map(|a| match a {
                PolicyAction::ExportCount { to, count, .. } => Some((*to, *count)),
                _ => None,
            })
            .collect();
        assert_eq!(exports, vec![(ProcessId(1), 4)]);
    }

    #[test]
    fn respects_wt_budget() {
        let mut p = difp(0);
        let mut ob = ObsBox::new(0, 3, 6, 5); // only 1 above W_T
        ob.neighbors = vec![ProcessId(1), ProcessId(2)];
        let mut out = Vec::new();
        p.on_message(&mut ob.obs(), ProcessId(1), &Msg::LoadReport { load: 0 }, 0.0, &mut out);
        p.on_message(&mut ob.obs(), ProcessId(2), &Msg::LoadReport { load: 0 }, 0.0, &mut out);
        p.poll(&mut ob.obs(), 0.0, &mut out);
        let total: usize = out
            .iter()
            .filter_map(|a| match a {
                PolicyAction::ExportCount { count, .. } => Some(*count),
                _ => None,
            })
            .sum();
        assert!(total <= 1, "must not dip below W_T: {out:?}");
    }

    #[test]
    fn period_reschedules_with_jitter() {
        let mut p = difp(0);
        let mut ob = ObsBox::new(0, 3, 0, 2);
        ob.neighbors = vec![ProcessId(1)];
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 1.0, &mut out);
        let next = p.next_wakeup().expect("always periodic");
        assert!(next > 1.0 && next <= 1.0 + 1.25 * p.cfg.delta + 1e-12, "{next}");
        // nothing happens before the period elapses
        out.clear();
        p.poll(&mut ob.obs(), (1.0 + next) / 2.0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn balanced_neighborhood_stays_quiet() {
        let mut p = difp(0);
        let mut ob = ObsBox::new(0, 3, 5, 2);
        ob.neighbors = vec![ProcessId(1), ProcessId(2)];
        let mut out = Vec::new();
        p.on_message(&mut ob.obs(), ProcessId(1), &Msg::LoadReport { load: 5 }, 0.0, &mut out);
        p.on_message(&mut ob.obs(), ProcessId(2), &Msg::LoadReport { load: 6 }, 0.0, &mut out);
        p.poll(&mut ob.obs(), 0.0, &mut out);
        assert!(!out.iter().any(|a| matches!(a, PolicyAction::ExportCount { .. })));
    }
}
