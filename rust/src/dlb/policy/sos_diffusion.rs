//! Second-order (SOS) diffusion as a [`BalancerPolicy`].
//!
//! The second-order scheme of Demirel & Sbalzarini ("Balancing indivisible
//! real-valued loads in arbitrary networks", 2013; arXiv:1308.0148): each
//! exchange round the flow toward neighbor `j` is
//!
//! ```text
//! x_ij(t) = β·α·(w_i − w_j)  +  (β − 1)·x_ij(t−1)
//! ```
//!
//! — the first-order diffusive gradient plus a momentum term carrying the
//! previous round's flow.  With the uniform diffusion coefficient
//! `α = 1/(Δ_max + 1)` (Δ_max = the topology's maximum degree) and the
//! over-relaxation factor `β = 2/(1 + √(1 − ρ²))`, where ρ is the second
//! eigenvalue modulus of the diffusion matrix `M = I − αL`, the scheme's
//! error contracts like the *square root* of first-order diffusion's rate —
//! on a ring of 8 the per-round factor drops from ρ ≈ 0.80 to ≈ 0.26.
//!
//! ρ is not computed from a closed form: a deterministic power iteration on
//! the sum-zero subspace of `M` (mean deflated every step) estimates it for
//! *any* connected topology, including the graph-backed shapes.  The
//! parameters are computed once per run ([`SosParams::for_topology`],
//! invoked from `ProcessParams::from_config`) and shared by every rank —
//! the scheme requires a uniform α, unlike first-order [`super::Diffusion`]
//! which uses each rank's local degree.
//!
//! Flows are integerized exactly like the first-order policy (floor with a
//! minimum quantum of one task on gradients ≥ 2) and shipping is push-only:
//! a negative `x_ij` moves no tasks but *is* remembered, so the momentum
//! term still damps overshoot.  Message pattern, counters, quiescence
//! signaling, and [`super::AdaptiveDelta`] wrapping are identical to
//! first-order diffusion — the only behavioral difference is how much flows.

use crate::core::ids::ProcessId;
use crate::dlb::pairing::PairingConfig;
use crate::metrics::counters::DlbCounters;
use crate::net::message::Msg;
use crate::net::topology::Topology;
use crate::util::rng::Rng;

use super::{BalancerPolicy, PolicyAction, PolicyObs};

/// Sentinel for "no load report received yet from this process".
const NO_REPORT: usize = usize::MAX;

/// The scheme's two global coefficients, derived from the topology once
/// per run and shared by all ranks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SosParams {
    /// Uniform diffusion coefficient `1/(Δ_max + 1)`.
    pub alpha: f64,
    /// Over-relaxation factor in `[1, 2)`; β = 1 degenerates the scheme to
    /// first-order diffusion.
    pub beta: f64,
}

impl SosParams {
    /// Derive (α, β) for the given shape over `p` ranks.
    pub fn for_topology(topology: &Topology, p: usize) -> SosParams {
        // Complete graph (flat): every Laplacian eigenvalue but 0 equals p,
        // so M = I − L/p annihilates the sum-zero subspace — ρ = 0, β = 1.
        // Skipping the O(p²) adjacency materialization matters at large P.
        if matches!(topology, Topology::Flat) {
            return SosParams { alpha: 1.0 / p.max(1) as f64, beta: 1.0 };
        }
        let adj: Vec<Vec<usize>> = (0..p)
            .map(|i| {
                topology
                    .neighbors(ProcessId(i as u32), p)
                    .iter()
                    .map(|q| q.idx())
                    .collect()
            })
            .collect();
        Self::from_adjacency(&adj)
    }

    /// (α, β) from an explicit adjacency structure: α from the maximum
    /// degree, ρ by deterministic power iteration of `M = I − αL` on the
    /// sum-zero subspace, β = 2/(1 + √(1 − ρ²)).
    pub fn from_adjacency(adj: &[Vec<usize>]) -> SosParams {
        let p = adj.len();
        let maxdeg = adj.iter().map(|a| a.len()).max().unwrap_or(0);
        let alpha = 1.0 / (maxdeg as f64 + 1.0);
        if p < 2 || maxdeg == 0 {
            return SosParams { alpha, beta: 1.0 };
        }
        // Deterministic start vector (Knuth multiplicative hash of the
        // index), deflated onto the sum-zero subspace.  No RNG: parameter
        // derivation must be reproducible across runs and ranks.
        let mut v: Vec<f64> = (0..p)
            .map(|i| (i.wrapping_mul(2_654_435_761) % 1000) as f64 / 1000.0)
            .collect();
        deflate(&mut v);
        if !normalize(&mut v) {
            // Hash degenerated (tiny p): any fixed sum-zero vector works.
            v[0] = std::f64::consts::FRAC_1_SQRT_2;
            v[1] = -std::f64::consts::FRAC_1_SQRT_2;
        }
        let mut w = vec![0.0f64; p];
        let mut rho = 0.0f64;
        for it in 0..200 {
            // w = Mv = v − α·Lv
            for (i, nbrs) in adj.iter().enumerate() {
                let mut lv = nbrs.len() as f64 * v[i];
                for &j in nbrs {
                    lv -= v[j];
                }
                w[i] = v[i] - alpha * lv;
            }
            deflate(&mut w);
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-300 {
                // v was (numerically) annihilated: no sum-zero spectrum left.
                rho = 0.0;
                break;
            }
            // ‖v‖ = 1, so the ratio is just ‖w‖.  Keep the max over the
            // last iterations: with eigenvalues ±λ present the per-step
            // ratio oscillates around λ rather than converging to it.
            if it >= 190 {
                rho = rho.max(norm);
            }
            for (vi, wi) in v.iter_mut().zip(&w) {
                *vi = wi / norm;
            }
        }
        let rho = rho.clamp(0.0, 0.999_999);
        let beta = 2.0 / (1.0 + (1.0 - rho * rho).sqrt());
        SosParams { alpha, beta }
    }
}

fn deflate(v: &mut [f64]) {
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    for x in v.iter_mut() {
        *x -= mean;
    }
}

fn normalize(v: &mut [f64]) -> bool {
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm < 1e-12 {
        return false;
    }
    for x in v.iter_mut() {
        *x /= norm;
    }
    true
}

pub struct SosDiffusion {
    cfg: PairingConfig,
    params: SosParams,
    next_exchange_at: f64,
    /// Latest load each neighbor reported, dense-indexed by process id
    /// (`NO_REPORT` until the first report).
    neighbor_loads: Vec<usize>,
    /// Previous round's real-valued scheme flow toward each neighbor — the
    /// second-order memory term.  Kept real-valued (the integerization
    /// applies to shipping only), and bounded: |β−1| < 1 makes the
    /// homogeneous recurrence a contraction.
    prev_flow: Vec<f64>,
    next_round: u64,
    pub counters: DlbCounters,
}

impl SosDiffusion {
    pub fn new(me: ProcessId, cfg: PairingConfig, params: SosParams) -> Self {
        let _ = me; // per-process identity lives in the neighbor set
        SosDiffusion {
            cfg,
            params,
            next_exchange_at: 0.0,
            neighbor_loads: Vec::new(),
            prev_flow: Vec::new(),
            next_round: 1,
            counters: DlbCounters::default(),
        }
    }

    fn load_of(&self, q: ProcessId) -> Option<usize> {
        self.neighbor_loads.get(q.idx()).copied().filter(|&w| w != NO_REPORT)
    }

    fn set_load(&mut self, q: ProcessId, load: usize) {
        if q.idx() >= self.neighbor_loads.len() {
            self.neighbor_loads.resize(q.idx() + 1, NO_REPORT);
        }
        self.neighbor_loads[q.idx()] = load;
    }

    fn prev_flow_of(&self, q: ProcessId) -> f64 {
        self.prev_flow.get(q.idx()).copied().unwrap_or(0.0)
    }

    fn set_prev_flow(&mut self, q: ProcessId, x: f64) {
        if q.idx() >= self.prev_flow.len() {
            self.prev_flow.resize(q.idx() + 1, 0.0);
        }
        self.prev_flow[q.idx()] = x;
    }
}

impl BalancerPolicy for SosDiffusion {
    fn name(&self) -> &'static str {
        "sos-diffusion"
    }

    fn init(&mut self, now: f64, rng: &mut Rng) {
        // stagger exchanges uniformly over one period
        self.next_exchange_at = now + rng.next_f64() * self.cfg.delta;
    }

    fn poll(&mut self, obs: &mut PolicyObs<'_>, now: f64, out: &mut Vec<PolicyAction>) {
        if now < self.next_exchange_at || obs.middle_zone || obs.neighbors.is_empty() {
            return;
        }
        // Slight jitter keeps neighbors from exchanging in global lock-step.
        self.next_exchange_at = now + self.cfg.delta * (0.75 + 0.5 * obs.rng.next_f64());
        self.counters.rounds += 1;

        // 1. Tell every neighbor our load (their gradient input).
        for &q in obs.neighbors {
            self.counters.requests_sent += 1;
            out.push(PolicyAction::Send { to: q, msg: Msg::LoadReport { load: obs.workload } });
        }

        // 2. Second-order flow toward every neighbor with a known load:
        //    x = β·α·(w_i − w_j) + (β−1)·x_prev.  The memory is updated for
        //    every computed flow — including negative ones, which ship
        //    nothing here (push-only) but damp the next round.  Shipping is
        //    the integerized positive part, bounded by the excess above W_T.
        let SosParams { alpha, beta } = self.params;
        let mut budget = obs.workload.saturating_sub(obs.wt);
        let mut flowed = false;
        let neighbors: &[ProcessId] = obs.neighbors;
        for &q in neighbors {
            let Some(wj) = self.load_of(q) else { continue };
            let gradient = obs.workload as f64 - wj as f64;
            let x = beta * alpha * gradient + (beta - 1.0) * self.prev_flow_of(q);
            self.set_prev_flow(q, x);
            if x <= 0.0 || wj >= obs.workload {
                continue;
            }
            let gap = obs.workload - wj;
            // ⌊x⌋ with a minimum quantum of one task for any gradient ≥ 2,
            // exactly as the first-order policy integerizes: indivisible
            // loads stall under pure fractional flow.
            let mut flow = x.floor() as usize;
            if flow == 0 && gap >= 2 {
                flow = 1;
            }
            let flow = flow.min(budget);
            if flow == 0 {
                continue;
            }
            budget -= flow;
            flowed = true;
            let round = self.next_round;
            self.next_round += 1;
            self.counters.transactions += 1;
            // assume the tasks land: avoids re-sending to the same
            // neighbor next period before its report catches up
            self.set_load(q, wj + flow);
            out.push(PolicyAction::ExportCount { to: q, round, count: flow });
            if budget == 0 {
                break;
            }
        }
        if !flowed {
            // Nothing moved — the quiescence signal AdaptiveDelta lengthens
            // the period on, same convention as first-order diffusion.
            self.counters.failed_rounds += 1;
        }
    }

    fn on_message(
        &mut self,
        _obs: &mut PolicyObs<'_>,
        from: ProcessId,
        msg: &Msg,
        _now: f64,
        _out: &mut Vec<PolicyAction>,
    ) {
        match *msg {
            Msg::LoadReport { load } => {
                self.counters.requests_received += 1;
                self.set_load(from, load);
            }
            // Transfers are fire-and-forget: the ack needs no bookkeeping.
            Msg::ExportAck { .. } => {}
            _ => {}
        }
    }

    fn on_transfer(
        &mut self,
        _obs: &mut PolicyObs<'_>,
        _from: ProcessId,
        _round: u64,
        received: usize,
        _now: f64,
        _out: &mut Vec<PolicyAction>,
    ) {
        // Count the transfer on the receiving side too, matching the
        // both-participants convention of the other policies.
        if received > 0 {
            self.counters.transactions += 1;
        }
    }

    fn on_tick(&mut self, _now: f64, _rng: &mut Rng) {}

    fn next_wakeup(&self) -> Option<f64> {
        Some(self.next_exchange_at)
    }

    fn set_delta(&mut self, delta: f64) {
        self.cfg.delta = delta;
    }

    fn engaged(&self) -> bool {
        false
    }

    fn counters(&self) -> &DlbCounters {
        &self.counters
    }

    fn counters_mut(&mut self) -> &mut DlbCounters {
        &mut self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::ObsBox;
    use super::*;

    fn sos(me: u32, params: SosParams) -> SosDiffusion {
        SosDiffusion::new(ProcessId(me), PairingConfig::default(), params)
    }

    #[test]
    fn ring_parameters_match_the_spectrum() {
        // Ring of 8: Δ_max = 2 → α = 1/3; Laplacian eigenvalues
        // 2 − 2cos(2πk/8) → ρ = max_k≠0 |1 − α·λ_k| = 1 − (2−√2)/3 ≈ 0.805,
        // so β = 2/(1+√(1−ρ²)) ≈ 1.255.
        let ring = Topology::Ring { len: 8 };
        let p = SosParams::for_topology(&ring, 8);
        assert!((p.alpha - 1.0 / 3.0).abs() < 1e-12, "alpha {}", p.alpha);
        assert!((p.beta - 1.2549).abs() < 0.01, "beta {}", p.beta);
    }

    #[test]
    fn torus_parameters_beat_the_closed_form_bound() {
        // 3×3 torus (α = 1/5): the sum-zero spectrum of M is {0.4, −0.2},
        // so ρ = 0.4 exactly; a degree-based closed-form bound would
        // overestimate ρ badly here — the power iteration must find the
        // true value, giving β = 2/(1+√0.84) ≈ 1.0436.
        let t = Topology::Torus { rows: 3, cols: 3 };
        let p = SosParams::for_topology(&t, 9);
        assert!((p.alpha - 0.2).abs() < 1e-12);
        assert!((p.beta - 1.0436).abs() < 0.005, "beta {}", p.beta);
    }

    #[test]
    fn flat_and_degenerate_shapes_reduce_to_first_order() {
        let p = SosParams::for_topology(&Topology::Flat, 16);
        assert_eq!(p.beta, 1.0, "complete graph has no sum-zero spectrum");
        let p = SosParams::from_adjacency(&[vec![]]);
        assert_eq!(p.beta, 1.0, "singleton");
    }

    #[test]
    fn graph_topology_derives_params_through_the_table() {
        use crate::net::graph::GraphTopo;
        use std::sync::Arc;
        // 8-cycle as an explicit graph must agree with the Ring shape.
        let edges: Vec<(usize, usize)> = (0..8).map(|i| (i, (i + 1) % 8)).collect();
        let g = GraphTopo::from_edges(8, &edges, "cycle8").expect("cycle");
        let via_graph = SosParams::for_topology(&Topology::Graph(Arc::new(g)), 8);
        let via_ring = SosParams::for_topology(&Topology::Ring { len: 8 }, 8);
        assert!((via_graph.alpha - via_ring.alpha).abs() < 1e-12);
        assert!((via_graph.beta - via_ring.beta).abs() < 1e-9);
    }

    #[test]
    fn first_exchange_reports_load_to_all_neighbors() {
        let mut p = sos(0, SosParams { alpha: 0.5, beta: 1.5 });
        let mut ob = ObsBox::new(0, 5, 10, 2);
        ob.neighbors = vec![ProcessId(1), ProcessId(4)];
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        let reports = out
            .iter()
            .filter(|a| matches!(a, PolicyAction::Send { msg: Msg::LoadReport { load: 10 }, .. }))
            .count();
        assert_eq!(reports, 2);
        assert!(!out.iter().any(|a| matches!(a, PolicyAction::ExportCount { .. })));
    }

    #[test]
    fn momentum_term_carries_the_previous_flow() {
        let mut p = sos(0, SosParams { alpha: 0.5, beta: 1.5 });
        let mut ob = ObsBox::new(0, 2, 12, 0); // wt 0: budget is the full load
        ob.neighbors = vec![ProcessId(1)];
        let mut out = Vec::new();
        p.on_message(&mut ob.obs(), ProcessId(1), &Msg::LoadReport { load: 0 }, 0.0, &mut out);
        p.poll(&mut ob.obs(), 0.0, &mut out);
        // round 1: x = 1.5·0.5·12 = 9 → ship 9, remember 9.0
        let first: Vec<_> = exports(&out);
        assert_eq!(first, vec![(ProcessId(1), 9)]);
        // round 2 (ObsBox workload is static at 12; believed neighbor load
        // is now 9): x = 0.75·3 + 0.5·9 = 6.75 → ship 6
        out.clear();
        let next = p.next_wakeup().expect("periodic");
        p.poll(&mut ob.obs(), next, &mut out);
        assert_eq!(exports(&out), vec![(ProcessId(1), 6)]);
    }

    #[test]
    fn beta_one_matches_first_order_flow() {
        let mut p = sos(0, SosParams { alpha: 1.0 / 3.0, beta: 1.0 });
        let mut ob = ObsBox::new(0, 5, 12, 2);
        ob.neighbors = vec![ProcessId(1), ProcessId(4)];
        let mut out = Vec::new();
        p.on_message(&mut ob.obs(), ProcessId(1), &Msg::LoadReport { load: 0 }, 0.0, &mut out);
        p.on_message(&mut ob.obs(), ProcessId(4), &Msg::LoadReport { load: 12 }, 0.0, &mut out);
        p.poll(&mut ob.obs(), 0.0, &mut out);
        // identical to Diffusion: ⌊12/3⌋ = 4 to the lighter neighbor only
        assert_eq!(exports(&out), vec![(ProcessId(1), 4)]);
    }

    #[test]
    fn respects_wt_budget() {
        let mut p = sos(0, SosParams { alpha: 0.5, beta: 1.8 });
        let mut ob = ObsBox::new(0, 3, 6, 5); // only 1 above W_T
        ob.neighbors = vec![ProcessId(1), ProcessId(2)];
        let mut out = Vec::new();
        p.on_message(&mut ob.obs(), ProcessId(1), &Msg::LoadReport { load: 0 }, 0.0, &mut out);
        p.on_message(&mut ob.obs(), ProcessId(2), &Msg::LoadReport { load: 0 }, 0.0, &mut out);
        p.poll(&mut ob.obs(), 0.0, &mut out);
        let total: usize = exports(&out).iter().map(|&(_, c)| c).sum();
        assert!(total <= 1, "must not dip below W_T: {out:?}");
    }

    #[test]
    fn negative_flow_is_remembered_but_not_shipped() {
        let mut p = sos(0, SosParams { alpha: 0.5, beta: 1.5 });
        let mut ob = ObsBox::new(0, 2, 3, 0);
        ob.neighbors = vec![ProcessId(1)];
        let mut out = Vec::new();
        // neighbor is heavier: gradient −7 → x = −5.25, nothing ships
        p.on_message(&mut ob.obs(), ProcessId(1), &Msg::LoadReport { load: 10 }, 0.0, &mut out);
        p.poll(&mut ob.obs(), 0.0, &mut out);
        assert!(exports(&out).is_empty());
        assert_eq!(p.counters.failed_rounds, 1, "quiescence signal for AdaptiveDelta");
        assert!(p.prev_flow_of(ProcessId(1)) < 0.0, "memory keeps the pull term");
    }

    fn exports(out: &[PolicyAction]) -> Vec<(ProcessId, usize)> {
        out.iter()
            .filter_map(|a| match a {
                PolicyAction::ExportCount { to, count, .. } => Some((*to, *count)),
                _ => None,
            })
            .collect()
    }
}
