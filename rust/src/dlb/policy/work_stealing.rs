//! Receiver-initiated work stealing as a [`BalancerPolicy`] — one protocol
//! state machine, pluggable victim choice.
//!
//! The classic distributed-runtime competitor to the paper's pairing
//! protocol (cf. "Distributed Work Stealing in a Task-Based Dataflow
//! Runtime", John et al. 2022): an **idle** process picks a victim and asks
//! for work; the victim answers immediately with a (possibly empty)
//! `TaskExport` — no multi-message handshake, no soft-locks.  A non-empty
//! reply refills the thief; an empty reply is a failed attempt.  Failed
//! attempts retry immediately against fresh victims up to `tries` times,
//! then back off for a jittered δ (the same livelock-avoidance jitter as
//! pairing).
//!
//! Uniform and hierarchical stealing share every part of that protocol —
//! request framing, grant rule, retries, back-off, confirm-timeout and
//! late-grant accounting — and differ *only* in whom the thief asks.  The
//! shared machine is [`StealProtocol`], parameterized by a
//! [`VictimSelector`]; [`WorkStealing`] instantiates it with the uniform
//! random draw, [`super::HierarchicalStealing`] with the locality ladder.
//! (The two used to be ~400 mirrored lines; the selector split removed the
//! duplicate without changing either policy's RNG call sequence, so run
//! fingerprints are preserved.)
//!
//! Steal amount: half the victim's excess above W_T (`steal-half`, the
//! standard choice) or a single task (`steal-one`, `dlb.steal_half =
//! false`).  The victim never dips below W_T — the shared invariant all
//! policies inherit from the export mechanics in `core::process`.

use crate::core::ids::ProcessId;
use crate::dlb::pairing::PairingConfig;
use crate::metrics::counters::DlbCounters;
use crate::net::message::{Msg, Role};
use crate::util::rng::Rng;

use super::{BalancerPolicy, PolicyAction, PolicyObs};

/// Whom does a thief ask?  The single axis on which the stealing policies
/// differ.  Hook order mirrors the protocol exactly, so selector state
/// (e.g. an escalation ladder) sees the same transitions the old
/// duplicated implementations drove by hand.
pub trait VictimSelector: Send {
    /// Policy name surfaced through [`BalancerPolicy::name`].
    fn name(&self) -> &'static str;
    /// Draw the next victim.  Must consume RNG exactly as the policy
    /// documents — this is the only RNG call of a steal attempt.
    fn pick(&mut self, num_processes: usize, rng: &mut Rng) -> Option<ProcessId>;
    /// An attempt came back empty or timed out.
    fn on_failed_attempt(&mut self) {}
    /// The hunt ended without success (δ back-off starts).
    fn on_hunt_end(&mut self) {}
    /// A grant landed (live or late): the neighborhood has work again.
    fn on_success(&mut self) {}
}

/// Uniform random victims, excluding self — plain work stealing.
pub struct UniformVictims {
    me: ProcessId,
}

impl VictimSelector for UniformVictims {
    fn name(&self) -> &'static str {
        "stealing"
    }

    fn pick(&mut self, num_processes: usize, rng: &mut Rng) -> Option<ProcessId> {
        rng.sample_distinct(num_processes, 1, Some(self.me.idx()))
            .into_iter()
            .map(|i| ProcessId(i as u32))
            .next()
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum StealState {
    /// No request in flight.
    Free,
    /// Waiting for a victim's reply.
    Outstanding { round: u64, deadline: f64 },
}

/// The shared receiver-initiated stealing state machine.  Everything here
/// is victim-choice-agnostic; `S` decides whom each request goes to.
pub struct StealProtocol<S: VictimSelector> {
    pub(crate) cfg: PairingConfig,
    steal_half: bool,
    pub(crate) selector: S,
    state: StealState,
    /// Earliest time the next steal attempt may start.
    pub(crate) next_attempt_at: f64,
    /// Immediate retries left before backing off for δ.
    pub(crate) retries_left: usize,
    /// Rounds whose confirm-timeout fired before their reply arrived; a
    /// reply carrying one of them is a late grant, not a live one.  Entries
    /// leave when the reply lands; they accumulate only when a victim halts
    /// without replying (shutdown), so the list stays tiny.
    pub(crate) stale_rounds: Vec<u64>,
    pub(crate) next_round: u64,
    pub counters: DlbCounters,
}

impl StealProtocol<UniformVictims> {
    pub fn new(me: ProcessId, cfg: PairingConfig, steal_half: bool) -> Self {
        StealProtocol::with_selector(cfg, steal_half, UniformVictims { me })
    }
}

/// Plain uniform work stealing (the John et al. 2022 baseline).
pub type WorkStealing = StealProtocol<UniformVictims>;

impl<S: VictimSelector> StealProtocol<S> {
    pub(crate) fn with_selector(cfg: PairingConfig, steal_half: bool, selector: S) -> Self {
        let retries = cfg.tries.max(1);
        StealProtocol {
            cfg,
            steal_half,
            selector,
            state: StealState::Free,
            next_attempt_at: 0.0,
            retries_left: retries,
            stale_rounds: Vec::new(),
            next_round: 1,
            counters: DlbCounters::default(),
        }
    }

    /// An attempt came back empty (or timed out): retry now or back off.
    fn attempt_failed(&mut self, now: f64, rng: &mut Rng) {
        self.state = StealState::Free;
        self.counters.failed_rounds += 1;
        self.selector.on_failed_attempt();
        if self.retries_left > 0 {
            self.retries_left -= 1;
            self.next_attempt_at = now;
        } else {
            self.retries_left = self.cfg.tries.max(1);
            self.selector.on_hunt_end();
            let jitter = 0.5 + rng.next_f64();
            self.next_attempt_at = now + self.cfg.delta * jitter;
        }
    }

    /// How much a busy victim with workload `w` hands over (identical under
    /// every selector — the policies differ only in victim choice).
    fn steal_amount(&self, w: usize, wt: usize) -> usize {
        let excess = w.saturating_sub(wt);
        if excess == 0 {
            0
        } else if self.steal_half {
            (excess + 1) / 2
        } else {
            1
        }
    }
}

impl<S: VictimSelector> BalancerPolicy for StealProtocol<S> {
    fn name(&self) -> &'static str {
        self.selector.name()
    }

    fn init(&mut self, now: f64, rng: &mut Rng) {
        // stagger first attempts uniformly over one δ
        self.next_attempt_at = now + rng.next_f64() * self.cfg.delta;
    }

    fn poll(&mut self, obs: &mut PolicyObs<'_>, now: f64, out: &mut Vec<PolicyAction>) {
        if obs.middle_zone
            || obs.role != Role::Idle
            || self.state != StealState::Free
            || now < self.next_attempt_at
            || obs.num_processes < 2
        {
            return;
        }
        let Some(victim) = self.selector.pick(obs.num_processes, obs.rng) else { return };
        let round = self.next_round;
        self.next_round += 1;
        self.counters.rounds += 1;
        self.counters.requests_sent += 1;
        self.state = StealState::Outstanding { round, deadline: now + self.cfg.confirm_timeout };
        out.push(PolicyAction::Send {
            to: victim,
            msg: Msg::StealRequest { round, load: obs.workload, eta: obs.queue_eta() },
        });
    }

    fn on_message(
        &mut self,
        obs: &mut PolicyObs<'_>,
        from: ProcessId,
        msg: &Msg,
        _now: f64,
        out: &mut Vec<PolicyAction>,
    ) {
        match *msg {
            Msg::StealRequest { round, .. } => {
                self.counters.requests_received += 1;
                let grant = if obs.middle_zone || obs.role != Role::Busy {
                    0
                } else {
                    self.steal_amount(obs.workload, obs.wt)
                };
                if grant > 0 {
                    self.counters.accepts_sent += 1;
                    self.counters.transactions += 1;
                } else {
                    self.counters.declines_sent += 1;
                }
                // Always reply, even empty: the thief is blocked on us.
                out.push(PolicyAction::ExportCount { to: from, round, count: grant });
            }
            // Victim side: transfer acked; stateless, nothing to unlock.
            Msg::ExportAck { .. } => {}
            _ => {}
        }
    }

    /// Thief side: our steal came back (tasks already enqueued).
    fn on_transfer(
        &mut self,
        obs: &mut PolicyObs<'_>,
        _from: ProcessId,
        round: u64,
        received: usize,
        now: f64,
        _out: &mut Vec<PolicyAction>,
    ) {
        match self.state {
            StealState::Outstanding { round: r, .. } if r == round => {
                if received == 0 {
                    self.attempt_failed(now, obs.rng);
                } else {
                    self.state = StealState::Free;
                    self.counters.transactions += 1;
                    self.retries_left = self.cfg.tries.max(1);
                    self.selector.on_success();
                    self.next_attempt_at = now;
                }
            }
            _ => {
                // A grant for a round whose confirm-timeout already fired:
                // the process has enqueued its tasks regardless, so the
                // thief may now hold this grant *plus* whatever its next
                // in-flight request brings back (over-stealing).  Track the
                // stale rounds explicitly and account for the double-fill.
                if let Some(pos) = self.stale_rounds.iter().position(|&r| r == round) {
                    self.stale_rounds.swap_remove(pos);
                    if received > 0 {
                        self.counters.late_grants += 1;
                        self.counters.transactions += 1;
                        self.selector.on_success();
                    }
                }
            }
        }
    }

    fn on_tick(&mut self, now: f64, rng: &mut Rng) {
        if let StealState::Outstanding { round, deadline } = self.state {
            if now >= deadline {
                // victim vanished or the reply is slow: remember the round
                // so a late grant is recognized, count, and move on
                self.stale_rounds.push(round);
                self.counters.confirm_timeouts += 1;
                self.attempt_failed(now, rng);
            }
        }
    }

    fn next_wakeup(&self) -> Option<f64> {
        match self.state {
            StealState::Free => Some(self.next_attempt_at),
            StealState::Outstanding { deadline, .. } => Some(deadline),
        }
    }

    fn set_delta(&mut self, delta: f64) {
        self.cfg.delta = delta;
    }

    fn engaged(&self) -> bool {
        self.state != StealState::Free
    }

    fn counters(&self) -> &DlbCounters {
        &self.counters
    }

    fn counters_mut(&mut self) -> &mut DlbCounters {
        &mut self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::ObsBox;
    use super::*;

    fn ws(me: u32, half: bool) -> WorkStealing {
        WorkStealing::new(ProcessId(me), PairingConfig::default(), half)
    }

    #[test]
    fn idle_thief_sends_one_request() {
        let mut p = ws(0, true);
        let mut ob = ObsBox::new(0, 8, 0, 2); // idle
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        assert_eq!(out.len(), 1);
        match &out[0] {
            PolicyAction::Send { to, msg: Msg::StealRequest { round, .. } } => {
                assert_ne!(*to, ProcessId(0), "never self");
                assert_eq!(*round, 1);
            }
            other => panic!("expected StealRequest, got {other:?}"),
        }
        assert!(p.engaged());
        // no second request while outstanding
        out.clear();
        p.poll(&mut ob.obs(), 0.001, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn busy_process_never_steals() {
        let mut p = ws(0, true);
        let mut ob = ObsBox::new(0, 8, 9, 2); // busy
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn victim_grants_half_the_excess() {
        let mut p = ws(1, true);
        let mut ob = ObsBox::new(1, 8, 12, 2); // excess 10 → grant 5
        let mut out = Vec::new();
        p.on_message(
            &mut ob.obs(),
            ProcessId(0),
            &Msg::StealRequest { round: 9, load: 0, eta: 0.0 },
            0.001,
            &mut out,
        );
        assert!(matches!(
            out.as_slice(),
            [PolicyAction::ExportCount { to: ProcessId(0), round: 9, count: 5 }]
        ));
        assert_eq!(p.counters.transactions, 1);
    }

    #[test]
    fn steal_one_mode_grants_single_task() {
        let mut p = ws(1, false);
        let mut ob = ObsBox::new(1, 8, 12, 2);
        let mut out = Vec::new();
        p.on_message(
            &mut ob.obs(),
            ProcessId(0),
            &Msg::StealRequest { round: 1, load: 0, eta: 0.0 },
            0.001,
            &mut out,
        );
        assert!(matches!(out.as_slice(), [PolicyAction::ExportCount { count: 1, .. }]));
    }

    #[test]
    fn idle_victim_replies_empty() {
        let mut p = ws(1, true);
        let mut ob = ObsBox::new(1, 8, 1, 2); // idle victim
        let mut out = Vec::new();
        p.on_message(
            &mut ob.obs(),
            ProcessId(0),
            &Msg::StealRequest { round: 2, load: 0, eta: 0.0 },
            0.001,
            &mut out,
        );
        assert!(matches!(out.as_slice(), [PolicyAction::ExportCount { count: 0, .. }]));
        assert_eq!(p.counters.declines_sent, 1);
    }

    #[test]
    fn empty_reply_retries_then_backs_off() {
        let mut p = ws(0, true);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        let tries = p.cfg.tries;
        let mut failures = 0;
        // drive attempts until the policy backs off past `now`
        let now = 0.01;
        loop {
            let mut out = Vec::new();
            p.poll(&mut ob.obs(), now, &mut out);
            if out.is_empty() {
                break;
            }
            let round = match &out[0] {
                PolicyAction::Send { msg: Msg::StealRequest { round, .. }, .. } => *round,
                other => panic!("{other:?}"),
            };
            p.on_transfer(&mut ob.obs(), ProcessId(1), round, 0, now, &mut out);
            failures += 1;
            assert!(failures < 100, "no backoff");
        }
        // tries immediate retries + the initial attempt, then δ back-off
        assert_eq!(failures, tries + 1);
        assert!(p.next_attempt_at > now);
        assert_eq!(p.counters.failed_rounds as usize, failures);
    }

    #[test]
    fn timeout_counts_and_frees() {
        let mut p = ws(0, true);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        assert!(p.engaged());
        let mut rng = Rng::new(7);
        p.on_tick(0.001, &mut rng); // before deadline
        assert!(p.engaged());
        p.on_tick(10.0, &mut rng); // past deadline
        assert!(!p.engaged());
        assert_eq!(p.counters.confirm_timeouts, 1);
    }

    /// The PR-4 over-stealing bug: a grant that lands after the confirm
    /// timeout was silently dropped by the round check although its tasks
    /// were already enqueued — while a second steal was free to launch.
    #[test]
    fn late_grant_after_timeout_is_tracked() {
        let mut p = ws(0, true);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        let round1 = match &out[0] {
            PolicyAction::Send { msg: Msg::StealRequest { round, .. }, .. } => *round,
            other => panic!("{other:?}"),
        };
        let mut rng = Rng::new(7);
        p.on_tick(10.0, &mut rng); // deadline fires: round 1 written off
        assert!(!p.engaged());
        // the thief immediately hunts again (over-steal window is open)
        out.clear();
        p.poll(&mut ob.obs(), 10.0, &mut out);
        let round2 = match &out[0] {
            PolicyAction::Send { msg: Msg::StealRequest { round, .. }, .. } => *round,
            other => panic!("{other:?}"),
        };
        assert_ne!(round1, round2);
        assert!(p.engaged());
        // …and the delayed grant for round 1 finally lands, with tasks
        p.on_transfer(&mut ob.obs(), ProcessId(1), round1, 4, 10.1, &mut out);
        assert_eq!(p.counters.late_grants, 1, "late grant must be accounted");
        assert!(p.engaged(), "round 2 must stay outstanding — not confused by round 1");
        // the live reply still resolves normally
        p.on_transfer(&mut ob.obs(), ProcessId(2), round2, 2, 10.2, &mut out);
        assert!(!p.engaged());
        // an empty late reply is not a grant
        p.on_tick(10.2, &mut rng);
        assert_eq!(p.counters.late_grants, 1);
    }

    /// Two rounds can be stale at once (both timed out before either reply
    /// arrived); each late grant must still be recognized.
    #[test]
    fn overlapping_stale_rounds_both_recognized() {
        let mut p = ws(0, true);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        let mut rng = Rng::new(7);
        let issue = |p: &mut WorkStealing, ob: &mut ObsBox, now: f64| -> u64 {
            let mut out = Vec::new();
            p.poll(&mut ob.obs(), now, &mut out);
            match &out[0] {
                PolicyAction::Send { msg: Msg::StealRequest { round, .. }, .. } => *round,
                other => panic!("{other:?}"),
            }
        };
        let r1 = issue(&mut p, &mut ob, 0.0);
        p.on_tick(1.0, &mut rng); // round 1 times out
        let r2 = issue(&mut p, &mut ob, 1.0);
        p.on_tick(2.0, &mut rng); // round 2 times out as well
        assert_eq!(p.counters.confirm_timeouts, 2);
        // the *older* stale reply lands first, then the newer one
        let mut out = Vec::new();
        p.on_transfer(&mut ob.obs(), ProcessId(1), r1, 2, 2.1, &mut out);
        p.on_transfer(&mut ob.obs(), ProcessId(2), r2, 3, 2.2, &mut out);
        assert_eq!(p.counters.late_grants, 2, "both delayed grants accounted");
        assert!(p.stale_rounds.is_empty(), "entries removed once matched");
    }

    #[test]
    fn successful_steal_resets_retries() {
        let mut p = ws(0, true);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        let round = match &out[0] {
            PolicyAction::Send { msg: Msg::StealRequest { round, .. }, .. } => *round,
            other => panic!("{other:?}"),
        };
        p.on_transfer(&mut ob.obs(), ProcessId(1), round, 3, 0.002, &mut out);
        assert!(!p.engaged());
        assert_eq!(p.counters.transactions, 1);
        assert_eq!(p.retries_left, p.cfg.tries);
    }
}
