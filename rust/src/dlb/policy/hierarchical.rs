//! Hierarchical locality-aware work stealing as a [`BalancerPolicy`].
//!
//! The ROADMAP's "steal within the cluster node first" idea, generalized to
//! every topology through the distance-ranked victim table
//! (`Topology::neighbors_by_distance`): victims are split into a **local
//! tier** — everyone at the minimum hop distance (the cluster node-mates,
//! or the ring/torus adjacency shell) — and the **remote tiers** beyond it.
//!
//! A hunt walks an escalation ladder: the first `local_tries` attempts draw
//! uniformly from the local tier; once that many consecutive attempts have
//! failed, the remaining attempts of the hunt escalate to a 1/hops²-weighted
//! draw over the remote tiers (near nodes dominate, far ones stay
//! reachable).  Any success — local or remote — resets the ladder, so a
//! thief returns to cheap intra-node stealing as soon as its neighborhood
//! has work again.  Distributed task runtimes show exactly this
//! locality-over-uniformity victim selection is what keeps stealing
//! competitive once the interconnect is not flat (John et al. 2022).
//!
//! Wire protocol, steal amounts, retries and back-off are *shared*, not
//! mirrored: the whole policy is [`StealProtocol`] (see
//! `super::work_stealing`) instantiated with the [`LocalityLadder`] victim
//! selector — the only ~60 lines that ever differed from plain stealing.
//! On a flat topology (every rank at one hop) the local tier is everybody
//! and the ladder never escalates, so the policy degenerates to plain
//! uniform stealing, which keeps the comparison in `ductr compare`
//! apples-to-apples: the only difference is *whom* the thief asks.

use crate::core::ids::ProcessId;
use crate::dlb::pairing::PairingConfig;
use crate::net::topology::Topology;
use crate::util::rng::Rng;

use super::work_stealing::{StealProtocol, VictimSelector};

/// The distance-tiered escalation ladder: local first, 1/hops²-weighted
/// remote after `local_tries` consecutive failures, reset on any success.
pub struct LocalityLadder {
    /// Consecutive failed attempts before a hunt escalates off-node.
    local_tries: usize,
    /// The minimum-distance tier (node-mates / adjacency shell).
    local: Vec<ProcessId>,
    /// Every farther rank, ascending distance.
    far: Vec<ProcessId>,
    /// Cumulative 1/hops² weights aligned with `far` (precomputed once:
    /// victim draws stay allocation-free).
    far_cum: Vec<f64>,
    /// Consecutive failures in the current hunt (drives escalation).
    failures: usize,
}

impl LocalityLadder {
    pub fn new(
        me: ProcessId,
        local_tries: usize,
        topology: &Topology,
        num_processes: usize,
    ) -> Self {
        let ranked = topology.neighbors_by_distance(me, num_processes);
        let min_h = ranked.first().map(|&(_, h)| h).unwrap_or(1);
        let local: Vec<ProcessId> =
            ranked.iter().take_while(|&&(_, h)| h == min_h).map(|&(q, _)| q).collect();
        let mut far = Vec::with_capacity(ranked.len() - local.len());
        let mut far_cum = Vec::with_capacity(ranked.len() - local.len());
        let mut acc = 0.0;
        for &(q, h) in ranked.iter().skip(local.len()) {
            acc += Topology::locality_weight(h);
            far.push(q);
            far_cum.push(acc);
        }
        LocalityLadder { local_tries: local_tries.max(1), local, far, far_cum, failures: 0 }
    }

    /// Is the current hunt past the local rungs of the ladder?
    fn escalated(&self) -> bool {
        self.failures >= self.local_tries && !self.far.is_empty()
    }
}

impl VictimSelector for LocalityLadder {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    /// Local phase: uniform node-mate.  Escalated: 1/hops²-weighted draw
    /// over the remote tiers.
    fn pick(&mut self, _num_processes: usize, rng: &mut Rng) -> Option<ProcessId> {
        if !self.escalated() {
            if self.local.is_empty() {
                return None;
            }
            return Some(*rng.choose(&self.local));
        }
        let total = *self.far_cum.last().expect("escalated ⇒ non-empty far tier");
        let x = rng.next_f64() * total;
        let i = self.far_cum.partition_point(|&c| c < x).min(self.far.len() - 1);
        Some(self.far[i])
    }

    fn on_failed_attempt(&mut self) {
        self.failures += 1;
    }

    fn on_hunt_end(&mut self) {
        // next hunt starts at the bottom of the ladder again
        self.failures = 0;
    }

    fn on_success(&mut self) {
        // success anywhere resets the ladder: steal locally again
        self.failures = 0;
    }
}

/// Locality-aware stealing: the shared steal protocol driven by the
/// escalation ladder.
pub type HierarchicalStealing = StealProtocol<LocalityLadder>;

impl StealProtocol<LocalityLadder> {
    pub fn new(
        me: ProcessId,
        cfg: PairingConfig,
        steal_half: bool,
        local_tries: usize,
        topology: &Topology,
        num_processes: usize,
    ) -> Self {
        StealProtocol::with_selector(
            cfg,
            steal_half,
            LocalityLadder::new(me, local_tries, topology, num_processes),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::ObsBox;
    use super::super::{BalancerPolicy, PolicyAction};
    use super::*;
    use crate::net::message::Msg;

    /// 2 nodes × 4 ranks, inter-node cost 4 (the `cluster2x4` shape).
    fn cluster() -> Topology {
        Topology::Cluster { nodes: 2, per_node: 4, inter_hops: 4 }
    }

    fn hier(me: u32, local_tries: usize, topo: &Topology, p: usize) -> HierarchicalStealing {
        HierarchicalStealing::new(
            ProcessId(me),
            PairingConfig::default(),
            true,
            local_tries,
            topo,
            p,
        )
    }

    fn request_target(p: &mut HierarchicalStealing, ob: &mut ObsBox, now: f64) -> ProcessId {
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), now, &mut out);
        match out.as_slice() {
            [PolicyAction::Send { to, msg: Msg::StealRequest { .. } }] => *to,
            other => panic!("expected one StealRequest, got {other:?}"),
        }
    }

    #[test]
    fn tiers_split_on_the_cluster_boundary() {
        let l = LocalityLadder::new(ProcessId(1), 3, &cluster(), 8);
        assert_eq!(l.local, vec![ProcessId(0), ProcessId(2), ProcessId(3)]);
        assert_eq!(
            l.far,
            (4..8).map(ProcessId).collect::<Vec<_>>(),
            "remote tier = the other node"
        );
    }

    #[test]
    fn first_local_tries_attempts_stay_on_node() {
        let topo = cluster();
        let mut p = hier(1, 3, &topo, 8);
        let mut ob = ObsBox::new(1, 8, 0, 2); // idle
        for attempt in 0..3 {
            let victim = request_target(&mut p, &mut ob, 0.0);
            assert!(
                victim.idx() < 4,
                "attempt {attempt} must stay intra-node, asked {victim}"
            );
            let round = p.next_round - 1;
            let mut out = Vec::new();
            p.on_transfer(&mut ob.obs(), victim, round, 0, 0.0, &mut out); // denied
        }
        // ladder climbed: the 4th attempt escalates to the other node
        let victim = request_target(&mut p, &mut ob, 0.0);
        assert!(victim.idx() >= 4, "escalated attempt must leave the node, asked {victim}");
    }

    #[test]
    fn success_resets_the_ladder_to_local() {
        let topo = cluster();
        let mut p = hier(0, 1, &topo, 8);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        // fail once locally → escalate
        let v = request_target(&mut p, &mut ob, 0.0);
        let mut out = Vec::new();
        p.on_transfer(&mut ob.obs(), v, p.next_round - 1, 0, 0.0, &mut out);
        let v = request_target(&mut p, &mut ob, 0.0);
        assert!(v.idx() >= 4, "escalated");
        // remote grant succeeds → next hunt starts local again
        p.on_transfer(&mut ob.obs(), v, p.next_round - 1, 2, 0.001, &mut out);
        assert_eq!(p.selector.failures, 0);
        let v = request_target(&mut p, &mut ob, 0.001);
        assert!(v.idx() < 4, "back to the local tier, asked {v}");
        assert_eq!(p.counters.transactions, 1);
    }

    #[test]
    fn tiers_split_on_graph_distance_shells() {
        use crate::net::graph::GraphTopo;
        use std::sync::Arc;
        // Path 0–1–2–3–4: from rank 1 the local tier is the 1-hop shell
        // {0, 2}; the ladder escalates outward through the BFS distance
        // table exactly as it does through the closed-form shapes.
        let g = GraphTopo::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)], "path5")
            .expect("path graph");
        let t = Topology::Graph(Arc::new(g));
        let l = LocalityLadder::new(ProcessId(1), 3, &t, 5);
        assert_eq!(l.local, vec![ProcessId(0), ProcessId(2)]);
        assert_eq!(l.far, vec![ProcessId(3), ProcessId(4)], "ascending hops");
    }

    #[test]
    fn flat_topology_degenerates_to_uniform_stealing() {
        let l = LocalityLadder::new(ProcessId(0), 3, &Topology::Flat, 6);
        assert_eq!(l.local.len(), 5, "everyone is one hop away");
        assert!(l.far.is_empty());
        // escalation can never trigger — pick stays on the local path
        assert!(!l.escalated());
    }

    #[test]
    fn busy_process_never_steals() {
        let topo = cluster();
        let mut p = hier(0, 3, &topo, 8);
        let mut ob = ObsBox::new(0, 8, 9, 2); // busy
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn victim_grants_half_the_excess() {
        let topo = cluster();
        let mut p = hier(1, 3, &topo, 8);
        let mut ob = ObsBox::new(1, 8, 12, 2); // excess 10 → grant 5
        let mut out = Vec::new();
        p.on_message(
            &mut ob.obs(),
            ProcessId(0),
            &Msg::StealRequest { round: 9, load: 0, eta: 0.0 },
            0.001,
            &mut out,
        );
        assert!(matches!(
            out.as_slice(),
            [PolicyAction::ExportCount { to: ProcessId(0), round: 9, count: 5 }]
        ));
    }

    #[test]
    fn late_grant_is_counted_not_replayed() {
        let topo = cluster();
        let mut p = hier(0, 3, &topo, 8);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        let round = p.next_round - 1;
        let mut rng = Rng::new(7);
        p.on_tick(10.0, &mut rng); // past the confirm deadline
        assert!(!p.engaged());
        assert_eq!(p.counters.confirm_timeouts, 1);
        // the grant finally lands — tasks were enqueued by the process, the
        // policy books it as a late grant and stays Free
        p.on_transfer(&mut ob.obs(), ProcessId(1), round, 3, 10.1, &mut out);
        assert_eq!(p.counters.late_grants, 1);
        assert!(!p.engaged());
        assert_eq!(p.selector.failures, 0, "a late grant still resets the ladder");
    }

    #[test]
    fn backoff_after_exhausting_retries() {
        let topo = cluster();
        let mut p = hier(0, 2, &topo, 8);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        let tries = p.cfg.tries;
        let now = 0.01;
        let mut failures = 0;
        loop {
            let mut out = Vec::new();
            p.poll(&mut ob.obs(), now, &mut out);
            if out.is_empty() {
                break;
            }
            let round = p.next_round - 1;
            p.on_transfer(&mut ob.obs(), ProcessId(1), round, 0, now, &mut out);
            failures += 1;
            assert!(failures < 100, "no backoff");
        }
        assert_eq!(failures, tries + 1);
        assert!(p.next_attempt_at > now);
        assert_eq!(p.selector.failures, 0, "ladder reset with the backoff");
    }
}
