//! Hierarchical locality-aware work stealing as a [`BalancerPolicy`].
//!
//! The ROADMAP's "steal within the cluster node first" idea, generalized to
//! every topology through the distance-ranked victim table
//! (`Topology::neighbors_by_distance`): victims are split into a **local
//! tier** — everyone at the minimum hop distance (the cluster node-mates,
//! or the ring/torus adjacency shell) — and the **remote tiers** beyond it.
//!
//! A hunt walks an escalation ladder: the first `local_tries` attempts draw
//! uniformly from the local tier; once that many consecutive attempts have
//! failed, the remaining attempts of the hunt escalate to a 1/hops²-weighted
//! draw over the remote tiers (near nodes dominate, far ones stay
//! reachable).  Any success — local or remote — resets the ladder, so a
//! thief returns to cheap intra-node stealing as soon as its neighborhood
//! has work again.  Distributed task runtimes show exactly this
//! locality-over-uniformity victim selection is what keeps stealing
//! competitive once the interconnect is not flat (John et al. 2022).
//!
//! Wire protocol, steal amounts, retries and back-off are identical to
//! [`super::WorkStealing`] — on a flat topology (every rank at one hop) the
//! local tier is everybody and the policy degenerates to plain uniform
//! stealing, which makes the comparison in `ductr compare` apples-to-apples:
//! the only difference is *whom* the thief asks.

use crate::core::ids::ProcessId;
use crate::dlb::pairing::PairingConfig;
use crate::metrics::counters::DlbCounters;
use crate::net::message::{Msg, Role};
use crate::net::topology::Topology;
use crate::util::rng::Rng;

use super::{BalancerPolicy, PolicyAction, PolicyObs};

#[derive(Debug, Clone, Copy, PartialEq)]
enum StealState {
    /// No request in flight.
    Free,
    /// Waiting for a victim's reply.
    Outstanding { round: u64, deadline: f64 },
}

pub struct HierarchicalStealing {
    cfg: PairingConfig,
    steal_half: bool,
    /// Consecutive failed attempts before a hunt escalates off-node.
    local_tries: usize,
    me: ProcessId,
    /// The minimum-distance tier (node-mates / adjacency shell).
    local: Vec<ProcessId>,
    /// Every farther rank, ascending distance.
    far: Vec<ProcessId>,
    /// Cumulative 1/hops² weights aligned with `far` (precomputed once:
    /// victim draws stay allocation-free).
    far_cum: Vec<f64>,
    state: StealState,
    /// Earliest time the next steal attempt may start.
    next_attempt_at: f64,
    /// Consecutive failures in the current hunt (drives escalation).
    failures: usize,
    /// Immediate retries left before backing off for δ.
    retries_left: usize,
    /// Rounds whose confirm-timeout fired before their reply arrived; a
    /// reply carrying one of them is a late grant, not a live one.
    stale_rounds: Vec<u64>,
    next_round: u64,
    pub counters: DlbCounters,
}

impl HierarchicalStealing {
    pub fn new(
        me: ProcessId,
        cfg: PairingConfig,
        steal_half: bool,
        local_tries: usize,
        topology: &Topology,
        num_processes: usize,
    ) -> Self {
        let ranked = topology.neighbors_by_distance(me, num_processes);
        let min_h = ranked.first().map(|&(_, h)| h).unwrap_or(1);
        let local: Vec<ProcessId> =
            ranked.iter().take_while(|&&(_, h)| h == min_h).map(|&(q, _)| q).collect();
        let mut far = Vec::with_capacity(ranked.len() - local.len());
        let mut far_cum = Vec::with_capacity(ranked.len() - local.len());
        let mut acc = 0.0;
        for &(q, h) in ranked.iter().skip(local.len()) {
            acc += Topology::locality_weight(h);
            far.push(q);
            far_cum.push(acc);
        }
        let retries = cfg.tries.max(1);
        HierarchicalStealing {
            cfg,
            steal_half,
            local_tries: local_tries.max(1),
            me,
            local,
            far,
            far_cum,
            state: StealState::Free,
            next_attempt_at: 0.0,
            failures: 0,
            retries_left: retries,
            stale_rounds: Vec::new(),
            next_round: 1,
            counters: DlbCounters::default(),
        }
    }

    /// Is the current attempt past the local rungs of the ladder?
    fn escalated(&self) -> bool {
        self.failures >= self.local_tries && !self.far.is_empty()
    }

    /// Local phase: uniform node-mate.  Escalated: 1/hops²-weighted draw
    /// over the remote tiers.
    fn pick_victim(&self, rng: &mut Rng) -> Option<ProcessId> {
        if !self.escalated() {
            if self.local.is_empty() {
                return None;
            }
            return Some(*rng.choose(&self.local));
        }
        let total = *self.far_cum.last().expect("escalated ⇒ non-empty far tier");
        let x = rng.next_f64() * total;
        let i = self.far_cum.partition_point(|&c| c < x).min(self.far.len() - 1);
        Some(self.far[i])
    }

    /// An attempt came back empty (or timed out): climb the ladder, retry
    /// now or back off for a jittered δ.
    fn attempt_failed(&mut self, now: f64, rng: &mut Rng) {
        self.state = StealState::Free;
        self.counters.failed_rounds += 1;
        self.failures += 1;
        if self.retries_left > 0 {
            self.retries_left -= 1;
            self.next_attempt_at = now;
        } else {
            self.retries_left = self.cfg.tries.max(1);
            // next hunt starts at the bottom of the ladder again
            self.failures = 0;
            let jitter = 0.5 + rng.next_f64();
            self.next_attempt_at = now + self.cfg.delta * jitter;
        }
    }

    /// How much a busy victim with workload `w` hands over (same rule as
    /// plain stealing — the policies differ only in victim choice).
    fn steal_amount(&self, w: usize, wt: usize) -> usize {
        let excess = w.saturating_sub(wt);
        if excess == 0 {
            0
        } else if self.steal_half {
            (excess + 1) / 2
        } else {
            1
        }
    }
}

impl BalancerPolicy for HierarchicalStealing {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn init(&mut self, now: f64, rng: &mut Rng) {
        // stagger first attempts uniformly over one δ
        self.next_attempt_at = now + rng.next_f64() * self.cfg.delta;
    }

    fn poll(&mut self, obs: &mut PolicyObs<'_>, now: f64, out: &mut Vec<PolicyAction>) {
        if obs.middle_zone
            || obs.role != Role::Idle
            || self.state != StealState::Free
            || now < self.next_attempt_at
            || obs.num_processes < 2
        {
            return;
        }
        let Some(victim) = self.pick_victim(obs.rng) else { return };
        let round = self.next_round;
        self.next_round += 1;
        self.counters.rounds += 1;
        self.counters.requests_sent += 1;
        self.state = StealState::Outstanding { round, deadline: now + self.cfg.confirm_timeout };
        out.push(PolicyAction::Send {
            to: victim,
            msg: Msg::StealRequest { round, load: obs.workload, eta: obs.queue_eta() },
        });
    }

    fn on_message(
        &mut self,
        obs: &mut PolicyObs<'_>,
        from: ProcessId,
        msg: &Msg,
        _now: f64,
        out: &mut Vec<PolicyAction>,
    ) {
        match *msg {
            Msg::StealRequest { round, .. } => {
                self.counters.requests_received += 1;
                let grant = if obs.middle_zone || obs.role != Role::Busy {
                    0
                } else {
                    self.steal_amount(obs.workload, obs.wt)
                };
                if grant > 0 {
                    self.counters.accepts_sent += 1;
                    self.counters.transactions += 1;
                } else {
                    self.counters.declines_sent += 1;
                }
                // Always reply, even empty: the thief is blocked on us.
                out.push(PolicyAction::ExportCount { to: from, round, count: grant });
            }
            // Victim side: transfer acked; stateless, nothing to unlock.
            Msg::ExportAck { .. } => {}
            _ => {}
        }
    }

    /// Thief side: a steal reply landed (tasks already enqueued).
    fn on_transfer(
        &mut self,
        obs: &mut PolicyObs<'_>,
        _from: ProcessId,
        round: u64,
        received: usize,
        now: f64,
        _out: &mut Vec<PolicyAction>,
    ) {
        match self.state {
            StealState::Outstanding { round: r, .. } if r == round => {
                if received == 0 {
                    self.attempt_failed(now, obs.rng);
                } else {
                    self.state = StealState::Free;
                    self.counters.transactions += 1;
                    self.retries_left = self.cfg.tries.max(1);
                    // success anywhere resets the ladder: steal locally again
                    self.failures = 0;
                    self.next_attempt_at = now;
                }
            }
            _ => {
                // A reply for a round whose timeout already fired: the tasks
                // are enqueued regardless (over-steal risk) — account for it.
                if let Some(pos) = self.stale_rounds.iter().position(|&r| r == round) {
                    self.stale_rounds.swap_remove(pos);
                    if received > 0 {
                        self.counters.late_grants += 1;
                        self.counters.transactions += 1;
                        self.failures = 0;
                    }
                }
            }
        }
    }

    fn on_tick(&mut self, now: f64, rng: &mut Rng) {
        if let StealState::Outstanding { round, deadline } = self.state {
            if now >= deadline {
                // victim vanished or the reply is slow: remember the round
                // so a late grant is recognized, count, and move on
                self.stale_rounds.push(round);
                self.counters.confirm_timeouts += 1;
                self.attempt_failed(now, rng);
            }
        }
    }

    fn next_wakeup(&self) -> Option<f64> {
        match self.state {
            StealState::Free => Some(self.next_attempt_at),
            StealState::Outstanding { deadline, .. } => Some(deadline),
        }
    }

    fn set_delta(&mut self, delta: f64) {
        self.cfg.delta = delta;
    }

    fn engaged(&self) -> bool {
        self.state != StealState::Free
    }

    fn counters(&self) -> &DlbCounters {
        &self.counters
    }

    fn counters_mut(&mut self) -> &mut DlbCounters {
        &mut self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::ObsBox;
    use super::*;

    /// 2 nodes × 4 ranks, inter-node cost 4 (the `cluster2x4` shape).
    fn cluster() -> Topology {
        Topology::Cluster { nodes: 2, per_node: 4, inter_hops: 4 }
    }

    fn hier(me: u32, local_tries: usize, topo: &Topology, p: usize) -> HierarchicalStealing {
        HierarchicalStealing::new(
            ProcessId(me),
            PairingConfig::default(),
            true,
            local_tries,
            topo,
            p,
        )
    }

    fn request_target(p: &mut HierarchicalStealing, ob: &mut ObsBox, now: f64) -> ProcessId {
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), now, &mut out);
        match out.as_slice() {
            [PolicyAction::Send { to, msg: Msg::StealRequest { .. } }] => *to,
            other => panic!("expected one StealRequest, got {other:?}"),
        }
    }

    #[test]
    fn tiers_split_on_the_cluster_boundary() {
        let p = hier(1, 3, &cluster(), 8);
        assert_eq!(p.local, vec![ProcessId(0), ProcessId(2), ProcessId(3)]);
        assert_eq!(
            p.far,
            (4..8).map(ProcessId).collect::<Vec<_>>(),
            "remote tier = the other node"
        );
    }

    #[test]
    fn first_local_tries_attempts_stay_on_node() {
        let topo = cluster();
        let mut p = hier(1, 3, &topo, 8);
        let mut ob = ObsBox::new(1, 8, 0, 2); // idle
        for attempt in 0..3 {
            let victim = request_target(&mut p, &mut ob, 0.0);
            assert!(
                victim.idx() < 4,
                "attempt {attempt} must stay intra-node, asked {victim}"
            );
            let round = p.next_round - 1;
            let mut out = Vec::new();
            p.on_transfer(&mut ob.obs(), victim, round, 0, 0.0, &mut out); // denied
        }
        // ladder climbed: the 4th attempt escalates to the other node
        let victim = request_target(&mut p, &mut ob, 0.0);
        assert!(victim.idx() >= 4, "escalated attempt must leave the node, asked {victim}");
    }

    #[test]
    fn success_resets_the_ladder_to_local() {
        let topo = cluster();
        let mut p = hier(0, 1, &topo, 8);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        // fail once locally → escalate
        let v = request_target(&mut p, &mut ob, 0.0);
        let mut out = Vec::new();
        p.on_transfer(&mut ob.obs(), v, p.next_round - 1, 0, 0.0, &mut out);
        let v = request_target(&mut p, &mut ob, 0.0);
        assert!(v.idx() >= 4, "escalated");
        // remote grant succeeds → next hunt starts local again
        p.on_transfer(&mut ob.obs(), v, p.next_round - 1, 2, 0.001, &mut out);
        assert_eq!(p.failures, 0);
        let v = request_target(&mut p, &mut ob, 0.001);
        assert!(v.idx() < 4, "back to the local tier, asked {v}");
        assert_eq!(p.counters.transactions, 1);
    }

    #[test]
    fn flat_topology_degenerates_to_uniform_stealing() {
        let p = hier(0, 3, &Topology::Flat, 6);
        assert_eq!(p.local.len(), 5, "everyone is one hop away");
        assert!(p.far.is_empty());
        // escalation can never trigger — pick_victim stays on the local path
        assert!(!p.escalated());
    }

    #[test]
    fn busy_process_never_steals() {
        let topo = cluster();
        let mut p = hier(0, 3, &topo, 8);
        let mut ob = ObsBox::new(0, 8, 9, 2); // busy
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn victim_grants_half_the_excess() {
        let topo = cluster();
        let mut p = hier(1, 3, &topo, 8);
        let mut ob = ObsBox::new(1, 8, 12, 2); // excess 10 → grant 5
        let mut out = Vec::new();
        p.on_message(
            &mut ob.obs(),
            ProcessId(0),
            &Msg::StealRequest { round: 9, load: 0, eta: 0.0 },
            0.001,
            &mut out,
        );
        assert!(matches!(
            out.as_slice(),
            [PolicyAction::ExportCount { to: ProcessId(0), round: 9, count: 5 }]
        ));
    }

    #[test]
    fn late_grant_is_counted_not_replayed() {
        let topo = cluster();
        let mut p = hier(0, 3, &topo, 8);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        let round = p.next_round - 1;
        let mut rng = Rng::new(7);
        p.on_tick(10.0, &mut rng); // past the confirm deadline
        assert!(!p.engaged());
        assert_eq!(p.counters.confirm_timeouts, 1);
        // the grant finally lands — tasks were enqueued by the process, the
        // policy books it as a late grant and stays Free
        p.on_transfer(&mut ob.obs(), ProcessId(1), round, 3, 10.1, &mut out);
        assert_eq!(p.counters.late_grants, 1);
        assert!(!p.engaged());
    }

    #[test]
    fn backoff_after_exhausting_retries() {
        let topo = cluster();
        let mut p = hier(0, 2, &topo, 8);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        let tries = p.cfg.tries;
        let now = 0.01;
        let mut failures = 0;
        loop {
            let mut out = Vec::new();
            p.poll(&mut ob.obs(), now, &mut out);
            if out.is_empty() {
                break;
            }
            let round = p.next_round - 1;
            p.on_transfer(&mut ob.obs(), ProcessId(1), round, 0, now, &mut out);
            failures += 1;
            assert!(failures < 100, "no backoff");
        }
        assert_eq!(failures, tries + 1);
        assert!(p.next_attempt_at > now);
        assert_eq!(p.failures, 0, "ladder reset with the backoff");
    }
}
