//! The paper's randomized idle–busy pairing (§3) as a [`BalancerPolicy`].
//!
//! This is a behavior-preserving refactor: the handshake state machine
//! stays in [`crate::dlb::pairing::Pairing`] untouched; this wrapper is the
//! glue that used to live inline in `core::process::ProcessState` —
//! turning `PairAction`s into messages, remembering the accepted peer's
//! role/load for the confirm, and re-arming the δ back-off after a
//! transaction.

use crate::core::ids::ProcessId;
use crate::dlb::pairing::{PairAction, PairStatus, Pairing, PairingConfig};
use crate::dlb::strategy::PartnerInfo;
use crate::metrics::counters::DlbCounters;
use crate::net::message::{Msg, Role};
use crate::util::rng::Rng;

use super::{BalancerPolicy, PolicyAction, PolicyObs};

pub struct RandomPairing {
    pairing: Pairing,
    /// Info about the peer we accepted (role/load/eta from their request).
    accepted_peer: Option<(ProcessId, Role, PartnerInfo)>,
}

impl RandomPairing {
    pub fn new(me: ProcessId, cfg: PairingConfig) -> Self {
        RandomPairing { pairing: Pairing::new(me, cfg), accepted_peer: None }
    }

    /// Paper §3: after a round (successful or not) wait δ before the next
    /// search — jittered to avoid lock-step retries.
    fn finish_transaction(&mut self, now: f64, rng: &mut Rng) {
        if matches!(self.pairing.status, PairStatus::InTransaction { .. }) {
            self.pairing.transaction_done(now);
        }
        self.accepted_peer = None;
        let jitter = 0.5 + rng.next_f64();
        self.pairing.next_search_at = now + self.pairing.cfg.delta * jitter;
    }
}

impl BalancerPolicy for RandomPairing {
    fn name(&self) -> &'static str {
        "pairing"
    }

    fn init(&mut self, now: f64, rng: &mut Rng) {
        // stagger the first search uniformly over one δ
        self.pairing.next_search_at = now + rng.next_f64() * self.pairing.cfg.delta;
    }

    fn poll(&mut self, obs: &mut PolicyObs<'_>, now: f64, out: &mut Vec<PolicyAction>) {
        // A busy process only searches if it actually has exportable tasks;
        // an idle process always searches (it can receive work even when it
        // owns nothing — that is the point of migration).  Middle-zone
        // processes (gap model, §3) do not search at all.
        let searchable = !obs.middle_zone
            && match obs.role {
                Role::Busy => obs.pinned || obs.workload > obs.wt,
                Role::Idle => true,
            };
        if !searchable {
            return;
        }
        let act = self.pairing.maybe_start_round(now, obs.role, obs.num_processes, obs.rng);
        if let PairAction::SendRequests { round, role, targets } = act {
            let load = obs.workload;
            let eta = obs.queue_eta();
            for t in targets {
                out.push(PolicyAction::Send {
                    to: t,
                    msg: Msg::PairRequest { round, role, load, eta },
                });
            }
        }
    }

    fn on_message(
        &mut self,
        obs: &mut PolicyObs<'_>,
        from: ProcessId,
        msg: &Msg,
        now: f64,
        out: &mut Vec<PolicyAction>,
    ) {
        match *msg {
            Msg::PairRequest { round, role, load, eta } => {
                // Middle-zone processes (gap model, §3) sit out entirely:
                // force a decline by reporting the same role as the asker.
                let my_role = if obs.middle_zone { role } else { obs.role };
                match self.pairing.on_request(from, round, role, my_role, now) {
                    PairAction::SendAccept { to, round } => {
                        self.accepted_peer = Some((from, role, PartnerInfo { load, eta }));
                        out.push(PolicyAction::Send {
                            to,
                            msg: Msg::PairAccept {
                                round,
                                load: obs.workload,
                                eta: obs.queue_eta(),
                            },
                        });
                    }
                    PairAction::SendDecline { to, round } => {
                        out.push(PolicyAction::Send { to, msg: Msg::PairDecline { round } });
                    }
                    _ => {}
                }
            }
            Msg::PairAccept { round, load, eta } => {
                match self.pairing.on_accept(from, round, now) {
                    PairAction::Confirmed { partner, round, then_export } => {
                        out.push(PolicyAction::Send {
                            to: partner,
                            msg: Msg::PairConfirm {
                                round,
                                load: obs.workload,
                                eta: obs.queue_eta(),
                            },
                        });
                        if then_export {
                            out.push(PolicyAction::ExportSelected {
                                to: partner,
                                round,
                                partner: PartnerInfo { load, eta },
                            });
                        }
                    }
                    PairAction::SendRelease { to, round } => {
                        out.push(PolicyAction::Send { to, msg: Msg::PairRelease { round } });
                    }
                    _ => {}
                }
            }
            Msg::PairDecline { round } => {
                let _ = self.pairing.on_decline(round, now, obs.rng);
            }
            Msg::PairConfirm { round, load, eta } => {
                let requester_is_busy = match self.accepted_peer {
                    Some((p, r, _)) if p == from => r == Role::Busy,
                    _ => false,
                };
                if let PairAction::BeginTransaction { partner, round, export } =
                    self.pairing.on_confirm(from, round, requester_is_busy, now)
                {
                    if export {
                        // refresh partner info from the confirm
                        out.push(PolicyAction::ExportSelected {
                            to: partner,
                            round,
                            partner: PartnerInfo { load, eta },
                        });
                    }
                    // else: wait for their TaskExport
                }
            }
            Msg::PairRelease { round } => {
                let _ = self.pairing.on_release(from, round);
                self.accepted_peer = None;
            }
            // Our export was acked: unlock and re-arm the back-off.
            Msg::ExportAck { .. } => {
                self.finish_transaction(now, obs.rng);
            }
            _ => {}
        }
    }

    fn on_transfer(
        &mut self,
        obs: &mut PolicyObs<'_>,
        _from: ProcessId,
        _round: u64,
        _received: usize,
        now: f64,
        _out: &mut Vec<PolicyAction>,
    ) {
        // tasks arrived: the transaction is complete on our side
        self.finish_transaction(now, obs.rng);
    }

    fn on_tick(&mut self, now: f64, rng: &mut Rng) {
        self.pairing.on_tick(now, rng);
    }

    fn next_wakeup(&self) -> Option<f64> {
        self.pairing.next_wakeup()
    }

    fn set_delta(&mut self, delta: f64) {
        self.pairing.cfg.delta = delta;
    }

    fn engaged(&self) -> bool {
        !self.pairing.is_free()
    }

    fn counters(&self) -> &DlbCounters {
        &self.pairing.counters
    }

    fn counters_mut(&mut self) -> &mut DlbCounters {
        &mut self.pairing.counters
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::ObsBox;
    use super::*;

    #[test]
    fn idle_search_emits_requests_with_load() {
        let mut p = RandomPairing::new(ProcessId(0), PairingConfig::default());
        let mut ob = ObsBox::new(0, 10, 0, 2); // idle
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        let reqs: Vec<_> = out
            .iter()
            .filter(|a| {
                matches!(a, PolicyAction::Send { msg: Msg::PairRequest { role: Role::Idle, .. }, .. })
            })
            .collect();
        assert_eq!(reqs.len(), 5, "five tries: {out:?}");
        assert!(p.engaged());
    }

    #[test]
    fn busy_below_threshold_does_not_search() {
        let mut p = RandomPairing::new(ProcessId(0), PairingConfig::default());
        let mut ob = ObsBox::new(0, 10, 1, 2);
        ob.role = Role::Busy; // inconsistent role/pinned — not searchable
        let mut out = Vec::new();
        p.poll(&mut ob.obs(), 0.0, &mut out);
        assert!(out.is_empty());
        ob.pinned = true; // fig3-style pin → searches regardless of queue
        p.poll(&mut ob.obs(), 0.0, &mut out);
        assert!(!out.is_empty());
    }

    #[test]
    fn request_accept_confirm_export_flow() {
        // busy p1 receives an idle request, accepts, then exports on confirm
        let mut p = RandomPairing::new(ProcessId(1), PairingConfig::default());
        let mut ob = ObsBox::new(1, 4, 9, 2); // busy
        let mut out = Vec::new();
        p.on_message(
            &mut ob.obs(),
            ProcessId(0),
            &Msg::PairRequest { round: 7, role: Role::Idle, load: 0, eta: 0.0 },
            0.001,
            &mut out,
        );
        assert!(matches!(
            out.as_slice(),
            [PolicyAction::Send { msg: Msg::PairAccept { round: 7, load: 9, .. }, .. }]
        ));
        out.clear();
        p.on_message(
            &mut ob.obs(),
            ProcessId(0),
            &Msg::PairConfirm { round: 7, load: 0, eta: 0.0 },
            0.002,
            &mut out,
        );
        assert!(
            matches!(
                out.as_slice(),
                [PolicyAction::ExportSelected { round: 7, partner, .. }] if partner.load == 0
            ),
            "confirm from idle requester → we export: {out:?}"
        );
        // ack closes the transaction
        out.clear();
        p.on_message(
            &mut ob.obs(),
            ProcessId(0),
            &Msg::ExportAck { round: 7, accepted: 3 },
            0.003,
            &mut out,
        );
        assert!(!p.engaged());
    }

    #[test]
    fn middle_zone_declines() {
        let mut p = RandomPairing::new(ProcessId(1), PairingConfig::default());
        let mut ob = ObsBox::new(1, 4, 9, 2);
        ob.middle_zone = true;
        let mut out = Vec::new();
        p.on_message(
            &mut ob.obs(),
            ProcessId(0),
            &Msg::PairRequest { round: 3, role: Role::Idle, load: 0, eta: 0.0 },
            0.001,
            &mut out,
        );
        assert!(matches!(
            out.as_slice(),
            [PolicyAction::Send { msg: Msg::PairDecline { round: 3 }, .. }]
        ));
    }
}
