//! The pluggable balancer-policy subsystem.
//!
//! A [`BalancerPolicy`] abstracts "what does a process do about load each
//! time something happens": when to search, whom to talk to, and how much
//! work to move.  Five implementations compete inside the same
//! deterministic simulator and threaded runtime:
//!
//! - [`RandomPairing`] — the paper's randomized idle–busy pairing (§3),
//!   refactored out of `core::process` / `dlb::pairing` unchanged in
//!   behavior;
//! - [`WorkStealing`] — receiver-initiated stealing from uniformly random
//!   victims with bounded retries (John et al. 2022);
//! - [`HierarchicalStealing`] — locality-aware stealing over the topology's
//!   distance tiers: intra-node first, distance-weighted remote escalation
//!   after `local_tries` consecutive local failures;
//! - [`Diffusion`] — periodic first-order load averaging restricted to
//!   topology neighbors (Demirel & Sbalzarini 2013);
//! - [`SosDiffusion`] — the same exchange pattern with the second-order
//!   momentum term and spectrally-tuned (α, β): the previous round's flows
//!   carry over, cutting convergence rounds on poorly-conditioned shapes
//!   (rings, large tori, sparse graphs).
//!
//! The two stealing policies are one state machine: [`StealProtocol`]
//! parameterized by a [`VictimSelector`] (`UniformVictims` vs the
//! `LocalityLadder`), so the wire protocol, retry/back-off and late-grant
//! accounting exist exactly once.
//!
//! Any of the five can additionally be wrapped in [`AdaptiveDelta`], the
//! AIMD controller that retunes the back-off / exchange period δ from
//! observed outcomes (shrink on successful transfers, grow on failed
//! rounds) instead of holding the paper's fixed δ.
//!
//! The split of responsibilities keeps every policy a pure, unit-testable
//! state machine, exactly like `dlb::pairing` always was:
//!
//! - the **policy** decides *when/whom/how much* and returns
//!   [`PolicyAction`]s;
//! - the **process state machine** (`core::process`) owns the queue, the
//!   data store, and the export mechanics (gathering task inputs, counting
//!   migrated doubles, acking transfers), and interprets the actions;
//! - the **engines** (`sim::engine`, `runtime::threaded`) stay policy-blind:
//!   they deliver messages and timer ticks.
//!
//! Task transfers are policy-neutral on the wire: every policy moves work
//! with `Msg::TaskExport` / `Msg::ExportAck`, so migrated-task accounting,
//! re-export of stolen tasks, and result return-to-origin work identically
//! under all of them.

pub mod adaptive;
pub mod diffusion;
pub mod hierarchical;
pub mod random_pairing;
pub mod sos_diffusion;
pub mod work_stealing;

pub use adaptive::{AdaptiveConfig, AdaptiveDelta};
pub use diffusion::Diffusion;
pub use hierarchical::{HierarchicalStealing, LocalityLadder};
pub use random_pairing::RandomPairing;
pub use sos_diffusion::{SosDiffusion, SosParams};
pub use work_stealing::{StealProtocol, UniformVictims, VictimSelector, WorkStealing};

use crate::config::PolicyKind;
use crate::core::graph::TaskGraph;
use crate::core::ids::ProcessId;
use crate::dlb::pairing::PairingConfig;
use crate::dlb::perfmodel::PerfRecorder;
use crate::dlb::strategy::PartnerInfo;
use crate::metrics::counters::DlbCounters;
use crate::net::message::{Msg, Role};
use crate::net::topology::Topology;
use crate::sched::queue::ReadyQueue;
use crate::util::rng::Rng;

/// What a policy sees each time it is consulted — a read-only view of the
/// process plus its private RNG stream.  Cheap scalars are precomputed;
/// the O(queue) eta is computed on demand via [`PolicyObs::queue_eta`]
/// only when a policy actually reports it.
pub struct PolicyObs<'a> {
    pub me: ProcessId,
    pub num_processes: usize,
    /// Current workload w_i(t) (ready-queue length).
    pub workload: usize,
    /// Busy/idle classification (role-override resolved).
    pub role: Role,
    /// Gap-model middle zone (§3): the process sits out balancing entirely.
    pub middle_zone: bool,
    /// Role is pinned by an experiment (`role_override`) — protocol
    /// micro-benchmarks drive searches regardless of queue state.
    pub pinned: bool,
    /// The busy threshold W_T.
    pub wt: usize,
    /// Topology neighbor set (diffusion's exchange partners).
    pub neighbors: &'a [ProcessId],
    /// The ready queue + lookups backing [`Self::queue_eta`].
    pub queue: &'a ReadyQueue,
    pub graph: &'a TaskGraph,
    pub perf: &'a PerfRecorder,
    pub rng: &'a mut Rng,
}

impl PolicyObs<'_> {
    /// Expected time to drain the current queue (the eta of §3's Smart
    /// strategy): per-task estimates from the performance recorder.  An
    /// O(queue) scan — call only when the value is actually sent.
    pub fn queue_eta(&self) -> f64 {
        self.queue
            .iter()
            .map(|rt| {
                let n = self.graph.task(rt.task);
                self.perf.exec_estimate(n.kind, n.flops)
            })
            .sum()
    }
}

/// Instructions a policy hands back to the process state machine.
#[derive(Debug)]
pub enum PolicyAction {
    /// Transmit a control message.
    Send { to: ProcessId, msg: Msg },
    /// Run the configured export strategy (Basic/Equalizing/Smart) against
    /// `partner` and ship the selection as `TaskExport { round }`.
    ExportSelected { to: ProcessId, round: u64, partner: PartnerInfo },
    /// Ship exactly `count` ready tasks from the queue back (capped so the
    /// local queue never drops below W_T) as `TaskExport { round }`.
    /// `count == 0` ships an empty export — protocol completion for a
    /// denied steal.
    ExportCount { to: ProcessId, round: u64, count: usize },
}

/// A distributed load-balancing policy: a pure state machine fed
/// observations, messages and time; emitting actions.
pub trait BalancerPolicy: Send {
    fn name(&self) -> &'static str;

    /// Called once at process start (stagger initial activity).
    fn init(&mut self, now: f64, rng: &mut Rng);

    /// Consulted after every state change and timer tick: start searches,
    /// run periodic exchanges.
    fn poll(&mut self, obs: &mut PolicyObs<'_>, now: f64, out: &mut Vec<PolicyAction>);

    /// A DLB control-plane message arrived (handshake, steal request, load
    /// report, export ack).  `TaskExport` is routed to [`Self::on_transfer`]
    /// instead.
    fn on_message(
        &mut self,
        obs: &mut PolicyObs<'_>,
        from: ProcessId,
        msg: &Msg,
        now: f64,
        out: &mut Vec<PolicyAction>,
    );

    /// A `TaskExport` from `from` was received: its `received` tasks are
    /// already enqueued and acked.  Zero tasks is a denied steal / empty
    /// transaction — the cue to retry or back off.
    fn on_transfer(
        &mut self,
        obs: &mut PolicyObs<'_>,
        from: ProcessId,
        round: u64,
        received: usize,
        now: f64,
        out: &mut Vec<PolicyAction>,
    );

    /// Deadline sweep, driven by timer ticks.
    fn on_tick(&mut self, now: f64, rng: &mut Rng);

    /// Earliest time `poll`/`on_tick` must run again, if any.
    fn next_wakeup(&self) -> Option<f64>;

    /// Retune the back-off / exchange period δ (the [`AdaptiveDelta`]
    /// wrapper's control knob).  Takes effect from the next scheduling
    /// decision; an already-armed deadline is not rewound.
    fn set_delta(&mut self, delta: f64);

    /// Mid-handshake or mid-transfer (diagnostics and tests).
    fn engaged(&self) -> bool;

    fn counters(&self) -> &DlbCounters;
    fn counters_mut(&mut self) -> &mut DlbCounters;
}

/// Everything needed to instantiate one process's balancer (derived from
/// `ProcessParams` by both engines).
#[derive(Debug, Clone)]
pub struct PolicySpec {
    pub kind: PolicyKind,
    pub pairing: PairingConfig,
    /// Work stealing: steal half the victim's excess vs a single task.
    pub steal_half: bool,
    /// Hierarchical: consecutive failed local attempts before escalating.
    pub local_tries: usize,
    /// AIMD δ-controller bounds; `None` = the paper's fixed δ.
    pub adaptive: Option<AdaptiveConfig>,
    /// Second-order diffusion coefficients, precomputed once per run by
    /// `ProcessParams::from_config` (the power iteration is O(P·E)) and
    /// shared by every rank.  `None` outside the SOS policy — `build`
    /// derives them on the spot then, which only tests exercise.
    pub sos: Option<SosParams>,
}

/// Instantiate the configured policy for one process, optionally wrapped in
/// the adaptive-δ controller.
pub fn build(
    spec: &PolicySpec,
    me: ProcessId,
    num_processes: usize,
    topology: &Topology,
) -> Box<dyn BalancerPolicy> {
    let base: Box<dyn BalancerPolicy> = match spec.kind {
        PolicyKind::RandomPairing => Box::new(RandomPairing::new(me, spec.pairing)),
        PolicyKind::WorkStealing => Box::new(WorkStealing::new(me, spec.pairing, spec.steal_half)),
        PolicyKind::Hierarchical => Box::new(HierarchicalStealing::new(
            me,
            spec.pairing,
            spec.steal_half,
            spec.local_tries,
            topology,
            num_processes,
        )),
        PolicyKind::Diffusion => Box::new(Diffusion::new(me, spec.pairing)),
        PolicyKind::SosDiffusion => Box::new(SosDiffusion::new(
            me,
            spec.pairing,
            spec.sos
                .unwrap_or_else(|| SosParams::for_topology(topology, num_processes)),
        )),
    };
    match spec.adaptive {
        Some(cfg) => Box::new(AdaptiveDelta::new(base, cfg, spec.pairing.delta)),
        None => base,
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use std::sync::Arc;

    use super::*;
    use crate::core::graph::GraphBuilder;
    use crate::core::task::TaskKind;
    use crate::dlb::costmodel::CostModel;
    use crate::sched::queue::ReadyTask;

    /// A standalone observation for policy unit tests (no ProcessState):
    /// owns a synthetic queue/graph of `workload` tasks.
    pub struct ObsBox {
        pub me: ProcessId,
        pub num_processes: usize,
        pub workload: usize,
        pub role: Role,
        pub middle_zone: bool,
        pub pinned: bool,
        pub wt: usize,
        pub neighbors: Vec<ProcessId>,
        pub rng: Rng,
        queue: ReadyQueue,
        graph: Arc<TaskGraph>,
        perf: PerfRecorder,
    }

    impl ObsBox {
        pub fn new(me: u32, p: usize, workload: usize, wt: usize) -> Self {
            let mut gb = GraphBuilder::new();
            let mut queue = ReadyQueue::new();
            for _ in 0..workload {
                let d = gb.data(ProcessId(me), 8, 8);
                let t = gb.task(TaskKind::Synthetic, vec![], d, 1000, None);
                queue.push(ReadyTask::home(t, ProcessId(me)));
            }
            ObsBox {
                me: ProcessId(me),
                num_processes: p,
                workload,
                role: if workload > wt { Role::Busy } else { Role::Idle },
                middle_zone: false,
                pinned: false,
                wt,
                neighbors: (0..p as u32).filter(|&i| i != me).map(ProcessId).collect(),
                rng: Rng::new(42 + me as u64),
                queue,
                graph: gb.build(),
                perf: PerfRecorder::new(CostModel::new(8.8e9, 2.2e8)),
            }
        }

        pub fn obs(&mut self) -> PolicyObs<'_> {
            PolicyObs {
                me: self.me,
                num_processes: self.num_processes,
                workload: self.workload,
                role: self.role,
                middle_zone: self.middle_zone,
                pinned: self.pinned,
                wt: self.wt,
                neighbors: &self.neighbors,
                queue: &self.queue,
                graph: &self.graph,
                perf: &self.perf,
                rng: &mut self.rng,
            }
        }
    }
}
