//! Reactive δ: an AIMD controller wrapped around any [`BalancerPolicy`].
//!
//! The paper fixes the search back-off / exchange period δ for a whole run
//! (§6: 10 ms).  Reactive balancing driven by observed outcomes beats a
//! fixed period (Samfass et al. 2019): when transfers succeed the system is
//! imbalanced and should rebalance *faster*; when rounds keep failing the
//! system is balanced (or drained) and the protocol should quiesce.
//!
//! [`AdaptiveDelta`] implements that as the classic AIMD rule, inverted for
//! a period rather than a rate:
//!
//! - **successful transfer** → δ ← max(δ · shrink, δ_min)  (multiplicative
//!   decrease: react quickly to discovered imbalance);
//! - **failed round / confirm timeout** → δ ← min(δ + grow, δ_max)
//!   (additive increase: back off gently, avoid synchronized thrash).
//!
//! It is a pure decorator: it delegates every [`BalancerPolicy`] method to
//! the wrapped policy, watches the outcome counters it already maintains
//! (`transactions`, `failed_rounds`, `confirm_timeouts` — no new plumbing
//! through the engines), and pushes the retuned δ back down through
//! [`BalancerPolicy::set_delta`].  Works identically around all four
//! policies and in both engines.

use crate::core::ids::ProcessId;
use crate::metrics::counters::DlbCounters;
use crate::net::message::Msg;
use crate::util::rng::Rng;

use super::{BalancerPolicy, PolicyAction, PolicyObs};

/// AIMD bounds and gains (`dlb.delta_min` / `dlb.delta_max`; the gains are
/// the standard halving/one-step choices).
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveConfig {
    pub delta_min: f64,
    pub delta_max: f64,
    /// Multiplicative factor applied on each successful transfer (< 1).
    pub shrink: f64,
    /// Additive growth in seconds applied on each failed round.
    pub grow: f64,
}

impl AdaptiveConfig {
    pub fn new(delta_min: f64, delta_max: f64) -> Self {
        AdaptiveConfig { delta_min, delta_max, shrink: 0.5, grow: delta_min }
    }
}

/// The decorator.  See the module docs for the control rule.
pub struct AdaptiveDelta {
    inner: Box<dyn BalancerPolicy>,
    cfg: AdaptiveConfig,
    delta: f64,
    /// Counter watermarks from the last adjustment.
    seen_transactions: u64,
    seen_failures: u64,
}

impl AdaptiveDelta {
    pub fn new(inner: Box<dyn BalancerPolicy>, cfg: AdaptiveConfig, initial_delta: f64) -> Self {
        let delta = initial_delta.clamp(cfg.delta_min, cfg.delta_max);
        let mut this = AdaptiveDelta {
            inner,
            cfg,
            delta,
            seen_transactions: 0,
            seen_failures: 0,
        };
        this.inner.set_delta(delta);
        this
    }

    /// The controller's current period (diagnostics and tests).
    pub fn current_delta(&self) -> f64 {
        self.delta
    }

    /// Compare the outcome counters against the watermarks and retune.
    /// At most one adjustment per delegated call — success wins ties (a
    /// call that both granted and failed still means there is work moving).
    fn readjust(&mut self) {
        let c = self.inner.counters();
        let transactions = c.transactions;
        let failures = c.failed_rounds + c.confirm_timeouts;
        let mut changed = false;
        if transactions > self.seen_transactions {
            self.delta = (self.delta * self.cfg.shrink).max(self.cfg.delta_min);
            changed = true;
        } else if failures > self.seen_failures {
            self.delta = (self.delta + self.cfg.grow).min(self.cfg.delta_max);
            changed = true;
        }
        self.seen_transactions = transactions;
        self.seen_failures = failures;
        if changed {
            self.inner.set_delta(self.delta);
        }
    }
}

impl BalancerPolicy for AdaptiveDelta {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn init(&mut self, now: f64, rng: &mut Rng) {
        self.inner.init(now, rng);
    }

    fn poll(&mut self, obs: &mut PolicyObs<'_>, now: f64, out: &mut Vec<PolicyAction>) {
        self.inner.poll(obs, now, out);
        self.readjust();
    }

    fn on_message(
        &mut self,
        obs: &mut PolicyObs<'_>,
        from: ProcessId,
        msg: &Msg,
        now: f64,
        out: &mut Vec<PolicyAction>,
    ) {
        self.inner.on_message(obs, from, msg, now, out);
        self.readjust();
    }

    fn on_transfer(
        &mut self,
        obs: &mut PolicyObs<'_>,
        from: ProcessId,
        round: u64,
        received: usize,
        now: f64,
        out: &mut Vec<PolicyAction>,
    ) {
        self.inner.on_transfer(obs, from, round, received, now, out);
        self.readjust();
    }

    fn on_tick(&mut self, now: f64, rng: &mut Rng) {
        self.inner.on_tick(now, rng);
        self.readjust();
    }

    fn next_wakeup(&self) -> Option<f64> {
        self.inner.next_wakeup()
    }

    fn set_delta(&mut self, delta: f64) {
        self.delta = delta.clamp(self.cfg.delta_min, self.cfg.delta_max);
        self.inner.set_delta(self.delta);
    }

    fn engaged(&self) -> bool {
        self.inner.engaged()
    }

    fn counters(&self) -> &DlbCounters {
        self.inner.counters()
    }

    fn counters_mut(&mut self) -> &mut DlbCounters {
        self.inner.counters_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::ObsBox;
    use super::super::WorkStealing;
    use super::*;
    use crate::dlb::pairing::PairingConfig;

    fn adaptive_ws(me: u32) -> AdaptiveDelta {
        let cfg = PairingConfig::default(); // δ = 0.010
        AdaptiveDelta::new(
            Box::new(WorkStealing::new(ProcessId(me), cfg, true)),
            AdaptiveConfig::new(0.001, 0.050),
            cfg.delta,
        )
    }

    #[test]
    fn initial_delta_is_clamped_into_bounds() {
        let inner = Box::new(WorkStealing::new(ProcessId(0), PairingConfig::default(), true));
        let a = AdaptiveDelta::new(inner, AdaptiveConfig::new(0.001, 0.004), 0.010);
        assert!((a.current_delta() - 0.004).abs() < 1e-12, "clamped to δ_max");
    }

    #[test]
    fn success_shrinks_multiplicatively() {
        let mut a = adaptive_ws(0);
        let mut ob = ObsBox::new(0, 8, 0, 2); // idle thief
        let mut out = Vec::new();
        a.poll(&mut ob.obs(), 0.0, &mut out);
        let round = match &out[0] {
            PolicyAction::Send { msg: Msg::StealRequest { round, .. }, .. } => *round,
            other => panic!("{other:?}"),
        };
        a.on_transfer(&mut ob.obs(), ProcessId(1), round, 3, 0.001, &mut out);
        assert!((a.current_delta() - 0.005).abs() < 1e-12, "0.010 × 0.5");
    }

    #[test]
    fn failure_grows_additively_to_the_cap() {
        let mut a = adaptive_ws(0);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        for i in 0..200 {
            let mut out = Vec::new();
            a.poll(&mut ob.obs(), i as f64, &mut out);
            let round = match out.first() {
                Some(PolicyAction::Send { msg: Msg::StealRequest { round, .. }, .. }) => *round,
                _ => continue, // backing off this tick
            };
            a.on_transfer(&mut ob.obs(), ProcessId(1), round, 0, i as f64, &mut out);
        }
        assert!(
            (a.current_delta() - 0.050).abs() < 1e-12,
            "repeated failures must pin δ at δ_max, got {}",
            a.current_delta()
        );
    }

    #[test]
    fn shrink_never_goes_below_delta_min() {
        let mut a = adaptive_ws(0);
        let mut ob = ObsBox::new(0, 8, 0, 2);
        for i in 0..30 {
            let now = i as f64;
            let mut out = Vec::new();
            a.poll(&mut ob.obs(), now, &mut out);
            let round = match out.first() {
                Some(PolicyAction::Send { msg: Msg::StealRequest { round, .. }, .. }) => *round,
                _ => continue,
            };
            a.on_transfer(&mut ob.obs(), ProcessId(1), round, 2, now, &mut out);
        }
        assert!((a.current_delta() - 0.001).abs() < 1e-12, "floored at δ_min");
    }

    #[test]
    fn counters_and_identity_pass_through() {
        let mut a = adaptive_ws(3);
        assert_eq!(a.name(), "stealing", "the wrapper is transparent");
        a.counters_mut().rounds = 7;
        assert_eq!(a.counters().rounds, 7);
        assert!(!a.engaged());
    }
}
