//! The §4 analytic cost model for task migration.
//!
//! A node computes S flops/s and moves R doubles/s.  A task with F flops and
//! D doubles of migration traffic costs `T_L = F/S` locally and
//! `T_R = F/S + D/R` remotely; the *relative* migration overhead is
//!
//! ```text
//! Q = (S/R) · (D/F)
//! ```
//!
//! Paper's worked examples (S/R = 40): block GEMM with D = 3m², F = 2m³
//! gives Q = 60/m (negligible for large blocks); GEMV with D = m², F = 2m²
//! gives Q = 20 — twenty local tasks complete in the time one migration
//! round-trips.  `wt_guideline` turns Q into the paper's W_T guidance.

use crate::core::task::{TaskKind, TaskNode};

/// Machine-balance parameters (paper §4's S and R).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// S: flops per second per process.
    pub flops_per_sec: f64,
    /// R: doubles per second across the interconnect.
    pub doubles_per_sec: f64,
    /// Fixed per-task runtime overhead (scheduling, dispatch), seconds.
    pub task_overhead: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
}

impl CostModel {
    pub fn new(flops_per_sec: f64, doubles_per_sec: f64) -> Self {
        CostModel { flops_per_sec, doubles_per_sec, task_overhead: 0.0, latency: 0.0 }
    }

    /// The machine balance S/R (≈ 40 on the paper's Rackham nodes).
    pub fn s_over_r(&self) -> f64 {
        self.flops_per_sec / self.doubles_per_sec
    }

    /// T_L = F/S (eq. 2), plus runtime overhead.
    pub fn local_time(&self, flops: u64) -> f64 {
        flops as f64 / self.flops_per_sec + self.task_overhead
    }

    /// T_R = F/S + D/R (eq. 3), plus overheads and two message latencies
    /// (ship + return).
    pub fn remote_time(&self, flops: u64, doubles: u64) -> f64 {
        self.local_time(flops) + self.transfer_time(doubles) + 2.0 * self.latency
    }

    /// Pure wire time for `doubles`.
    pub fn transfer_time(&self, doubles: u64) -> f64 {
        doubles as f64 / self.doubles_per_sec
    }

    /// Q = (S/R)(D/F) (eq. 4) for explicit F, D.
    pub fn q(&self, flops: u64, doubles: u64) -> f64 {
        if flops == 0 {
            return f64::INFINITY;
        }
        self.s_over_r() * doubles as f64 / flops as f64
    }

    /// Q for a graph node, using its migration D = in + out doubles.
    pub fn q_of(&self, t: &TaskNode) -> f64 {
        self.q(t.flops, t.migration_doubles())
    }

    /// Q for a task kind at block size `b`, with D counted like the paper
    /// (§4: inputs + outputs that must cross the network).
    pub fn q_kind(&self, kind: TaskKind, b: u64) -> f64 {
        let f = kind.flops_for_block(b);
        let d = match kind {
            // paper counts D = 3m² for gemm (2 in + 1 out of the update);
            // we ship 3 inputs and return 1 output = 4m². Keep our real
            // traffic so predictions match the implementation.
            TaskKind::Gemm => 4 * b * b,
            TaskKind::Syrk => 3 * b * b,
            TaskKind::Trsm => 3 * b * b,
            TaskKind::Potrf => 2 * b * b,
            TaskKind::Gemv => b * b + 2 * b,
            TaskKind::Synthetic => 0,
        };
        self.q(f, d)
    }

    /// The paper's W_T guidance: exporting pays off only when at least ⌈Q⌉
    /// tasks remain locally per exported task, so the busy threshold should
    /// be at least this for the dominant task kind.
    pub fn wt_guideline(&self, kind: TaskKind, b: u64) -> usize {
        self.q_kind(kind, b).ceil().max(1.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_model() -> CostModel {
        // Any S with S/R = 40 reproduces the §4 numbers.
        CostModel::new(8.8e9, 2.2e8)
    }

    #[test]
    fn s_over_r_is_40() {
        assert!((paper_model().s_over_r() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn local_remote_times() {
        let m = paper_model();
        let f = 1_000_000u64;
        let d = 10_000u64;
        let tl = m.local_time(f);
        let tr = m.remote_time(f, d);
        assert!(tr > tl);
        assert!((tr - tl - d as f64 / m.doubles_per_sec).abs() < 1e-12);
    }

    #[test]
    fn q_gemm_paper_variant() {
        // paper counts D = 3m² → Q = 60/m with S/R = 40
        let m = paper_model();
        for &b in &[10u64, 100, 1000] {
            let q = m.q(2 * b * b * b, 3 * b * b);
            assert!((q - 60.0 / b as f64).abs() < 1e-9, "b={b}: {q}");
        }
    }

    #[test]
    fn q_gemv_is_about_20() {
        // paper: F = 2m², D = m² → Q = 20
        let m = paper_model();
        let q = m.q(2 * 1000 * 1000, 1000 * 1000);
        assert!((q - 20.0).abs() < 1e-9);
        // implementation variant (ship A and x, return y): still ≈ 20
        let qi = m.q_kind(TaskKind::Gemv, 1000);
        assert!((qi - 20.0).abs() < 0.1, "{qi}");
    }

    #[test]
    fn q_of_implementation_gemm_shrinks_with_block() {
        let m = paper_model();
        let q64 = m.q_kind(TaskKind::Gemm, 64);
        let q512 = m.q_kind(TaskKind::Gemm, 512);
        assert!(q64 > q512);
        assert!(q512 < 0.5, "large blocks migrate almost free: {q512}");
    }

    #[test]
    fn wt_guideline_matches_paper_reading() {
        let m = paper_model();
        // gemv: ~20 tasks must remain per export
        let wt = m.wt_guideline(TaskKind::Gemv, 512);
        assert!((19..=21).contains(&wt), "{wt}");
        // big gemm: threshold can be minimal
        assert_eq!(m.wt_guideline(TaskKind::Gemm, 2048), 1);
    }

    #[test]
    fn zero_flops_q_infinite() {
        assert!(paper_model().q(0, 10).is_infinite());
    }
}
