//! The randomized idle–busy pairing protocol (§3).
//!
//! Every process periodically tries to become one half of an idle–busy
//! pair: it draws `tries` (paper: 5) distinct peers uniformly at random and
//! sends each a `PairRequest`.  Receivers with the opposite role that are
//! not already engaged answer `PairAccept` and soft-lock awaiting a
//! `PairConfirm`; everyone else declines.  The requester confirms the first
//! accept and releases any later ones.  A fully-declined round backs off for
//! δ (jittered ±50% — without jitter two lone processes that request
//! simultaneously and decline each other would retry in lock-step forever).
//!
//! Once confirmed, the pair is locked ("will not accept or send any further
//! requests until their work exchange transaction has completed"): the busy
//! side sends `TaskExport`, the idle side answers `ExportAck`, both unlock.
//!
//! This module is a pure state machine: inputs are protocol events plus the
//! current time; outputs are `PairAction`s the process state machine turns
//! into messages.  That keeps it unit-testable without any transport and
//! shared verbatim between the DES and the threaded runtime.

use crate::core::ids::ProcessId;
use crate::metrics::counters::DlbCounters;
use crate::net::message::Role;
use crate::util::rng::Rng;

/// Tunables (paper §3/§6: tries = 5, δ = 10 ms).
#[derive(Debug, Clone, Copy)]
pub struct PairingConfig {
    pub tries: usize,
    pub delta: f64,
    pub confirm_timeout: f64,
}

impl Default for PairingConfig {
    fn default() -> Self {
        PairingConfig { tries: 5, delta: 0.010, confirm_timeout: 0.050 }
    }
}

/// Protocol phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PairStatus {
    /// Not engaged; may start a round or accept a request.
    Free,
    /// Sent a round of requests; counting replies.
    Searching { round: u64, role: Role, outstanding: usize, deadline: f64 },
    /// Accepted a request; soft-locked until Confirm/Release/timeout.
    PendingConfirm { partner: ProcessId, round: u64, deadline: f64 },
    /// Confirmed pair; `exporting` = we are the busy side.
    InTransaction { partner: ProcessId, round: u64, exporting: bool, deadline: f64 },
}

/// What the caller must do after feeding an event.
#[derive(Debug, Clone, PartialEq)]
pub enum PairAction {
    None,
    /// Send `PairRequest` to each target.
    SendRequests { round: u64, role: Role, targets: Vec<ProcessId> },
    SendAccept { to: ProcessId, round: u64 },
    SendDecline { to: ProcessId, round: u64 },
    /// We confirmed `partner`; if `then_export`, we are the busy side and
    /// must follow with a `TaskExport`.
    Confirmed { partner: ProcessId, round: u64, then_export: bool },
    SendRelease { to: ProcessId, round: u64 },
    /// Our partner confirmed us; if `export`, we are the busy side and must
    /// send the `TaskExport` now.
    BeginTransaction { partner: ProcessId, round: u64, export: bool },
}

/// The per-process pairing engine.
#[derive(Debug)]
pub struct Pairing {
    pub cfg: PairingConfig,
    pub status: PairStatus,
    pub next_search_at: f64,
    next_round: u64,
    me: ProcessId,
    pub counters: DlbCounters,
}

impl Pairing {
    pub fn new(me: ProcessId, cfg: PairingConfig) -> Self {
        Pairing {
            cfg,
            status: PairStatus::Free,
            next_search_at: 0.0,
            next_round: 1,
            me,
            counters: DlbCounters::default(),
        }
    }

    pub fn is_free(&self) -> bool {
        matches!(self.status, PairStatus::Free)
    }

    /// Earliest time `on_tick` needs to run again (search start or a
    /// deadline), if any.
    pub fn next_wakeup(&self) -> Option<f64> {
        match self.status {
            PairStatus::Free => Some(self.next_search_at),
            PairStatus::Searching { deadline, .. }
            | PairStatus::PendingConfirm { deadline, .. }
            | PairStatus::InTransaction { deadline, .. } => Some(deadline),
        }
    }

    /// Try to start a search round: requires Free, the backoff expired, and
    /// ≥ 1 peer.  `role` is the caller's current load classification.
    pub fn maybe_start_round(
        &mut self,
        now: f64,
        role: Role,
        num_processes: usize,
        rng: &mut Rng,
    ) -> PairAction {
        if !self.is_free() || now < self.next_search_at || num_processes < 2 {
            return PairAction::None;
        }
        let round = self.next_round;
        self.next_round += 1;
        let targets: Vec<ProcessId> = rng
            .sample_distinct(num_processes, self.cfg.tries, Some(self.me.idx()))
            .into_iter()
            .map(|i| ProcessId(i as u32))
            .collect();
        if targets.is_empty() {
            return PairAction::None;
        }
        self.counters.rounds += 1;
        self.counters.requests_sent += targets.len() as u64;
        self.status = PairStatus::Searching {
            round,
            role,
            outstanding: targets.len(),
            deadline: now + self.cfg.confirm_timeout,
        };
        PairAction::SendRequests { round, role, targets }
    }

    /// Incoming `PairRequest`.  `my_role` is our classification *now*.
    pub fn on_request(
        &mut self,
        from: ProcessId,
        round: u64,
        their_role: Role,
        my_role: Role,
        now: f64,
    ) -> PairAction {
        self.counters.requests_received += 1;
        if self.is_free() && my_role == their_role.opposite() {
            self.counters.accepts_sent += 1;
            self.status = PairStatus::PendingConfirm {
                partner: from,
                round,
                deadline: now + self.cfg.confirm_timeout,
            };
            PairAction::SendAccept { to: from, round }
        } else {
            self.counters.declines_sent += 1;
            PairAction::SendDecline { to: from, round }
        }
    }

    /// Incoming `PairAccept` (a peer answered our request).
    pub fn on_accept(&mut self, from: ProcessId, round: u64, now: f64) -> PairAction {
        match self.status {
            PairStatus::Searching { round: r, role, .. } if r == round => {
                let exporting = role == Role::Busy;
                self.counters.transactions += 1;
                self.status = PairStatus::InTransaction {
                    partner: from,
                    round,
                    exporting,
                    deadline: now + self.cfg.confirm_timeout,
                };
                PairAction::Confirmed { partner: from, round, then_export: exporting }
            }
            // late accept (already paired / round over): release the peer
            _ => PairAction::SendRelease { to: from, round },
        }
    }

    /// Incoming `PairDecline`.
    pub fn on_decline(&mut self, round: u64, now: f64, rng: &mut Rng) -> PairAction {
        if let PairStatus::Searching { round: r, ref mut outstanding, .. } = self.status {
            if r == round {
                *outstanding -= 1;
                if *outstanding == 0 {
                    self.round_failed(now, rng);
                }
            }
        }
        PairAction::None
    }

    /// Incoming `PairConfirm` (we accepted, requester committed).
    /// `their_role_busy`: the role from the original request — if the
    /// *requester* is busy, they export; otherwise we do.
    pub fn on_confirm(
        &mut self,
        from: ProcessId,
        round: u64,
        requester_is_busy: bool,
        now: f64,
    ) -> PairAction {
        match self.status {
            PairStatus::PendingConfirm { partner, round: r, .. }
                if partner == from && r == round =>
            {
                let export = !requester_is_busy; // requester idle ⇒ we are busy
                self.counters.transactions += 1;
                self.status = PairStatus::InTransaction {
                    partner: from,
                    round,
                    exporting: export,
                    deadline: now + self.cfg.confirm_timeout,
                };
                PairAction::BeginTransaction { partner: from, round, export }
            }
            _ => PairAction::None, // stale confirm; ignore
        }
    }

    /// Incoming `PairRelease`.
    pub fn on_release(&mut self, from: ProcessId, round: u64) -> PairAction {
        if let PairStatus::PendingConfirm { partner, round: r, .. } = self.status {
            if partner == from && r == round {
                self.status = PairStatus::Free;
            }
        }
        PairAction::None
    }

    /// The transaction completed (export sent + acked, or import acked).
    pub fn transaction_done(&mut self, now: f64) {
        debug_assert!(matches!(self.status, PairStatus::InTransaction { .. }));
        self.status = PairStatus::Free;
        // Re-arm the search: after a successful exchange a process may look
        // again immediately (the δ wait only applies to failed rounds).
        self.next_search_at = self.next_search_at.max(now);
    }

    /// Deadline sweep; call from timer ticks.
    pub fn on_tick(&mut self, now: f64, rng: &mut Rng) {
        match self.status {
            PairStatus::Searching { deadline, .. } if now >= deadline => {
                // Unanswered round (slow peers): treat as failed.
                self.round_failed(now, rng);
            }
            PairStatus::PendingConfirm { deadline, .. } if now >= deadline => {
                self.counters.confirm_timeouts += 1;
                self.status = PairStatus::Free;
            }
            PairStatus::InTransaction { deadline, .. } if now >= deadline => {
                // Partner vanished mid-transaction; unlock.
                self.counters.confirm_timeouts += 1;
                self.status = PairStatus::Free;
            }
            _ => {}
        }
    }

    fn round_failed(&mut self, now: f64, rng: &mut Rng) {
        self.counters.failed_rounds += 1;
        self.status = PairStatus::Free;
        // δ jittered in [0.5δ, 1.5δ]: prevents lock-step retry livelock
        // between two processes that keep declining each other.
        let jitter = 0.5 + rng.next_f64();
        self.next_search_at = now + self.cfg.delta * jitter;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(me: u32) -> (Pairing, Rng) {
        (
            Pairing::new(ProcessId(me), PairingConfig::default()),
            Rng::new(42 + me as u64),
        )
    }

    #[test]
    fn round_sends_five_distinct_requests() {
        let (mut p, mut rng) = mk(0);
        match p.maybe_start_round(0.0, Role::Idle, 10, &mut rng) {
            PairAction::SendRequests { targets, role, .. } => {
                assert_eq!(targets.len(), 5);
                assert_eq!(role, Role::Idle);
                let mut t = targets.clone();
                t.sort();
                t.dedup();
                assert_eq!(t.len(), 5, "distinct");
                assert!(!targets.contains(&ProcessId(0)), "never self");
            }
            other => panic!("expected SendRequests, got {other:?}"),
        }
        assert!(!p.is_free());
    }

    #[test]
    fn small_population_caps_tries() {
        let (mut p, mut rng) = mk(0);
        match p.maybe_start_round(0.0, Role::Idle, 3, &mut rng) {
            PairAction::SendRequests { targets, .. } => assert_eq!(targets.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn no_round_while_engaged_or_backing_off() {
        let (mut p, mut rng) = mk(0);
        let _ = p.maybe_start_round(0.0, Role::Idle, 10, &mut rng);
        assert_eq!(p.maybe_start_round(0.0, Role::Idle, 10, &mut rng), PairAction::None);

        let (mut p2, mut rng2) = mk(1);
        p2.next_search_at = 5.0;
        assert_eq!(p2.maybe_start_round(1.0, Role::Idle, 10, &mut rng2), PairAction::None);
        assert!(matches!(
            p2.maybe_start_round(5.0, Role::Idle, 10, &mut rng2),
            PairAction::SendRequests { .. }
        ));
    }

    #[test]
    fn opposite_role_accepts_same_role_declines() {
        let (mut p, _) = mk(1);
        let a = p.on_request(ProcessId(0), 7, Role::Busy, Role::Idle, 0.0);
        assert_eq!(a, PairAction::SendAccept { to: ProcessId(0), round: 7 });
        assert!(matches!(p.status, PairStatus::PendingConfirm { .. }));

        let (mut p2, _) = mk(2);
        let d = p2.on_request(ProcessId(0), 8, Role::Idle, Role::Idle, 0.0);
        assert_eq!(d, PairAction::SendDecline { to: ProcessId(0), round: 8 });
        assert!(p2.is_free());
    }

    #[test]
    fn engaged_process_declines_everything() {
        let (mut p, _) = mk(1);
        let _ = p.on_request(ProcessId(0), 1, Role::Busy, Role::Idle, 0.0);
        let a = p.on_request(ProcessId(3), 2, Role::Busy, Role::Idle, 0.0);
        assert_eq!(a, PairAction::SendDecline { to: ProcessId(3), round: 2 });
    }

    #[test]
    fn full_idle_requester_flow() {
        // idle p0 requests; busy p1 accepts; p0 confirms; p1 exports; ack.
        let (mut idle, mut rng) = mk(0);
        let round = match idle.maybe_start_round(0.0, Role::Idle, 4, &mut rng) {
            PairAction::SendRequests { round, .. } => round,
            other => panic!("{other:?}"),
        };
        let (mut busy, _) = mk(1);
        assert!(matches!(
            busy.on_request(ProcessId(0), round, Role::Idle, Role::Busy, 0.0),
            PairAction::SendAccept { .. }
        ));
        match idle.on_accept(ProcessId(1), round, 0.001) {
            PairAction::Confirmed { partner, then_export, .. } => {
                assert_eq!(partner, ProcessId(1));
                assert!(!then_export, "idle side does not export");
            }
            other => panic!("{other:?}"),
        }
        match busy.on_confirm(ProcessId(0), round, false, 0.002) {
            PairAction::BeginTransaction { export, .. } => assert!(export),
            other => panic!("{other:?}"),
        }
        busy.transaction_done(0.003);
        idle.transaction_done(0.003);
        assert!(busy.is_free() && idle.is_free());
        assert_eq!(busy.counters.transactions, 1);
        assert_eq!(idle.counters.transactions, 1);
    }

    #[test]
    fn busy_requester_exports() {
        let (mut busy, mut rng) = mk(0);
        let round = match busy.maybe_start_round(0.0, Role::Busy, 4, &mut rng) {
            PairAction::SendRequests { round, .. } => round,
            other => panic!("{other:?}"),
        };
        match busy.on_accept(ProcessId(2), round, 0.001) {
            PairAction::Confirmed { then_export, .. } => assert!(then_export),
            other => panic!("{other:?}"),
        }
        let (mut idle, _) = mk(2);
        let _ = idle.on_request(ProcessId(0), round, Role::Busy, Role::Idle, 0.0005);
        match idle.on_confirm(ProcessId(0), round, true, 0.002) {
            PairAction::BeginTransaction { export, .. } => assert!(!export),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn second_accept_released() {
        let (mut p, mut rng) = mk(0);
        let round = match p.maybe_start_round(0.0, Role::Idle, 8, &mut rng) {
            PairAction::SendRequests { round, .. } => round,
            other => panic!("{other:?}"),
        };
        let _ = p.on_accept(ProcessId(1), round, 0.001);
        let a = p.on_accept(ProcessId(2), round, 0.002);
        assert_eq!(a, PairAction::SendRelease { to: ProcessId(2), round });
    }

    #[test]
    fn release_unlocks_pending() {
        let (mut p, _) = mk(3);
        let _ = p.on_request(ProcessId(0), 9, Role::Busy, Role::Idle, 0.0);
        let _ = p.on_release(ProcessId(0), 9);
        assert!(p.is_free());
    }

    #[test]
    fn all_declines_back_off_with_jitter() {
        let (mut p, mut rng) = mk(0);
        let round = match p.maybe_start_round(0.0, Role::Idle, 4, &mut rng) {
            PairAction::SendRequests { round, targets, .. } => {
                assert_eq!(targets.len(), 3);
                round
            }
            other => panic!("{other:?}"),
        };
        for _ in 0..3 {
            let _ = p.on_decline(round, 0.001, &mut rng);
        }
        assert!(p.is_free());
        assert_eq!(p.counters.failed_rounds, 1);
        let wait = p.next_search_at - 0.001;
        assert!(
            wait >= 0.5 * p.cfg.delta && wait <= 1.5 * p.cfg.delta,
            "jittered δ: {wait}"
        );
    }

    #[test]
    fn stale_decline_ignored() {
        let (mut p, mut rng) = mk(0);
        let _ = p.maybe_start_round(0.0, Role::Idle, 4, &mut rng);
        let _ = p.on_decline(999, 0.001, &mut rng); // wrong round
        assert!(!p.is_free());
    }

    #[test]
    fn pending_confirm_times_out() {
        let (mut p, mut rng) = mk(1);
        let _ = p.on_request(ProcessId(0), 1, Role::Busy, Role::Idle, 0.0);
        p.on_tick(0.01, &mut rng); // before deadline
        assert!(!p.is_free());
        p.on_tick(1.0, &mut rng); // past deadline
        assert!(p.is_free());
        assert_eq!(p.counters.confirm_timeouts, 1);
    }

    #[test]
    fn stale_confirm_ignored() {
        let (mut p, _) = mk(1);
        let a = p.on_confirm(ProcessId(0), 5, false, 0.0);
        assert_eq!(a, PairAction::None);
        assert!(p.is_free());
    }

    #[test]
    fn next_wakeup_tracks_state() {
        let (mut p, mut rng) = mk(0);
        p.next_search_at = 3.0;
        assert_eq!(p.next_wakeup(), Some(3.0));
        let _ = p.maybe_start_round(3.0, Role::Idle, 4, &mut rng);
        let w = p.next_wakeup().expect("deadline");
        assert!(w > 3.0);
    }
}
