//! Bench/regeneration target for **Fig 4**: the paper's headline result —
//! block Cholesky on non-square grids, DLB off vs on.
//!
//! Cases (paper §6): N = 20 000, 12×12 blocks, P = 10 (2×5 grid) and
//! N = 30 000, 12×12 blocks, P = 15 (3×5 grid); W_T calibrated as
//! max w_i(t)/2 from a DLB-off run; δ = 10 ms; Basic strategy.
//! Paper reports a 5–6% execution-time reduction; shape target here:
//! measurable improvement, no regression, migrations > 0.
//!
//! Run: `cargo bench --bench fig4_cholesky_dlb`

use ductr::experiments::fig4;
use ductr::util::bench::{BenchConfig, Runner};

fn main() {
    let mut r = Runner::new("fig4: Cholesky DLB off vs on (DES, paper scale)", BenchConfig::macro_bench());

    let results = fig4::run(1).expect("fig4 run");
    for case in &results {
        println!("{}", case.render(5));
        r.record(&format!("{} makespan off", case.spec.name), case.off.makespan, "s");
        r.record(&format!("{} makespan on", case.spec.name), case.on.makespan, "s");
        r.record(
            &format!("{} improvement", case.spec.name),
            case.improvement() * 100.0,
            "%",
        );
        r.record(
            &format!("{} migrations", case.spec.name),
            case.on.counters.tasks_exported as f64,
            "tasks",
        );
        assert!(case.on.counters.tasks_exported > 0, "DLB must migrate work");
        assert!(
            case.improvement() > -0.05,
            "DLB must not regress: {:+.2}%",
            case.improvement() * 100.0
        );
    }

    // average improvement across the two paper cases should be positive
    let avg: f64 =
        results.iter().map(|c| c.improvement()).sum::<f64>() / results.len() as f64;
    r.record("average improvement (paper: 5-6%)", avg * 100.0, "%");
    assert!(avg > 0.0, "average DLB improvement must be positive, got {:+.2}%", avg * 100.0);

    let dir = ductr::experiments::out_dir("fig4");
    for case in &results {
        let stem = case.spec.name.replace([' ', '='], "_");
        ductr::metrics::csv::write_rows(
            dir.join(format!("fig4_{stem}.csv")),
            &["process", "time", "workload", "dlb"],
            &case.csv_rows(),
        )
        .expect("csv");
    }
    r.write_csv(dir.join("fig4_bench.csv").to_str().expect("utf8")).expect("csv");
    println!("fig4: OK (csv in {})", dir.display());
}
