//! Bench/regeneration target for **Fig 3**: average (and max) time to find
//! a busy–idle pair, measured on the live protocol in the DES.
//!
//! Paper shape targets: the average grows slowly with P; the hardest mix is
//! ~50% busy; with δ = 10 ms the averages justify δ ≈ 10 ms for the 10–15
//! process runs of Fig 4.
//!
//! Run: `cargo bench --bench fig3_pairing_time`

use ductr::experiments::fig3;
use ductr::util::bench::{BenchConfig, Runner};

fn main() {
    let mut r = Runner::new("fig3: time to find a busy–idle pair", BenchConfig::macro_bench());

    r.bench("single trial P=16 busy=8 δ=10ms", || {
        fig3::pairing_time(16, 8, 0.010, 12345)
    });

    let fig = fig3::run(&[8, 16, 32, 64, 128], &[0.1, 0.3, 0.5, 0.7, 0.9], 0.010, 30, 7);
    println!("{}", fig.render());
    for c in &fig.cells {
        r.record(
            &format!("P={:<3} busy={:>2.0}% mean", c.processes, c.busy_fraction * 100.0),
            c.mean * 1e3,
            "ms",
        );
    }

    // shape assertions
    let mean_of = |p: usize, f: f64| {
        fig.cells
            .iter()
            .find(|c| c.processes == p && (c.busy_fraction - f).abs() < 1e-9)
            .expect("cell")
            .mean
    };
    // grows slowly: less than 10× from P=8 to P=128 at the hardest mix
    assert!(
        mean_of(128, 0.5) < mean_of(8, 0.5) * 10.0,
        "pairing time must grow slowly with P"
    );
    // δ = 10 ms is reasonable for P in 10..15 (paper's choice): pairing in
    // a handful of δ
    assert!(mean_of(16, 0.5) < 0.10, "P=16 pairing within 100 ms");

    let dir = ductr::experiments::out_dir("fig3");
    ductr::metrics::csv::write_rows(
        dir.join("fig3.csv"),
        &["processes", "busy_fraction", "mean_s", "max_s", "p95_s"],
        &fig.csv_rows(),
    )
    .expect("csv");
    r.write_csv(dir.join("fig3_bench.csv").to_str().expect("utf8")).expect("csv");
    println!("fig3: OK (csv in {})", dir.display());
}
