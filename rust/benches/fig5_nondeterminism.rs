//! Bench/regeneration target for **Fig 5**: the non-determinism of
//! randomized DLB on the N = 100 000, P = 11 (11×1 grid) configuration.
//!
//! The paper shows two executions — one successful, one not.  We sweep ten
//! seeds, report each improvement, and name the best/worst pair (the honest
//! reproduction of the paper's lucky/unlucky runs).
//!
//! Run: `cargo bench --bench fig5_nondeterminism`

use ductr::experiments::fig5;
use ductr::util::bench::{BenchConfig, Runner};

fn main() {
    let mut r = Runner::new(
        "fig5: seed-dependence of DLB, N=100000 P=11 11x1",
        BenchConfig::macro_bench(),
    );

    let seeds: Vec<u64> = (1..=10).collect();
    let fig = fig5::run(100_000, &seeds).expect("fig5 run");
    println!("{}", fig.render());

    r.record("baseline (DLB off) makespan", fig.baseline_makespan, "s");
    for o in &fig.outcomes {
        r.record(&format!("seed {:<2} improvement", o.seed), o.improvement * 100.0, "%");
    }
    r.record("best improvement", fig.best().improvement * 100.0, "%");
    r.record("worst improvement", fig.worst().improvement * 100.0, "%");
    r.record("spread (best − worst)", fig.spread() * 100.0, "%");

    // paper's qualitative claims:
    // (1) outcomes vary across runs (non-determinism is real)
    assert!(fig.spread() > 0.001, "seeds must produce different outcomes");
    // (2) at least one run improves (the paper's 'successful' execution)
    assert!(
        fig.best().improvement > 0.0,
        "some seed should find an improvement, best = {:+.3}%",
        fig.best().improvement * 100.0
    );

    let dir = ductr::experiments::out_dir("fig5");
    ductr::metrics::csv::write_rows(
        dir.join("fig5.csv"),
        &["seed", "makespan", "improvement", "migrations"],
        &fig.csv_rows(),
    )
    .expect("csv");
    r.write_csv(dir.join("fig5_bench.csv").to_str().expect("utf8")).expect("csv");
    println!("fig5: OK (csv in {})", dir.display());
}
