//! Ablation bench: design-choice sensitivity on the Fig 4-left case —
//! export strategy, δ back-off, and the §3 gap (hysteresis) model.
//!
//! Run: `cargo bench --bench ablation`

use ductr::experiments::ablation;
use ductr::util::bench::{BenchConfig, Runner};

fn main() {
    let mut r = Runner::new("ablation: strategy / δ / gap on Fig 4-left", BenchConfig::macro_bench());

    let res = ablation::run(1).expect("ablation");
    println!("{}", res.render());

    r.record("baseline (DLB off)", res.baseline_makespan, "s");
    for row in res.strategies.iter().chain(&res.deltas).chain(&res.gaps) {
        r.record(&row.label, row.improvement_vs_off * 100.0, "%");
    }

    // sanity: the gap shrinks the busy set, so migrations fall monotonically
    // (measured: total request traffic is dominated by idle searchers and
    // does NOT fall — recorded as-is in EXPERIMENTS.md §Ablations)
    let gap0 = res.gaps.iter().find(|g| g.label == "gap=0").expect("gap0");
    let gap10 = res.gaps.iter().find(|g| g.label == "gap=10").expect("gap10");
    assert!(
        gap10.migrations <= gap0.migrations,
        "gap must reduce migrations: {} vs {}",
        gap10.migrations,
        gap0.migrations
    );

    let dir = ductr::experiments::out_dir("ablation");
    ductr::metrics::csv::write_rows(
        dir.join("ablation.csv"),
        &["row", "makespan", "improvement", "migrations", "requests", "max_w"],
        &res.csv_rows(),
    )
    .expect("csv");
    r.write_csv(dir.join("ablation_bench.csv").to_str().expect("utf8")).expect("csv");
    println!("ablation: OK (csv in {})", dir.display());
}
