//! Bench/regeneration target for **Fig 1**: hypergeometric success
//! probability of the randomized partner search.
//!
//! Regenerates both panels (P = 10, P = 100) with Monte-Carlo validation
//! and benches the exact evaluation (it sits on the DLB decision path when
//! reasoning about δ).
//!
//! Run: `cargo bench --bench fig1_probability`

use ductr::experiments::fig1;
use ductr::prob::hypergeom::Hypergeometric;
use ductr::util::bench::{bb, BenchConfig, Runner};

fn main() {
    let mut r = Runner::new("fig1: pairing success probability", BenchConfig::micro_bench());

    r.bench("hypergeom pmf(0) P=100 K=50 n=5", || {
        bb(Hypergeometric::new(bb(100), bb(50), bb(5)).pmf(0))
    });
    r.bench("success_probability P=1e6 K=5e5 n=5", || {
        bb(Hypergeometric::new(bb(1_000_000), bb(500_000), bb(5)).success_probability())
    });

    // regenerate the figure data
    let fig = fig1::run(10, 20_000, 1);
    println!("{}", fig.render_panel(10));
    println!("{}", fig.render_panel(100));
    for c in &fig.curves {
        let n5 = c.points.iter().find(|p| p.0 == 5).expect("n=5 present");
        r.record(
            &format!("P={} K={} n=5 success", c.population, c.busy),
            n5.1,
            "probability",
        );
        let mc_err = (n5.2 - n5.1).abs();
        assert!(mc_err < 0.02, "MC vs exact at P={} K={}: {mc_err}", c.population, c.busy);
    }
    r.record("paper claim: K=P/2 n=5 (P=100)", fig.k_half_n5, "probability");
    r.record("asymptote 1-2^-5", fig.asymptote_n5, "probability");
    assert!(fig.k_half_n5 > 0.96, "paper's >96% claim must hold");

    let dir = ductr::experiments::out_dir("fig1");
    ductr::metrics::csv::write_rows(
        dir.join("fig1.csv"),
        &["population", "busy", "tries", "exact", "monte_carlo"],
        &fig.csv_rows(),
    )
    .expect("csv");
    r.write_csv(dir.join("fig1_bench.csv").to_str().expect("utf8")).expect("csv");
    println!("fig1: OK (csv in {})", dir.display());
}
