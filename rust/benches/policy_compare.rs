//! Balancer-policy benchmark: makespan and migration volume for pairing vs
//! stealing vs hierarchical vs diffusion — fixed and adaptive δ — swept
//! over topology and process count on the Cholesky and random-DAG
//! workloads (DES mode).
//!
//! Figure-regeneration style (like `fig4_cholesky_dlb`): each cell runs
//! once under a fixed seed — the DES is deterministic, so repetition would
//! measure nothing but itself — and records makespan plus migration count.
//!
//! Run: `cargo bench --bench policy_compare`

use std::sync::Arc;

use ductr::apps::rand_dag;
use ductr::cholesky;
use ductr::config::{Config, Grid, PolicyKind, TopologyKind};
use ductr::sim::engine::SimEngine;
use ductr::util::bench::{BenchConfig, Runner};

fn cell_cfg(
    p: usize,
    grid: (usize, usize),
    policy: PolicyKind,
    topo: TopologyKind,
    adaptive: bool,
) -> Config {
    let mut c = Config::default();
    c.processes = p;
    c.grid = Some(Grid::new(grid.0, grid.1));
    c.nb = 10;
    c.block = 128;
    c.dlb_enabled = true;
    c.policy = policy;
    c.topology = topo;
    c.adaptive_delta = adaptive;
    c.wt = 3;
    c.delta = 0.002;
    c.seed = 7;
    c.validate().expect("bench config");
    c
}

fn main() {
    let mut r = Runner::new("policy × topology × adaptive × P", BenchConfig::macro_bench());

    for &(p, grid) in &[(8usize, (2usize, 4usize)), (16, (4, 4))] {
        for topo in [TopologyKind::Flat, TopologyKind::Torus, TopologyKind::Cluster] {
            for policy in PolicyKind::ALL {
                for adaptive in [false, true] {
                    let tag = if adaptive { "adaptive" } else { "fixed" };
                    let cfg = cell_cfg(p, grid, policy, topo, adaptive);
                    let chol = cholesky::run_sim(&cfg).expect("cholesky sim");
                    r.record(
                        &format!("cholesky P={p} {topo} {policy} {tag} makespan"),
                        chol.makespan,
                        "s",
                    );
                    r.record(
                        &format!("cholesky P={p} {topo} {policy} {tag} migrated"),
                        chol.counters.tasks_exported as f64,
                        "tasks",
                    );
                    r.record(
                        &format!("cholesky P={p} {topo} {policy} {tag} inter-node"),
                        chol.counters.tasks_exported_remote as f64,
                        "tasks",
                    );
                    assert!(chol.makespan > 0.0);

                    let g = rand_dag::build(p, rand_dag::DagParams::default(), 7);
                    let dag = SimEngine::from_config(&cfg, Arc::clone(&g))
                        .run()
                        .expect("rand_dag sim");
                    r.record(
                        &format!("rand_dag P={p} {topo} {policy} {tag} makespan"),
                        dag.makespan,
                        "s",
                    );
                    r.record(
                        &format!("rand_dag P={p} {topo} {policy} {tag} migrated"),
                        dag.counters.tasks_exported as f64,
                        "tasks",
                    );
                    assert!(dag.makespan > 0.0);
                }
            }
        }
    }

    let dir = ductr::experiments::out_dir("compare");
    r.write_csv(dir.join("policy_compare_bench.csv").to_str().expect("utf8")).expect("csv");
    println!("policy_compare: OK (csv in {})", dir.display());
}
