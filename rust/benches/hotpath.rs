//! Hot-path micro-benchmarks: the L3 structures the §Perf pass optimizes.
//!
//! - ready-queue push/pop and strategy drains (per-transaction path)
//! - STF graph construction (startup path)
//! - DES event throughput on the Fig 4 workload (whole-sim path)
//! - pairing-protocol round trip (control-plane path)
//! - PJRT kernel execution (real-mode task path; needs artifacts)
//!
//! Run: `cargo bench --bench hotpath`

use std::sync::Arc;

use ductr::cholesky::{self, ProcessGrid};
use ductr::config::{Config, Grid};
use ductr::core::graph::GraphBuilder;
use ductr::core::ids::ProcessId;
use ductr::core::task::TaskKind;
use ductr::sched::queue::{ReadyQueue, ReadyTask};
use ductr::sim::engine::SimEngine;
use ductr::util::bench::{bb, BenchConfig, Runner};

fn main() {
    let mut micro = Runner::new("hotpath micro", BenchConfig::micro_bench());

    // queue ops
    micro.bench("ready-queue push+pop", || {
        let mut q = ReadyQueue::new();
        for i in 0..32 {
            q.push(ReadyTask { task: ductr::core::ids::TaskId(i), origin: ProcessId(0) });
        }
        while q.pop().is_some() {}
    });

    // drain_back (export-selection primitive)
    micro.bench("drain_back 8 of 32", || {
        let mut q = ReadyQueue::new();
        for i in 0..32 {
            q.push(ReadyTask { task: ductr::core::ids::TaskId(i), origin: ProcessId(0) });
        }
        bb(q.drain_back(8, |_| true))
    });

    let mut meso = Runner::new("hotpath meso", BenchConfig::default());

    // graph construction: the Fig 4 DAG (12×12 blocks, 378 tasks)
    meso.bench("cholesky DAG build nb=12", || {
        bb(cholesky::build(12, 64, ProcessGrid::new(Grid::new(2, 5))))
    });
    meso.bench("cholesky DAG build nb=32 (6544 tasks)", || {
        bb(cholesky::build(32, 64, ProcessGrid::new(Grid::new(2, 5))))
    });

    // synthetic STF builder throughput
    meso.bench("STF builder 10k independent tasks", || {
        let mut b = GraphBuilder::new();
        for _ in 0..10_000 {
            let d = b.data(ProcessId(0), 8, 8);
            b.task(TaskKind::Synthetic, vec![], d, 1, None);
        }
        bb(b.build())
    });

    // whole-sim throughput: Fig 4 left in the DES
    let mut cfg = Config::default();
    cfg.processes = 10;
    cfg.grid = Some(Grid::new(2, 5));
    cfg.nb = 12;
    cfg.block = 1667;
    cfg.dlb_enabled = true;
    cfg.wt = 5;
    cfg.validate().expect("valid");
    let mut events_per_sec = 0.0;
    let res = meso.bench("DES full fig4-left run (DLB on)", || {
        let dag = cholesky::build(cfg.nb, cfg.block, ProcessGrid::new(cfg.effective_grid()));
        let mut eng = SimEngine::from_config(&cfg, Arc::clone(&dag.graph));
        let r = eng.run().expect("sim");
        events_per_sec = r.events_processed as f64;
        bb(r.makespan)
    });
    let sim_secs = res.secs_per_iter();
    println!(
        "DES throughput: {:.0} events/s ({:.0} events per run)",
        events_per_sec / sim_secs,
        events_per_sec
    );

    // the acceptance workload of the hot-path overhaul: P=256 random DAG
    // (the exact case `ductr bench` records — one shared definition)
    let (cfg256, g256, _) = ductr::experiments::bench::rand_dag_case(256, 1);
    let mut events256 = 0.0;
    let mut peak256 = 0usize;
    let res256 = meso.bench("DES rand-dag P=256 (DLB on)", || {
        let mut eng = SimEngine::from_config(&cfg256, Arc::clone(&g256));
        let r = eng.run().expect("sim");
        events256 = r.events_processed as f64;
        peak256 = r.peak_pending_events;
        bb(r.makespan)
    });
    println!(
        "DES P=256 throughput: {:.0} events/s ({:.0} events per run, peak pending {peak256})",
        events256 / res256.secs_per_iter(),
        events256
    );

    // PJRT kernel hot path (skipped without artifacts)
    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art.join("manifest.txt").exists() {
        let manifest =
            Arc::new(ductr::runtime::Manifest::load(&art).expect("manifest"));
        for block in [32usize, 64, 128] {
            let mut lib =
                ductr::runtime::KernelLibrary::new(Arc::clone(&manifest), block).expect("lib");
            let c: Vec<f32> = vec![0.5; block * block];
            let a: Vec<f32> = vec![0.25; block * block];
            let b2: Vec<f32> = vec![0.125; block * block];
            // warm compile outside the timer
            let _ = lib.execute(TaskKind::Gemm, &[&c, &a, &b2]).expect("gemm");
            let r = meso.bench(&format!("PJRT gemm b={block}"), || {
                bb(lib.execute(TaskKind::Gemm, &[&c, &a, &b2]).expect("gemm"))
            });
            let flops = TaskKind::Gemm.flops_for_block(block as u64) as f64;
            println!(
                "  gemm b={block}: {:.2} GFLOP/s",
                flops / r.secs_per_iter() / 1e9
            );
        }
    } else {
        println!("(PJRT benches skipped: artifacts not built)");
    }

    let dir = ductr::experiments::out_dir("hotpath");
    micro.write_csv(dir.join("micro.csv").to_str().expect("utf8")).expect("csv");
    meso.write_csv(dir.join("meso.csv").to_str().expect("utf8")).expect("csv");
    println!("hotpath: OK (csv in {})", dir.display());
}
