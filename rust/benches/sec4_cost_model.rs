//! Bench/regeneration target for the **§4 analysis**: the migration cost
//! ratio Q = (S/R)(D/F) and its measured consequence.
//!
//! Paper numbers at S/R = 40: GEMM Q = 60/m (our traffic accounting gives
//! 80/m — we also return the output), GEMV Q ≈ 20.  Measured half: an
//! imbalanced GEMM-intensity bag benefits clearly from DLB; GEMV chains do
//! not (until queues ≫ Q).
//!
//! Run: `cargo bench --bench sec4_cost_model`

use ductr::experiments::sec4;
use ductr::util::bench::{BenchConfig, Runner};

fn main() {
    let mut r = Runner::new("sec4: migration cost model Q = (S/R)(D/F)", BenchConfig::macro_bench());

    let res = sec4::run(1).expect("sec4");
    println!("{}", res.render());

    for row in &res.table {
        r.record(
            &format!("Q {} b={}", row.kind, row.block),
            row.q,
            "ratio",
        );
    }
    for case in &res.cases {
        r.record(&format!("{} improvement", case.name), case.improvement() * 100.0, "%");
    }

    // paper checks
    let gemv_row = res
        .table
        .iter()
        .find(|t| t.kind == ductr::core::task::TaskKind::Gemv && t.block >= 512)
        .expect("gemv row");
    assert!((gemv_row.q - 20.0).abs() < 0.5, "paper: Q_gemv ≈ 20, got {}", gemv_row.q);
    let bag = &res.cases[0];
    let gemv = &res.cases[1];
    assert!(
        bag.improvement() > gemv.improvement(),
        "high-intensity tasks must benefit more from DLB than GEMV"
    );
    assert!(bag.improvement() > 0.10, "gemm bag should clearly benefit");

    let dir = ductr::experiments::out_dir("sec4");
    ductr::metrics::csv::write_rows(
        dir.join("sec4_q_table.csv"),
        &["kind_index", "block", "q", "wt_guideline"],
        &res.csv_rows(),
    )
    .expect("csv");
    r.write_csv(dir.join("sec4_bench.csv").to_str().expect("utf8")).expect("csv");
    println!("sec4: OK (csv in {})", dir.display());
}
