//! Determinism golden tests for the hot-path overhaul.
//!
//! The scratch-buffer effect API, the event slab, and the dense stores must
//! not change *what* the simulator computes — only how fast.  Three layers
//! of protection:
//!
//! 1. **bit-identity**: every policy × workload cell, run twice from the
//!    same seed, must agree exactly on makespan, counters, and event count;
//! 2. **state-machine replay**: driving one `ProcessState` through a fixed
//!    event script with a reused scratch buffer produces the same effect
//!    stream as fresh buffers per step (the engines reuse, the tests
//!    mostly don't — both must see identical streams);
//! 3. **golden snapshot**: run fingerprints are compared against
//!    `tests/golden/determinism.txt` when it exists; absent, the file is
//!    written (bless-on-first-run) so any later engine change that shifts a
//!    makespan bit shows up as a diff, not silence.

use std::sync::Arc;

use ductr::config::{Config, PolicyKind, WindowMode};
use ductr::core::graph::{GraphBuilder, TaskGraph};
use ductr::core::ids::ProcessId;
use ductr::core::process::{Effect, ProcessParams, ProcessState};
use ductr::core::task::TaskKind;
use ductr::net::message::{Envelope, Msg, Role};
use ductr::sim::engine::SimEngine;

/// Skewed bag: all tasks start on p0, DLB must spread them.
fn bag_graph(n: usize) -> Arc<TaskGraph> {
    let mut b = GraphBuilder::new();
    for _ in 0..n {
        let d = b.data(ProcessId(0), 64, 64);
        b.task(TaskKind::Synthetic, vec![], d, 30_000_000, None);
    }
    b.build()
}

fn cfg_for(policy: PolicyKind, adaptive: bool, seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.processes = 4;
    cfg.grid = None;
    cfg.dlb_enabled = true;
    cfg.policy = policy;
    cfg.adaptive_delta = adaptive;
    cfg.wt = 2;
    cfg.delta = 0.001;
    cfg.seed = seed;
    cfg.validate().expect("valid");
    cfg
}

/// A compact, exact fingerprint of one run: makespan bits + the counters
/// that any behavioral drift would disturb.
fn fingerprint(policy: PolicyKind, adaptive: bool, seed: u64) -> String {
    let cfg = cfg_for(policy, adaptive, seed);
    let r = SimEngine::from_config(&cfg, bag_graph(24)).run().expect("run");
    format!(
        "{policy}{} seed={seed} makespan={:016x} events={} exported={} received={} rounds={}",
        if adaptive { "+adaptive" } else { "" },
        r.makespan.to_bits(),
        r.events_processed,
        r.counters.tasks_exported,
        r.counters.tasks_received,
        r.counters.rounds,
    )
}

#[test]
fn every_policy_is_bit_identical_across_runs() {
    for policy in PolicyKind::ALL {
        for adaptive in [false, true] {
            for seed in [1u64, 7, 42] {
                let a = fingerprint(policy, adaptive, seed);
                let b = fingerprint(policy, adaptive, seed);
                assert_eq!(
                    a, b,
                    "{policy} (adaptive {adaptive}) seed {seed} must be deterministic"
                );
            }
        }
    }
}

#[test]
fn every_policy_conserves_migrated_tasks() {
    for policy in PolicyKind::ALL {
        for adaptive in [false, true] {
            let cfg = cfg_for(policy, adaptive, 11);
            let r = SimEngine::from_config(&cfg, bag_graph(24)).run().expect("run");
            assert_eq!(
                r.counters.tasks_exported, r.counters.tasks_received,
                "{policy} (adaptive {adaptive}): every exported task must be received"
            );
            assert!(
                r.counters.tasks_exported > 0,
                "{policy} (adaptive {adaptive}): the skewed bag must migrate"
            );
        }
    }
}

/// Drive one busy `ProcessState` through a fixed pairing script twice: once
/// with a single reused scratch buffer (the engine pattern), once with a
/// fresh buffer per step.  The rendered effect streams must match exactly.
#[test]
fn scratch_buffer_reuse_matches_fresh_buffers() {
    let script: &[(u32, Msg, f64)] = &[
        (1, Msg::PairRequest { round: 1, role: Role::Idle, load: 0, eta: 0.0 }, 0.001),
        (1, Msg::PairConfirm { round: 1, load: 0, eta: 0.0 }, 0.002),
        (1, Msg::ExportAck { round: 1, accepted: 7 }, 0.003),
        (2, Msg::PairRequest { round: 9, role: Role::Idle, load: 1, eta: 0.0 }, 0.004),
    ];

    let mk = || {
        let mut cfg = Config::default();
        cfg.dlb_enabled = true;
        cfg.wt = 2;
        cfg.validate().expect("valid");
        let params = ProcessParams::from_config(&cfg);
        ProcessState::new(ProcessId(0), 3, bag_graph(10), params, 5)
    };
    let env = |from: u32, msg: Msg| Envelope {
        from: ProcessId(from),
        to: ProcessId(0),
        msg,
        wire_doubles: 8,
    };

    // run A: one buffer, drained between steps (engine-style)
    let mut a_log = Vec::new();
    let mut ps = mk();
    let mut buf: Vec<Effect> = Vec::new();
    ps.start(0.0, &mut buf);
    a_log.extend(buf.drain(..).map(|e| format!("{e:?}")));
    for (from, msg, t) in script {
        ps.on_message(env(*from, msg.clone()), *t, &mut buf);
        a_log.extend(buf.drain(..).map(|e| format!("{e:?}")));
    }

    // run B: fresh buffer per step
    let mut b_log = Vec::new();
    let mut ps = mk();
    let mut buf: Vec<Effect> = Vec::new();
    ps.start(0.0, &mut buf);
    b_log.extend(buf.into_iter().map(|e| format!("{e:?}")));
    for (from, msg, t) in script {
        let mut buf: Vec<Effect> = Vec::new();
        ps.on_message(env(*from, msg.clone()), *t, &mut buf);
        b_log.extend(buf.into_iter().map(|e| format!("{e:?}")));
    }

    assert_eq!(a_log, b_log, "effect stream must not depend on buffer reuse");
    assert!(a_log.iter().any(|e| e.contains("TaskExport")), "script must export work");
}

/// Transport coalescing is transparent when it has nothing to pack: on a
/// dependency chain alternating between two processes every step emits at
/// most one message per destination, so `[sim] coalesce = true` must
/// reproduce the coalesce-off run bit for bit — makespan, counters and
/// event count.  (The golden snapshot below runs with the default
/// `coalesce = false`, so it is untouched by this PR either way.)
#[test]
fn coalesce_onoff_identical_when_steps_send_one_message_per_destination() {
    let chain = |coalesce: bool| {
        let mut cfg = Config::default();
        cfg.processes = 2;
        cfg.grid = None;
        cfg.dlb_enabled = false;
        cfg.coalesce = coalesce;
        cfg.validate().expect("valid");
        let mut b = GraphBuilder::new();
        let mut prev: Option<ductr::core::ids::DataId> = None;
        for i in 0..12u32 {
            let d = b.data(ProcessId(i % 2), 32, 32);
            let args = prev.map(|x| vec![x]).unwrap_or_default();
            b.task(TaskKind::Synthetic, args, d, 2_000_000, None);
            prev = Some(d);
        }
        SimEngine::from_config(&cfg, b.build()).run().expect("run")
    };
    let off = chain(false);
    let on = chain(true);
    assert_eq!(on.makespan.to_bits(), off.makespan.to_bits(), "makespan must not move");
    assert_eq!(on.events_processed, off.events_processed);
    assert_eq!(on.counters, off.counters);
    assert_eq!(on.counters.messages_coalesced, 0, "nothing to pack on a chain");
}

/// Arming the span recorder must not move a single bit of any run: the
/// recorder is write-only and lives strictly downstream of every RNG draw
/// and policy decision.  Checked across the full policy × adaptive grid so
/// a future hook placed upstream of a decision cannot slip through on the
/// one policy the other tests happen to exercise.
#[test]
fn tracing_on_is_bit_identical_to_tracing_off_for_every_policy() {
    for policy in PolicyKind::ALL {
        for adaptive in [false, true] {
            let run = |trace: bool| {
                let mut cfg = cfg_for(policy, adaptive, 3);
                cfg.trace_enabled = trace;
                SimEngine::from_config(&cfg, bag_graph(24)).run().expect("run")
            };
            let off = run(false);
            let on = run(true);
            let tag = format!("{policy} (adaptive {adaptive})");
            assert_eq!(on.makespan.to_bits(), off.makespan.to_bits(), "{tag}: makespan moved");
            assert_eq!(on.events_processed, off.events_processed, "{tag}: event count moved");
            assert_eq!(on.counters, off.counters, "{tag}: counters moved");
            assert!(off.trace.is_empty(), "{tag}: recorder off must record nothing");
            assert!(on.trace.total_events() > 0, "{tag}: recorder on must record");
        }
    }
}

/// The tentpole contract of the sharded engine: `--sim-threads N` is
/// bit-identical to the single-threaded oracle on every policy × adaptive
/// cell — makespan bits, end-time bits, event count, and the full counter
/// set, aggregate and per-rank.  The skewed bag forces heavy cross-shard
/// migration, so every pairing message crosses the window barrier.
#[test]
fn parallel_engine_matches_single_thread_fingerprints_for_every_policy() {
    for policy in PolicyKind::ALL {
        for adaptive in [false, true] {
            let cfg = cfg_for(policy, adaptive, 1);
            let single = SimEngine::from_config(&cfg, bag_graph(24)).run().expect("single");
            // Both barrier protocols must land on the oracle's bits.
            for window in [WindowMode::Matrix, WindowMode::Scalar] {
                let mut pcfg = cfg.clone();
                pcfg.sim_threads = 2;
                pcfg.sim_window = window;
                pcfg.validate().expect("valid");
                let par = ductr::sim::run_config(&pcfg, bag_graph(24)).expect("sharded");
                let tag = format!("{policy} (adaptive {adaptive}, window {window})");
                assert_eq!(
                    par.makespan.to_bits(),
                    single.makespan.to_bits(),
                    "{tag}: makespan diverged across engines"
                );
                assert_eq!(
                    par.end_time.to_bits(),
                    single.end_time.to_bits(),
                    "{tag}: end time diverged across engines"
                );
                assert_eq!(par.events_processed, single.events_processed, "{tag}: event count");
                assert_eq!(par.counters, single.counters, "{tag}: aggregate counters");
                assert_eq!(
                    par.per_process_counters, single.per_process_counters,
                    "{tag}: per-rank counters"
                );
            }
        }
    }
}

/// Snapshot comparison.  When `tests/golden/determinism.txt` exists the
/// current fingerprints must match it bit for bit; when it does not (first
/// run on a new toolchain/checkout) it is written, and the test passes with
/// a notice — commit the file to pin the baseline.
///
/// KNOWN LIMITATION: until the snapshot is committed, a fresh checkout
/// (e.g. CI) takes the bless branch every time and this test guards
/// nothing — the cross-PR protection starts the moment someone with a
/// toolchain commits the generated file (ROADMAP.md open item).  Failing
/// hard on absence is not an option: it would permanently fail `cargo
/// test` on every fresh checkout until that commit exists.
#[test]
fn golden_fingerprints_match_snapshot() {
    let mut lines = Vec::new();
    for policy in PolicyKind::ALL {
        for adaptive in [false, true] {
            lines.push(fingerprint(policy, adaptive, 1));
        }
    }
    let current = lines.join("\n") + "\n";

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/determinism.txt");
    match std::fs::read_to_string(&path) {
        Ok(golden) => {
            assert_eq!(
                current, golden,
                "run fingerprints drifted from the blessed snapshot \
                 ({}); if the change is intentional, delete the file and \
                 re-run to re-bless",
                path.display()
            );
        }
        // Bless only on genuine absence; any other read failure (perms,
        // I/O, bad UTF-8) must fail rather than overwrite the baseline.
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            std::fs::create_dir_all(path.parent().expect("parent")).expect("mkdir golden");
            std::fs::write(&path, &current).expect("write golden");
            eprintln!("blessed new golden snapshot at {}", path.display());
        }
        Err(e) => panic!("cannot read golden snapshot {}: {e}", path.display()),
    }
}
